"""Deterministic, resumable, shardable data pipeline.

Sources:
  * SyntheticLM — seeded random token streams (CI / smoke / dry-run scale)
  * MMapTokens  — memory-mapped packed uint16/uint32 token files (production
    path: one flat array of tokens, sequence-packed on the fly)

Determinism & fault tolerance: batches are a pure function of (seed, step),
so a restart at step k regenerates exactly the batch stream from k — no
iterator state to checkpoint beyond the step counter already in the train
state.  Per-host sharding slices the global batch by data-parallel rank.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    source: str = "synthetic"        # synthetic | mmap
    path: Optional[str] = None       # token file for mmap
    seed: int = 0
    dp_rank: int = 0                 # this host's data-parallel rank
    dp_size: int = 1


class SyntheticLM:
    """Zipf-ish random tokens — shaped like real text token statistics."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
        self.cfg, self.shape, self.data = cfg, shape, data
        assert shape.global_batch % data.dp_size == 0
        self.local_batch = shape.global_batch // data.dp_size

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.data.seed * 1_000_003 + step) * 65_537 + self.data.dp_rank)
        b, s, v = self.local_batch, self.shape.seq_len, self.cfg.vocab_size
        # Zipf over the vocab, clipped
        toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(toks - 1, v - 1).astype(np.int32)
        batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
        if self.cfg.input_mode == "embeddings":
            emb = rng.standard_normal(
                (b, s, self.cfg.d_model), dtype=np.float32)
            batch["inputs"] = emb
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MMapTokens:
    """Packed flat token file; deterministic strided sequence sampling."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig,
                 dtype=np.uint16):
        self.cfg, self.shape, self.data = cfg, shape, data
        self.tokens = np.memmap(data.path, dtype=dtype, mode="r")
        self.n_tokens = len(self.tokens)
        assert shape.global_batch % data.dp_size == 0
        self.local_batch = shape.global_batch // data.dp_size
        self.n_seqs = (self.n_tokens - 1) // shape.seq_len

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(self.data.seed * 1_000_003 + step)
        # one global permutation draw per step; slice this host's ranks
        idx = rng.integers(0, self.n_seqs, size=self.shape.global_batch)
        lo = self.data.dp_rank * self.local_batch
        idx = idx[lo: lo + self.local_batch]
        s = self.shape.seq_len
        rows = np.stack([
            np.asarray(self.tokens[i * s: i * s + s + 1]) for i in idx])
        rows = rows.astype(np.int32) % self.cfg.vocab_size
        return {"inputs": rows[:, :-1], "targets": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
    if data.source == "mmap":
        return MMapTokens(cfg, shape, data)
    return SyntheticLM(cfg, shape, data)


def write_token_file(path: str, tokens: np.ndarray):
    """Helper for tests/examples: write a packed token file."""
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    tokens.astype(np.uint16).tofile(path)
