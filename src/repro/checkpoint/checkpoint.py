"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Layout of one checkpoint:

    <dir>/step_000123/
        manifest.json      # leaf paths, shapes, dtypes, shard files, checksums
        <leaf>.<i>.npy     # per-leaf shard files (this host's device shards)
    <dir>/step_000123.done # commit marker — written LAST (atomicity)

Fault-tolerance properties:
  * atomic: data written to step_X.tmp/, fsync'd, renamed, then .done marker;
    a crash mid-save never corrupts the latest valid checkpoint;
  * self-validating: manifest carries per-file crc32; restore verifies;
  * keep-last-k garbage collection;
  * elastic restore: shards are stored with their LOGICAL slice indices, so a
    restore onto a different mesh/device-count re-slices per the new sharding
    (ZeRO-style resharding on load);
  * async: save() can run in a background thread (snapshot taken on host
    first), overlapping serialization with the next train steps.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import uuid
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_SAVE_LOCK = threading.Lock()   # serializes concurrent saves (async + final)


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
        out.append(("/".join(parts), leaf))
    return out


def leaf_paths(tree) -> list[tuple[str, Any]]:
    """Public form of the flattener: (name, leaf) pairs with "/"-joined
    pytree paths — the naming scheme every checkpoint in this layout uses.
    A dict whose keys already contain "/" flattens to the same names, so a
    nested snapshot and its flat (name -> array) load round-trip."""
    return _leaf_paths(tree)


def _safe(name: str) -> str:
    return name.replace("/", "__")


def save(tree, directory: str | Path, step: int, keep: int = 3,
         blocking: bool = True) -> Path:
    """Snapshot the pytree to host memory, then write atomically."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    # host snapshot (device -> host) happens synchronously; IO may be async
    snap = [(name, np.asarray(leaf)) for name, leaf in _leaf_paths(tree)]

    def _write():
        with _SAVE_LOCK:
            final = directory / f"step_{step:08d}"
            if (directory / f"step_{step:08d}.done").exists():
                return  # another writer already committed this step
            tmp = directory / f".tmp_{step:08d}_{os.getpid()}_{uuid.uuid4().hex[:8]}"
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": {}}
            for name, arr in snap:
                fname = f"{_safe(name)}.npy"
                np.save(tmp / fname, arr)
                manifest["leaves"][name] = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32((tmp / fname).read_bytes()),
                }
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            (directory / f"step_{step:08d}.done").write_text("ok")
            _gc(directory, keep)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t  # caller may join
    return directory / f"step_{step:08d}"


def _gc(directory: Path, keep: int):
    done = sorted(directory.glob("step_*.done"))
    for marker in done[:-keep]:
        step_dir = directory / marker.stem
        if step_dir.exists():
            shutil.rmtree(step_dir)
        marker.unlink()


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    done = sorted(directory.glob("step_*.done"))
    if not done:
        return None
    return int(done[-1].stem.split("_")[1])


def restore(tree_like, directory: str | Path, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of `tree_like` (shapes/dtypes from the
    checkpoint).  With `shardings` given, each leaf is device_put with its
    (possibly different-mesh) sharding — elastic re-sharding on load."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = directory / f"step_{step:08d}"
    manifest = json.loads((cdir / "manifest.json").read_text())

    leaves = {}
    for name, meta in manifest["leaves"].items():
        raw = (cdir / meta["file"]).read_bytes()
        if verify and zlib.crc32(raw) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {name} in {cdir}")
        leaves[name] = np.load(cdir / meta["file"])

    shard_list = None if shardings is None else _leaf_paths(shardings)
    out = []
    for i, (name, _) in enumerate(_leaf_paths(tree_like)):
        if name not in leaves:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = leaves[name]
        if shard_list is not None:
            arr = jax.device_put(arr, shard_list[i][1])
        out.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out), step


# ---------------------------------------------------------------------------
# TD-VMM calibration state (site-keyed readout windows)
# ---------------------------------------------------------------------------
# CalibrationState is a plain pytree (site name -> scalar or (E,) window), so
# it rides the same atomic/self-validating machinery as params/optimizer
# state — these wrappers just pin the conventional sub-directory so serving
# restarts find the windows next to the weights.
_CALIB_SUBDIR = "calibration"


def save_calibration(calib, directory: str | Path, step: int = 0,
                     keep: int = 3, blocking: bool = True) -> Path:
    """Persist a ``core.calibration.CalibrationState`` under
    ``<directory>/calibration/step_XXXXXXXX`` (atomic, checksummed)."""
    return save(calib, Path(directory) / _CALIB_SUBDIR, step, keep=keep,
                blocking=blocking)


def restore_calibration(calib_like, directory: str | Path,
                        step: Optional[int] = None):
    """Restore a CalibrationState saved by ``save_calibration``.

    ``calib_like`` supplies the pytree structure (site names); use the state
    returned by ``models.model.calibrate`` on the same model config."""
    return restore(calib_like, Path(directory) / _CALIB_SUBDIR, step=step)


def latest_calibration_step(directory: str | Path) -> Optional[int]:
    return latest_step(Path(directory) / _CALIB_SUBDIR)


# ---------------------------------------------------------------------------
# Serving-engine snapshots (preemption-safe full in-flight state)
# ---------------------------------------------------------------------------
# ``Engine.snapshot()`` emits one pytree — paged KV pools, runtime windows,
# and a JSON-as-uint8 "meta" leaf carrying every host-side structure
# (scheduler queue, slots, block tables, page free-list, records, counters).
# It rides the same atomic/checksummed machinery; restore is structure-free
# (``load_flat``) because the engine rebuilds its own pytree from the names.
_ENGINE_SUBDIR = "engine"


def save_engine_snapshot(snap, directory: str | Path, step: int,
                         keep: int = 3, blocking: bool = True) -> Path:
    """Persist an ``Engine.snapshot()`` pytree under
    ``<directory>/engine/step_XXXXXXXX`` (atomic, checksummed)."""
    return save(snap, Path(directory) / _ENGINE_SUBDIR, step, keep=keep,
                blocking=blocking)


def load_flat(directory: str | Path, step: Optional[int] = None,
              verify: bool = True) -> tuple[dict, int]:
    """Load a checkpoint as a flat ``{leaf name: np.ndarray}`` dict — no
    template pytree needed.  Names are the "/"-joined paths ``leaf_paths``
    produced at save time; the caller reassembles its own structure
    (``Engine.restore`` consumes this directly)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = directory / f"step_{step:08d}"
    manifest = json.loads((cdir / "manifest.json").read_text())
    leaves = {}
    for name, meta in manifest["leaves"].items():
        raw = (cdir / meta["file"]).read_bytes()
        if verify and zlib.crc32(raw) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {name} in {cdir}")
        leaves[name] = np.load(cdir / meta["file"])
    return leaves, step


def load_engine_snapshot(directory: str | Path, step: Optional[int] = None,
                         verify: bool = True) -> tuple[dict, int]:
    """Flat-load the latest (or given-step) engine snapshot saved by
    ``save_engine_snapshot``."""
    return load_flat(Path(directory) / _ENGINE_SUBDIR, step=step,
                     verify=verify)


def latest_engine_snapshot_step(directory: str | Path) -> Optional[int]:
    return latest_step(Path(directory) / _ENGINE_SUBDIR)
