"""Pure-jnp oracle for the threshold-crossing kernel.

Solves, per output column n and batch row b,

    Q(t) = sum_k I[k, n] * max(t - t_on[b, k], 0)  =  K_charge

i.e. the latch firing time of the charge-integration column (paper Eq. 4).
Q is monotone piecewise-linear, so the exact answer comes from the sort-based
event sweep (same math as core.tdcore.crossing_time, vectorized over (B, N)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def crossing_ref(t_on: jax.Array, currents: jax.Array, k_charge: float) -> jax.Array:
    """t_on: (B, K); currents: (K, N); returns (B, N) crossing times."""

    def one(t_row):
        order = jnp.argsort(t_row)
        ts = t_row[order]                       # (K,)
        cs = currents[order, :]                 # (K, N)
        slope = jnp.cumsum(cs, axis=0)          # (K, N)
        moment = jnp.cumsum(cs * ts[:, None], axis=0)
        q_at_break = slope * ts[:, None] - moment

        def col(qb, sl, mo):
            idx = jnp.clip(
                jnp.searchsorted(qb, k_charge, side="right") - 1, 0, ts.shape[0] - 1)
            return (k_charge + mo[idx]) / jnp.maximum(sl[idx], 1e-30)

        return jax.vmap(col, in_axes=(1, 1, 1))(q_at_break, slope, moment)

    return jax.vmap(one)(t_on)
