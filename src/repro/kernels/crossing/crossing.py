"""Pallas TPU kernel: latch threshold-crossing solver (bisection in VMEM).

The analog circuit finds the crossing time for free (the S-R latch fires when
V_C crosses V_TH).  Digitally, each column's charge Q(t) is monotone
piecewise-linear, so `iters` bisection steps resolve t* to T / 2^iters — at
p-bit precision, iters = p + 2 suffices.

TPU blocking rationale (the hardware-codesign point): the (K x bn) current
tile and the (K,) onset vector are loaded into VMEM ONCE and reused for every
bisection iteration — arithmetic intensity scales with `iters` instead of
being memory-bound per iteration.  A naive XLA lowering of the bisection loop
would re-stream the currents from HBM each iteration (K*N*4 bytes x iters);
this kernel streams them exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(t_ref, i_ref, o_ref, *, iters: int, k_charge: float,
            t_lo: float, t_hi: float):
    t_on = t_ref[...]            # (1, K)   this batch row's onsets
    cur = i_ref[...]             # (K, bn)  current tile, VMEM-resident
    bn = cur.shape[1]

    lo = jnp.full((1, bn), t_lo, jnp.float32)
    hi = jnp.full((1, bn), t_hi, jnp.float32)

    def body(_, lo_hi):
        lo, hi = lo_hi
        mid = 0.5 * (lo + hi)                             # (1, bn)
        # Q(mid) per column: sum_k I[k,n] * relu(mid[n] - t_on[k])
        dt = jnp.maximum(mid - t_on.T, 0.0)               # (K, bn)
        q = jnp.sum(cur * dt, axis=0, keepdims=True)      # (1, bn)
        too_low = q < k_charge
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    o_ref[...] = 0.5 * (lo + hi)


@functools.partial(jax.jit, static_argnames=("iters", "k_charge", "t_lo",
                                              "t_hi", "bn", "interpret"))
def crossing_kernel(
    t_on: jax.Array,        # (B, K) onset times
    currents: jax.Array,    # (K, N)
    k_charge: float,
    t_lo: float = 0.0,
    t_hi: float = 1.0,
    iters: int = 24,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, k = t_on.shape
    k2, n = currents.shape
    assert k == k2
    bn = min(bn, n)
    assert n % bn == 0

    return pl.pallas_call(
        functools.partial(_kernel, iters=iters, k_charge=float(k_charge),
                          t_lo=float(t_lo), t_hi=float(t_hi)),
        grid=(b, n // bn),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(t_on.astype(jnp.float32), currents.astype(jnp.float32))
