"""jit'd wrapper: full TD-VMM column readout via the crossing kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.crossing.crossing import crossing_kernel
from repro.kernels.crossing.ref import crossing_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k_charge", "t_window", "iters",
                                              "interpret"))
def crossing_times(
    t_on: jax.Array,        # (B, K)
    currents: jax.Array,    # (K, N)
    k_charge: float,
    t_window: float,
    iters: int = 24,
    interpret: bool | None = None,
) -> jax.Array:
    """Latch firing times in [0, 2T] for every (batch row, output column)."""
    if interpret is None:
        interpret = not _on_tpu()
    return crossing_kernel(
        t_on, currents, k_charge,
        t_lo=0.0, t_hi=2.0 * t_window, iters=iters,
        interpret=bool(interpret))


def crossing_times_exact(t_on, currents, k_charge):
    """Sort-based exact solve (the oracle), exposed for convenience."""
    return crossing_ref(t_on, currents, k_charge)
