"""Pure-jnp oracle for the TD-VMM quantized matmul kernel.

Semantics (integer-valued charge accumulation of the four-quadrant TD-VMM),
mirroring ops.tdvmm_matmul stage for stage:

    z[m, n] = (sum_k xc[m, k] * wc[k, n]) * gain          charge + latch
    z       = readout(z, out_bits)                        p-bit ADC (§4.2)
    y[m, n] = z[m, n] * x_scale[m] * w_scale[n]           digital rescale

where xc are signed p-bit time codes (integer-valued floats, the differential
(+/-) wire pair folded into a sign) and wc are signed weight codes.  The
readout quantizes the latch-normalized accumulation over the calibrated
output window — before the per-row/per-channel digital rescale — exactly as
the shared-counter ADC samples the crossing time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tdvmm_matmul_ref(
    x_codes: jax.Array,      # (M, K) float32, integer-valued in [-L, L]
    w_codes: jax.Array,      # (K, N) float32, integer-valued in [-Lw, Lw]
    x_scale: jax.Array,      # (M,) or (M, 1)
    w_scale: jax.Array,      # (N,)
    gain: float,
    out_bits: int | None = None,
    out_scale: float | None = None,
) -> jax.Array:
    acc = jnp.dot(x_codes, w_codes, preferred_element_type=jnp.float32)
    z = acc * gain
    if out_bits is not None:
        # Deliberately inlined (NOT quant.readout): the oracle must stay
        # independent of the implementation it validates.
        levels = (1 << out_bits) - 1
        s = out_scale if out_scale is not None else jnp.maximum(
            jnp.max(jnp.abs(z)), 1e-9)
        z = jnp.round(jnp.clip(z / s, -1.0, 1.0) * levels) / levels * s
    return z * x_scale.reshape(-1, 1) * w_scale.reshape(1, -1)
