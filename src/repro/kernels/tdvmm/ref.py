"""Pure-jnp oracle for the TD-VMM quantized matmul kernel.

Semantics (integer-valued charge accumulation of the four-quadrant TD-VMM):

    y[m, n] = (sum_k xc[m, k] * wc[k, n]) * x_scale[m] * w_scale[n] * gain

where xc are signed p-bit time codes (integer-valued floats, the differential
(+/-) wire pair folded into a sign) and wc are signed weight codes.  The
optional output readout quantizes y to p bits over the calibrated output
window (the shared-counter ADC of section 4.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tdvmm_matmul_ref(
    x_codes: jax.Array,      # (M, K) float32, integer-valued in [-L, L]
    w_codes: jax.Array,      # (K, N) float32, integer-valued in [-Lw, Lw]
    x_scale: jax.Array,      # (M,) or (M, 1)
    w_scale: jax.Array,      # (N,)
    gain: float,
    out_bits: int | None = None,
) -> jax.Array:
    acc = jnp.dot(x_codes, w_codes, preferred_element_type=jnp.float32)
    y = acc * x_scale.reshape(-1, 1) * w_scale.reshape(1, -1) * gain
    if out_bits is not None:
        levels = (1 << out_bits) - 1
        s = jnp.maximum(jnp.max(jnp.abs(y)), 1e-9)
        y = jnp.round(y / s * levels) / levels * s
    return y
