"""Pure-jnp oracle for the TD-VMM quantized matmul kernel.

Semantics (integer-valued charge accumulation of the four-quadrant TD-VMM),
mirroring ops.tdvmm_matmul stage for stage:

    z[m, n] = (sum_k xc[m, k] * wc[k, n]) * gain          charge + latch
    z       = readout(z, out_bits)                        p-bit ADC (§4.2)
    y[m, n] = z[m, n] * x_scale[m] * w_scale[n]           digital rescale

where xc are signed p-bit time codes (the differential (+/-) wire pair folded
into a sign) and wc are signed weight codes.  Codes may arrive as
integer-valued floats or as int8 (the storage format of the int path); the
oracle accumulates in int32 for integer inputs — the same exact arithmetic
the MXU int8 path performs — and in f32 otherwise.  The readout quantizes
the latch-normalized accumulation over the calibrated output window — before
the per-row/per-channel digital rescale — exactly as the shared-counter ADC
samples the crossing time.

Batched (E, M, K) x (E, K, N) expert stacks are supported with per-expert
scales (E, M) / (E, N); a data-calibrated readout window (out_scale=None) is
taken per expert tile, since each expert is its own analog array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tdvmm_matmul_ref(
    x_codes: jax.Array,      # (M, K) or (E, M, K), int8 or integer-valued f32
    w_codes: jax.Array,      # (K, N) or (E, K, N)
    x_scale: jax.Array,      # (M,), (M, 1) or (E, M)
    w_scale: jax.Array,      # (N,) or (E, N)
    gain: float,
    out_bits: int | None = None,
    out_scale: float | None = None,
) -> jax.Array:
    acc_dtype = jnp.int32 if jnp.issubdtype(x_codes.dtype, jnp.integer) \
        else jnp.float32
    if x_codes.ndim == 2:
        acc = jnp.dot(x_codes, w_codes, preferred_element_type=acc_dtype)
    else:
        acc = jnp.einsum("emk,ekn->emn", x_codes, w_codes,
                         preferred_element_type=acc_dtype)
    z = acc.astype(jnp.float32) * gain
    if out_bits is not None:
        # Deliberately inlined (NOT quant.readout): the oracle must stay
        # independent of the implementation it validates.
        levels = (1 << out_bits) - 1
        s = out_scale if out_scale is not None else jnp.maximum(
            jnp.max(jnp.abs(z), axis=(-2, -1), keepdims=True), 1e-9)
        z = jnp.round(jnp.clip(z / s, -1.0, 1.0) * levels) / levels * s
    xs = x_scale.reshape(z.shape[:-2] + (z.shape[-2], 1))
    ws = w_scale.reshape(z.shape[:-2] + (1, z.shape[-1]))
    return (z * xs) * ws
