"""Pallas TPU kernel: TD-VMM quantized matmul (charge-accumulation core).

The analog array integrates charge Q[n] = sum_k I[k,n] * on_time[k] — on TPU
that inner product is the MXU's job.  Blocking: (bm x bk) time-code tiles and
(bk x bn) current-code tiles stream HBM->VMEM; a (bm x bn) accumulator lives
in VMEM scratch across the K grid walk (the K axis is the
'arbitrary'/sequential grid dim), so partial charges never round-trip to HBM
— the digital analogue of the capacitor accumulating charge on-node.

Code dtypes (the paper's signal is a p-bit integer code, Eq. 1-3):

  int8   codes with |code| <= 127 (p <= 7 incl. the default p = 6) stream at
         1 byte/code — a quarter of the f32 bytes — and take the MXU's
         int8 x int8 -> int32 path, so charge accumulation is *exact* for any
         K with |acc| < 2^31 (no 2^24 f32 envelope).
  f32    integer-valued float codes (p = 8, or noise-perturbed analog
         currents); exact while |acc| < 2^24.

Fused epilogue: the final K step finishes the (bm, bn) tile *in VMEM* —
latch gain, optional p-bit shared-counter readout (Eq. 3) over a fixed
calibrated window, and the per-row x per-channel digital rescale — so the
output hits HBM exactly once, already in model units.  (Data-calibrated
readout needs a global max|z| and stays an unfused jnp epilogue; see
ops.tdvmm_matmul.)

Batched expert grid: a leading E dimension maps (E, M, K) x (E, K, N) MoE
expert stacks onto grid axis 0 — one analog tile per expert — with per-expert
scale vectors riding along as (1, bm, 1) / (1, 1, bn) blocks.

Shared-input grouped grid: (1, M, K) x (G, K, N) runs the *same* time-code
matrix against G stacked weight tiles — the paper's shared-DAC dataflow (one
input encode amortized across every output column of every tile).  The x (and
x_scale) block index maps pin grid axis 0 to batch 0, so HBM holds exactly
one copy of the codes; each group member still owns its per-channel w_scale
and per-tile readout window via the (G, ...) operands.

MXU alignment: block dims default to multiples of 128; the minor-most tile
minimums are dtype-dependent (f32 sublane 8, int8 sublane 32, lane 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

# Default MXU-aligned block shape; pad_to_blocks() aligns arbitrary model
# shapes to these so the divisibility asserts below never constrain callers.
BM, BK, BN = 128, 512, 128
# Mosaic tiling: sublane (second-to-last dim) minimum is dtype-dependent;
# lane (last dim) is always 128.
LANE = 128
_MIN_SUBLANE = {"float32": 8, "bfloat16": 16, "int8": 32}


def min_sublane(dtype) -> int:
    return _MIN_SUBLANE.get(jnp.dtype(dtype).name, 8)


# ---------------------------------------------------------------------------
# Block-size autotune table
# ---------------------------------------------------------------------------
# Keyed on the *unpadded* (M, K, N, dtype-name) of the codes matmul; values
# are (bm, bk, bn).  Entries come from interpret-mode sweeps + MXU sizing
# arithmetic (int8 tiles carry 4x the codes per VMEM byte, so the K block
# doubles at equal VMEM budget).  Misses fall back to the dtype heuristic.
AUTOTUNE_TABLE: dict[tuple[int, int, int, str], tuple[int, int, int]] = {
    # model-emitted shapes from benchmarks/bench_kernels.py
    (512, 1024, 4096, "float32"): (128, 512, 256),
    (512, 1024, 4096, "int8"): (128, 1024, 256),
    (256, 896, 896, "float32"): (128, 448, 128),
    (256, 896, 896, "int8"): (128, 896, 128),
    (512, 2048, 512, "float32"): (128, 512, 128),
    (512, 2048, 512, "int8"): (128, 1024, 128),
    # the perceptron case-study shape
    (8, 128, 64, "float32"): (8, 128, 64),
    (8, 128, 64, "int8"): (32, 128, 64),
}


def autotune_blocks(m: int, k: int, n: int, dtype=jnp.float32) -> tuple[int, int, int]:
    """(bm, bk, bn) for a codes matmul: table hit or dtype heuristic.

    The heuristic doubles the K block for int8 (same VMEM bytes as the f32
    default, half the HBM refills).  Callers must pad with the *same* blocks
    they launch with (``pad_to_blocks`` takes them), so any return value is
    launchable.
    """
    name = jnp.dtype(dtype).name
    hit = AUTOTUNE_TABLE.get((m, k, n, name))
    if hit is not None:
        return hit
    if name == "int8":
        return (BM, 2 * BK, BN)
    return (BM, BK, BN)


def padded_size(size: int, block: int, tile: int) -> int:
    """Smallest n >= max(size, 1) with n % tile == 0 and n % min(block, n) == 0.

    Rounding to ``tile`` first keeps sub-block dims Mosaic-lowerable on real
    TPUs (block sizes are tile multiples, so block-rounding preserves it).
    Empty dims pad up to one tile — all-zero codes, zero charge — so the
    sliced-back result is the correct empty (or zero) array instead of a
    zero-size grid.
    """
    n = ((max(size, 1) + tile - 1) // tile) * tile
    if n >= block:
        n = ((n + block - 1) // block) * block
    return n


def pad_to_blocks(
    x_codes: jax.Array,      # (..., M, K)
    w_codes: jax.Array,      # (..., K, N)
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
) -> tuple[jax.Array, jax.Array]:
    """Zero-pad code matrices up to block multiples (and MXU tile multiples).

    A zero time code contributes zero charge (the source never turns on), so
    padding is exact: slice the kernel output back to [:M, :N] and the result
    is identical to the unpadded product.  Tile minimums are dtype-aware
    (int8 sublane is 32 vs f32's 8); leading batch (expert) dims pass through
    unpadded — the E grid axis has no tiling constraint.
    """
    m, k = x_codes.shape[-2], x_codes.shape[-1]
    n = w_codes.shape[-1]
    mp = padded_size(m, bm, min_sublane(x_codes.dtype))
    # K is x's lane (128) and w's sublane (<= 32): LANE covers both.
    kp = padded_size(k, bk, LANE)
    np_ = padded_size(n, bn, LANE)
    zero = ((0, 0),) * (x_codes.ndim - 2)
    if (mp, kp) != (m, k):
        x_codes = jnp.pad(x_codes, zero + ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w_codes = jnp.pad(w_codes, zero + ((0, kp - k), (0, np_ - n)))
    return x_codes, w_codes


# ---------------------------------------------------------------------------
# Kernel body (shared by the plain and fused entry points)
# ---------------------------------------------------------------------------
def _kernel(*refs, nk: int, acc_dtype, fuse: bool, gain: float,
            out_bits: int | None, has_window: bool = False):
    os_ref = ob_ref = None
    if fuse and has_window:
        x_ref, w_ref, xs_ref, ws_ref, os_ref, ob_ref, o_ref, acc_ref = refs
    elif fuse:
        x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=acc_dtype)

    @pl.when(pl.program_id(3) == nk - 1)
    def _done():
        acc = acc_ref[...]
        if not fuse:
            o_ref[0] = acc
            return
        # Fused epilogue — the (bm, bn) tile is finished in VMEM and written
        # to HBM exactly once.  The expression mirrors ops._epilogue term for
        # term so the fused and unfused paths stay bit-for-bit identical.
        z = acc.astype(jnp.float32) * gain
        ws_row = ws_ref[0]
        if out_bits is not None:
            # The readout window (and its precomputed back-scale s/levels)
            # ride along as (1, 1, 1) blocks of (E, 1, 1) operand vectors —
            # grid axis 0 is the expert axis, so each tile reads its own
            # analog tile's calibrated window (a scalar window is broadcast
            # to all E tiles by the caller).  Runtime values + the
            # constant-free post-round chain ``(q * xs) * (ws * back)``
            # mirror ops._epilogue term for term, so fused, unfused, and
            # per-call data-calibrated windows stay bit-for-bit identical
            # (baked literals would invite XLA strength reduction /
            # constant reassociation on one side only).
            levels = float((1 << out_bits) - 1)
            inv = jnp.float32(1.0) / os_ref[0, 0, 0]
            z = jnp.round(jnp.clip(z * inv, -1.0, 1.0) * levels)
            ws_row = ws_row * ob_ref[0, 0, 0]
        o_ref[0] = (z * xs_ref[0]) * ws_row


def _grid_call(e, m, k, n, bm, bk, bn, *, acc_dtype, out_dtype, fuse,
               gain, out_bits, interpret, shared_x=False):
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    nk = k // bk
    has_window = fuse and out_bits is not None
    # Shared-input grouped launch: x (and x_scale) carry a single batch entry
    # that every grid-axis-0 tile reads — one code copy in HBM for G tiles.
    xb = (lambda b: 0) if shared_x else (lambda b: b)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda b, i, j, s: (xb(b), i, s)),
        pl.BlockSpec((1, bk, bn), lambda b, i, j, s: (b, s, j)),
    ]
    if fuse:
        in_specs += [
            pl.BlockSpec((1, bm, 1), lambda b, i, j, s: (xb(b), i, 0)),
            pl.BlockSpec((1, 1, bn), lambda b, i, j, s: (b, 0, j)),
        ]
    if has_window:
        # (E, 1, 1) per-expert window + back-scale vectors, one (1, 1, 1)
        # block per tile.
        in_specs += [pl.BlockSpec((1, 1, 1), lambda b, i, j, s: (b, 0, 0)),
                     pl.BlockSpec((1, 1, 1), lambda b, i, j, s: (b, 0, 0))]
    return pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, acc_dtype=acc_dtype, fuse=fuse, gain=gain,
            out_bits=out_bits, has_window=has_window),
        grid=(e, m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, s: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )


def acc_dtype_for(code_dtype) -> jnp.dtype:
    """Accumulator dtype for a code dtype: int codes accumulate on the MXU
    int8 path (exact int32); float codes in f32.  Single source of truth for
    both the Pallas scratch accumulator and the jnp einsum accumulator
    (ops.py) — they must agree or backend parity breaks."""
    if jnp.issubdtype(jnp.dtype(code_dtype), jnp.integer):
        return jnp.dtype(jnp.int32)
    return jnp.dtype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tdvmm_matmul_kernel(
    x_codes: jax.Array,      # (M, K) or (E, M, K) signed time codes
    w_codes: jax.Array,      # (K, N) or (E, K, N) signed weight codes
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """Raw charge accumulation: int8 codes -> int32 acc, f32 codes -> f32 acc.

    2-D inputs run as a single-expert (E=1) batch; 3-D inputs map the leading
    expert dim onto grid axis 0.  A (1, M, K) x (G, K, N) pair runs the
    shared-input grouped grid: one code copy feeds all G tiles.
    """
    squeeze = x_codes.ndim == 2 and w_codes.ndim == 2
    if x_codes.ndim == 2:
        x_codes = x_codes[None]
    if w_codes.ndim == 2:
        w_codes = w_codes[None]
    ex, m, k = x_codes.shape
    e, k2, n = w_codes.shape
    assert (ex == e or ex == 1) and k == k2, (x_codes.shape, w_codes.shape)
    acc_dtype = acc_dtype_for(x_codes.dtype)
    out = _grid_call(
        e, m, k, n, bm, bk, bn, acc_dtype=acc_dtype, out_dtype=acc_dtype,
        fuse=False, gain=1.0, out_bits=None,
        interpret=interpret, shared_x=ex == 1 and e > 1)(x_codes, w_codes)
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=(
    "gain", "out_bits", "out_scale", "bm", "bk", "bn", "interpret"))
def tdvmm_fused_kernel(
    x_codes: jax.Array,      # (E, M, K) signed time codes (int8 or f32);
    #                          (1, M, K) against (G, K, N) weights = shared-x
    w_codes: jax.Array,      # (E, K, N) signed weight codes
    x_scale: jax.Array,      # (E, M, 1) f32 per-row input scales ((1, M, 1)
    #                          in shared-x mode)
    w_scale: jax.Array,      # (E, 1, N) f32 per-channel weight scales
    gain: float = 1.0,
    out_bits: int | None = None,
    out_scale: float | tuple[float, ...] | None = None,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """Integrate + fused readout epilogue: model-unit f32 (E, M, N) out.

    The latch gain, the optional p-bit readout over the *fixed* window
    ``out_scale`` (a calibration-time capture; a tuple is an (E,)-vector of
    per-expert windows, one per tile on grid axis 0 — data-calibrated
    windows need a global max and use the unfused path), and the per-row x
    per-channel rescale all run on the finished accumulator tile in VMEM;
    each output tile is written to HBM exactly once.
    """
    assert x_codes.ndim == 3, "fused kernel is batched; add an E=1 axis"
    if out_bits is not None and out_scale is None:
        raise ValueError("fused readout needs a fixed out_scale window")
    ex, m, k = x_codes.shape
    e, _, n = w_codes.shape
    assert ex == e or ex == 1, (x_codes.shape, w_codes.shape)
    if isinstance(out_scale, tuple) and len(out_scale) != e:
        raise ValueError(f"per-expert out_scale: {len(out_scale)} windows "
                         f"for E={e} tiles")
    operands = [x_codes, w_codes, x_scale, w_scale]
    if out_bits is not None:
        # The window (and its back-scale window/levels) enter the kernel as
        # runtime (E, 1, 1) operands, never baked literals: constant scales
        # invite XLA strength-reduction / constant reassociation that would
        # break bitwise parity with the unfused and per-call paths (see
        # ops._epilogue).
        if isinstance(out_scale, tuple):
            win = jnp.asarray(out_scale, jnp.float32).reshape(e, 1, 1)
        else:
            win = jnp.full((e, 1, 1), out_scale, jnp.float32)
        levels = float((1 << out_bits) - 1)
        operands += [win, win * (np.float32(1.0) / np.float32(levels))]
    return _grid_call(
        e, m, k, n, bm, bk, bn, acc_dtype=acc_dtype_for(x_codes.dtype),
        out_dtype=jnp.float32, fuse=True, gain=gain, out_bits=out_bits,
        interpret=interpret, shared_x=ex == 1 and e > 1,
    )(*operands)
