"""Pallas TPU kernel: TD-VMM quantized matmul (charge-accumulation core).

The analog array integrates charge Q[n] = sum_k I[k,n] * on_time[k] — on TPU
that inner product is the MXU's job.  Blocking: (bm x bk) time-code tiles and
(bk x bn) current-code tiles stream HBM->VMEM; a (bm x bn) f32 accumulator
lives in VMEM scratch across the K grid walk (the K axis is the
'arbitrary'/sequential grid dim), so partial charges never round-trip to HBM
— the digital analogue of the capacitor accumulating charge on-node.

MXU alignment: all block dims default to multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tdvmm_matmul_kernel(
    x_codes: jax.Array,      # (M, K) f32, integer-valued signed time codes
    w_codes: jax.Array,      # (K, N) f32, integer-valued signed weight codes
    bm: int = 128,
    bk: int = 512,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_codes.shape
    k2, n = w_codes.shape
    assert k == k2
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_codes, w_codes)
