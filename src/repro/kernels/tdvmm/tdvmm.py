"""Pallas TPU kernel: TD-VMM quantized matmul (charge-accumulation core).

The analog array integrates charge Q[n] = sum_k I[k,n] * on_time[k] — on TPU
that inner product is the MXU's job.  Blocking: (bm x bk) time-code tiles and
(bk x bn) current-code tiles stream HBM->VMEM; a (bm x bn) f32 accumulator
lives in VMEM scratch across the K grid walk (the K axis is the
'arbitrary'/sequential grid dim), so partial charges never round-trip to HBM
— the digital analogue of the capacitor accumulating charge on-node.

MXU alignment: all block dims default to multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

# Default MXU-aligned block shape; pad_to_blocks() aligns arbitrary model
# shapes to these so the divisibility asserts below never constrain callers.
BM, BK, BN = 128, 512, 128
# Mosaic f32 tiling: sublane (second-to-last dim) x lane (last dim) minimums.
SUBLANE, LANE = 8, 128


def padded_size(size: int, block: int, tile: int) -> int:
    """Smallest n >= max(size, 1) with n % tile == 0 and n % min(block, n) == 0.

    Rounding to ``tile`` first keeps sub-block dims Mosaic-lowerable on real
    TPUs (block sizes are tile multiples, so block-rounding preserves it).
    Empty dims pad up to one tile — all-zero codes, zero charge — so the
    sliced-back result is the correct empty (or zero) array instead of a
    zero-size grid.
    """
    n = ((max(size, 1) + tile - 1) // tile) * tile
    if n >= block:
        n = ((n + block - 1) // block) * block
    return n


def pad_to_blocks(
    x_codes: jax.Array,      # (M, K)
    w_codes: jax.Array,      # (K, N)
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
) -> tuple[jax.Array, jax.Array]:
    """Zero-pad code matrices up to block multiples (and MXU tile multiples).

    A zero time code contributes zero charge (the source never turns on), so
    padding is exact: slice the kernel output back to [:M, :N] and the result
    is identical to the unpadded product.
    """
    m, k = x_codes.shape
    _, n = w_codes.shape
    mp = padded_size(m, bm, SUBLANE)
    kp = padded_size(k, bk, LANE)
    np_ = padded_size(n, bn, LANE)
    if (mp, kp) != (m, k):
        x_codes = jnp.pad(x_codes, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w_codes = jnp.pad(w_codes, ((0, kp - k), (0, np_ - n)))
    return x_codes, w_codes


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tdvmm_matmul_kernel(
    x_codes: jax.Array,      # (M, K) f32, integer-valued signed time codes
    w_codes: jax.Array,      # (K, N) f32, integer-valued signed weight codes
    bm: int = 128,
    bk: int = 512,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = x_codes.shape
    k2, n = w_codes.shape
    assert k == k2
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_codes, w_codes)
