"""Block-size autotune tables for the TD-VMM kernel (GENERATED FILE).

Regenerate with ``python scripts/autotune_tdvmm.py`` — the script sweeps
(bm, bk, bn) candidates per (M, K, N, dtype) launch shape and rewrites the
table for the platform it ran on, preserving the other platform's entries.
Hand edits survive only until the next script run; tune through the script.

Two tables, selected by ``tdvmm.autotune_platform()``:

  MOSAIC_TABLE     real-TPU block choices: VMEM-budgeted MXU tiles (int8
                   tiles carry 4x the codes per VMEM byte, so K blocks
                   double at equal budget).  Entries come from sizing
                   arithmetic until measured on hardware (ROADMAP).
  INTERPRET_TABLE  CPU interpret-mode choices: interpret wall-clock scales
                   with the *grid step count* (each step is a Python-level
                   block dispatch), so the sweep lands on the largest
                   launchable blocks — the opposite regime from VMEM-bound
                   Mosaic tiling.

Keys are the *unpadded* (M, K, N, dtype-name) of the codes matmul with
dtype-name in {"float32", "int8", "int4"}; int4 keys use the unpacked K.
Values are (bm, bk, bn).  Misses fall back to the per-platform heuristic in
``tdvmm.autotune_blocks`` (and warn once via ``ops.plan_kernel``).
"""

# fmt: off
MOSAIC_TABLE: dict[tuple[int, int, int, str], tuple[int, int, int]] = {
    (8, 128, 64, "float32"): (8, 128, 64),
    (8, 128, 64, "int8"): (32, 128, 64),
    (256, 896, 896, "float32"): (128, 448, 128),
    (256, 896, 896, "int8"): (128, 896, 128),
    (512, 1024, 4096, "float32"): (128, 512, 256),
    (512, 1024, 4096, "int8"): (128, 1024, 256),
    (512, 2048, 512, "float32"): (128, 512, 128),
    (512, 2048, 512, "int8"): (128, 1024, 128),
}

INTERPRET_TABLE: dict[tuple[int, int, int, str], tuple[int, int, int]] = {
    (8, 128, 64, "float32"): (16384, 32768, 32768),
    (8, 128, 64, "int8"): (16384, 32768, 2048),
    (33, 300, 130, "float32"): (16384, 32768, 32768),
    (64, 512, 2432, "int8"): (16384, 32768, 32768),
    (64, 896, 1152, "int8"): (16384, 32768, 2048),
    (256, 896, 896, "float32"): (16384, 32768, 32768),
    (256, 1024, 512, "int8"): (512, 4096, 1024),
    (256, 1024, 4096, "int8"): (16384, 32768, 32768),
    (512, 1024, 1024, "int8"): (16384, 32768, 2048),
    (512, 1024, 2816, "int8"): (16384, 32768, 32768),
    (512, 1024, 3072, "int8"): (16384, 32768, 32768),
    (512, 1024, 4096, "float32"): (16384, 32768, 32768),
    (512, 1024, 4096, "int4"): (16384, 32768, 32768),
    (512, 1024, 4096, "int8"): (16384, 32768, 32768),
    (512, 2048, 512, "float32"): (16384, 32768, 2048),
    (512, 2048, 512, "int4"): (512, 8192, 1024),
    (512, 2048, 512, "int8"): (512, 32768, 2048),
    (512, 2048, 2048, "int8"): (512, 32768, 2048),
    (512, 2048, 6144, "int8"): (512, 4096, 1024),
    (512, 2048, 7168, "int8"): (16384, 32768, 32768),
    (512, 2048, 8192, "int8"): (512, 4096, 1024),
    (512, 2048, 8576, "int8"): (16384, 32768, 32768),
    (512, 2048, 50432, "int8"): (16384, 32768, 32768),
    (512, 2560, 2560, "int8"): (16384, 32768, 32768),
    (512, 2560, 7680, "int8"): (16384, 32768, 32768),
    (512, 2560, 10240, "int8"): (16384, 32768, 32768),
    (512, 2560, 10624, "int8"): (16384, 32768, 32768),
    (512, 2560, 32000, "int8"): (16384, 32768, 32768),
    (512, 2816, 1024, "int8"): (16384, 32768, 32768),
    (512, 4096, 2048, "int8"): (512, 32768, 2048),
    (512, 4096, 4096, "int8"): (16384, 32768, 32768),
    (512, 4096, 6144, "int8"): (16384, 32768, 32768),
    (512, 4096, 14336, "int8"): (16384, 32768, 32768),
    (512, 4096, 32000, "int8"): (16384, 32768, 32768),
    (512, 5120, 2560, "int8"): (16384, 32768, 32768),
    (512, 5120, 5120, "int8"): (16384, 32768, 32768),
    (512, 5120, 7168, "int8"): (16384, 32768, 32768),
    (512, 5120, 13824, "int8"): (16384, 32768, 32768),
    (512, 5120, 152064, "int8"): (16384, 32768, 32768),
    (512, 6144, 6144, "int8"): (16384, 32768, 32768),
    (512, 6144, 8192, "int8"): (16384, 32768, 32768),
    (512, 6144, 24576, "int8"): (16384, 32768, 32768),
    (512, 6144, 256000, "int8"): (16384, 32768, 32768),
    (512, 7168, 2048, "int8"): (16384, 32768, 2048),
    (512, 7168, 7168, "int8"): (16384, 32768, 32768),
    (512, 7168, 8960, "int8"): (16384, 32768, 32768),
    (512, 7168, 9216, "int8"): (16384, 32768, 32768),
    (512, 7168, 20480, "int8"): (16384, 32768, 32768),
    (512, 7168, 64000, "int8"): (16384, 32768, 32768),
    (512, 7168, 163840, "int8"): (16384, 32768, 32768),
    (512, 8192, 2048, "int8"): (16384, 32768, 2048),
    (512, 10240, 2560, "int8"): (16384, 32768, 32768),
    (512, 13824, 5120, "int8"): (16384, 32768, 32768),
    (512, 14336, 4096, "int8"): (16384, 32768, 32768),
    (512, 20480, 7168, "int8"): (16384, 32768, 32768),
    (512, 24576, 6144, "int8"): (16384, 32768, 32768),
}
# fmt: on
