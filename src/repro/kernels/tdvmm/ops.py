"""jit'd public wrapper around the TD-VMM matmul kernel (+ scales epilogue)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tdvmm.tdvmm import tdvmm_matmul_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("gain", "out_bits", "interpret"))
def tdvmm_matmul(
    x_codes: jax.Array,
    w_codes: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
    gain: float = 1.0,
    out_bits: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantized four-quadrant TD-VMM: codes matmul + scale epilogue + optional
    p-bit readout.  Uses the Pallas kernel on TPU (or interpret mode when
    requested); falls back to jnp.dot elsewhere — numerics are identical."""
    if interpret is None:
        interpret = not _on_tpu()
    if interpret or _on_tpu():
        acc = tdvmm_matmul_kernel(
            x_codes.astype(jnp.float32), w_codes.astype(jnp.float32),
            interpret=bool(interpret))
    else:  # pragma: no cover
        acc = jnp.dot(x_codes, w_codes, preferred_element_type=jnp.float32)
    y = acc * x_scale.reshape(-1, 1) * w_scale.reshape(1, -1) * gain
    if out_bits is not None:
        levels = (1 << out_bits) - 1
        s = jnp.maximum(jnp.max(jnp.abs(y)), 1e-9)
        y = jnp.round(y / s * levels) / levels * s
    return y
