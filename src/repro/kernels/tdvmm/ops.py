"""jit'd public wrapper around the TD-VMM matmul kernel (+ scales epilogue).

This is the *integrate + readout* tail of the code-and-scale pipeline
(core/quant.py): integer code matrices in, model-unit outputs out.

    acc = x_codes @ w_codes          charge accumulation (Eq. 1)
    z   = acc * gain                 latch normalization (crossing time)
    z   = readout(z, out_bits)       p-bit shared-counter ADC (Eq. 3, §4.2)
    y   = z * x_scale[:, None] * w_scale[None, :]   digital rescale

The readout happens on the latch-normalized accumulation — the ADC samples
the crossing *time*, before any per-row/per-channel digital rescale — so the
epilogue carries per-row input scales and per-channel weight scales through
without changing what the hardware quantizes.

Code dtypes (``code_dtype``): ``"int8"`` stores the codes as int8 in HBM
(quarter the f32 bytes) and accumulates exactly in int32 on both backends —
the MXU int8 path on TPU, an s8 x s8 -> s32 dot under XLA elsewhere — so the
backends are bit-for-bit identical for *any* K with |acc| < 2^31, with no
2^24 f32 envelope.  ``"f32"`` is the legacy float-code path (8-bit codes,
noise-perturbed analog currents); exact only while |acc| < 2^24.  ``"auto"``
follows the input arrays' dtypes.

Epilogue placement: with a *fixed* readout window (``out_scale`` given, the
serving-path calibration cache) or no readout at all, the Pallas backend runs
the whole epilogue inside the kernel's final K step (tdvmm_fused_kernel) —
each output tile is written to HBM exactly once, already in model units.  A
data-calibrated window (``out_scale=None`` with ``out_bits``) needs a global
max|z| and falls back to the unfused jnp epilogue after the codes matmul.
Both epilogues evaluate the same expression term for term, so fused and
unfused results are bit-for-bit identical.

Batching: 3-D inputs (E, M, K) x (E, K, N) map the expert dim onto the
kernel's batched grid axis (scales (E, M) / (E, N)); 2-D inputs run as E=1.
A 2-D x against a 3-D (G, K, N) weight bank runs the **shared-input grouped**
grid — the paper's shared-DAC dataflow: one (M, K) code matrix (and one
(M,) scale vector) feeds all G weight tiles in a single launch, returning
(G, M, N).  Per-group w_scale/out_scale ride the same (G, ...) operands as
per-expert batching.

Gradients flow through a shared custom VJP (plain matmul cotangents on the
STE-wrapped codes, identity through the readout quantizer), so every backend
x dtype x fusion combination is trainable and backend-independent in the
backward pass.  Pass int arrays directly only on no-grad (serving) paths;
the QAT path feeds the f32 STE view and lets the forward cast to int8.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tdvmm.tdvmm import (
    acc_dtype_for, autotune_blocks, pad_to_blocks, tdvmm_fused_kernel,
    tdvmm_matmul_kernel)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """'auto' | 'jnp' | 'pallas' -> concrete integrate implementation.

    Shape-aware form: ``plan_kernel`` additionally consults the block-size
    autotune table (tdvmm.AUTOTUNE_TABLE) keyed on (M, K, N, dtype).
    """
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown TD-VMM backend {backend!r}")
    return backend


class KernelPlan(NamedTuple):
    """Resolved backend + autotuned block sizes for one codes matmul."""
    backend: str
    bm: int
    bk: int
    bn: int

    @property
    def blocks(self) -> tuple[int, int, int]:
        return (self.bm, self.bk, self.bn)


def plan_kernel(backend: str, m: int, k: int, n: int,
                code_dtype: str = "f32") -> KernelPlan:
    """resolve_backend + the (M, K, N, dtype)-keyed block autotune table."""
    dt = jnp.int8 if code_dtype == "int8" else jnp.float32
    bm, bk, bn = autotune_blocks(m, k, n, dt)
    return KernelPlan(resolve_backend(backend), bm, bk, bn)


# ---------------------------------------------------------------------------
# Epilogue (unfused form; the fused kernel mirrors this term for term)
# ---------------------------------------------------------------------------
def _epilogue(acc, x_scale, w_scale, gain, out_bits, out_scale):
    """gain -> optional p-bit readout -> per-row x per-channel rescale.

    acc: (E, M, N) int32 or f32; x_scale: (E, M); w_scale: (E, N).
    ``out_scale=None`` calibrates the ADC window to max|z| *per expert tile*
    (each expert is its own analog array; E=1 reproduces the global window).
    A tuple ``out_scale`` is an (E,)-vector of fixed per-expert windows —
    one calibrated readout window per expert's analog tile.
    """
    z = acc.astype(jnp.float32) * gain
    ws_row = w_scale[..., None, :]
    if out_bits is not None:
        # Bit-for-bit contract: a calibration-pinned window must reproduce
        # the per-call data-calibrated window it was captured from, and the
        # fused Pallas epilogue must match this unfused form exactly.  Two
        # XLA behaviors break that if window-derived factors enter the graph
        # as literals: division by a constant strength-reduces into a
        # 1-ulp-off reciprocal multiply, and constant factors get
        # reassociated (sunk) through neighboring multiply chains.  So the
        # window is always a *runtime* value (constants pass through an
        # optimization_barrier), divisions are explicit, and the post-round
        # rescale chain ``(q * xs) * (ws * back)`` carries no constants —
        # matching the fused kernel's association term for term.
        s = out_scale
        if s is None:
            s = jax.lax.stop_gradient(jnp.maximum(jnp.max(
                jnp.abs(z), axis=(-2, -1), keepdims=True, initial=0.0), 1e-9))
        elif isinstance(s, tuple):
            s = jnp.asarray(s, jnp.float32).reshape(-1, 1, 1)
        else:
            s = jnp.float32(s)
        s = jax.lax.optimization_barrier(s.astype(jnp.float32))
        levels = float((1 << out_bits) - 1)
        inv = jnp.float32(1.0) / s
        z = jnp.round(jnp.clip(z * inv, -1.0, 1.0) * levels)
        back = jax.lax.optimization_barrier(
            s * (np.float32(1.0) / np.float32(levels)))
        ws_row = ws_row * back
    return (z * x_scale[..., :, None]) * ws_row


def _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                out_scale, backend, interpret, code_dtype, blocks):
    ex, m, k = x_codes.shape
    e, _, n = w_codes.shape
    shared_x = ex == 1 and e > 1
    assert ex == e or shared_x, (x_codes.shape, w_codes.shape)
    if min(e, m, k, n) == 0:
        # Empty expert batch / filtered serving batch / zero-width contraction:
        # zero charge everywhere, and readout(0) * scales == 0 on every path.
        return jnp.zeros((e, m, n), jnp.float32)
    if code_dtype == "int8":
        # Codes are integer-valued with |code| <= 127 by the caller's
        # contract (p <= 7); the cast is exact and XLA fuses it into the
        # producer, so the kernel streams 1-byte codes from HBM.
        xi = x_codes.astype(jnp.int8)
        wi = w_codes.astype(jnp.int8)
    else:
        xi = x_codes.astype(jnp.float32)
        wi = w_codes.astype(jnp.float32)
    if blocks is None:
        blocks = autotune_blocks(m, k, n, xi.dtype)
    bm, bk, bn = blocks

    if backend == "jnp":
        if shared_x:
            # Same contraction (and accumulation order) as the batched form,
            # with the single code matrix broadcast over the G weight tiles.
            acc = jnp.einsum("mk,gkn->gmn", xi[0], wi,
                             preferred_element_type=acc_dtype_for(xi.dtype))
        else:
            acc = jnp.einsum("emk,ekn->emn", xi, wi,
                             preferred_element_type=acc_dtype_for(xi.dtype))
        return _epilogue(acc, x_scale, w_scale, gain, out_bits, out_scale)

    xp, wp = pad_to_blocks(xi, wi, bm, bk, bn)
    mp, np_ = xp.shape[-2], wp.shape[-1]
    if out_bits is None or out_scale is not None:
        # Fixed readout window (or no readout): fully fused epilogue — the
        # (bm, bn) tile leaves VMEM exactly once, already in model units.
        xsp = jnp.pad(x_scale, ((0, 0), (0, mp - m)))[..., :, None]
        wsp = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))[..., None, :]
        y = tdvmm_fused_kernel(
            xp, wp, xsp, wsp, gain=gain, out_bits=out_bits,
            out_scale=out_scale, bm=bm, bk=bk, bn=bn, interpret=interpret)
        return y[:, :m, :n]
    # Data-calibrated readout window: needs a global (per-expert) max over
    # the latch-normalized accumulation — integrate in the kernel, run the
    # epilogue unfused.
    acc = tdvmm_matmul_kernel(
        xp, wp, bm=bm, bk=bk, bn=bn, interpret=interpret)[:, :m, :n]
    return _epilogue(acc, x_scale, w_scale, gain, out_bits, out_scale)


# ---------------------------------------------------------------------------
# Shared custom VJP (all backends / dtypes / fusion modes)
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10))
def _tdvmm_core(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                out_scale, backend, interpret, code_dtype, blocks):
    """Differentiable integrate+epilogue on canonical (E, M, K) shapes."""
    return _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                       out_scale, backend, interpret, code_dtype, blocks)


def _tdvmm_core_fwd(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                    out_scale, backend, interpret, code_dtype, blocks):
    y = _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                    out_scale, backend, interpret, code_dtype, blocks)
    return y, (x_codes, w_codes, x_scale, w_scale, y)


def _tdvmm_core_bwd(gain, out_bits, out_scale, backend, interpret,
                    code_dtype, blocks, res, g):
    x_codes, w_codes, x_scale, w_scale, y = res
    denom = x_scale[..., :, None] * w_scale[..., None, :]
    # Recover the post-readout latch value z = y / (xs * ws); internal
    # callers clamp scales >= 1e-6, so the where() only guards direct API
    # calls with exact-zero scales (whose y, and scale grads, are both 0).
    z = jnp.where(denom == 0.0, 0.0, y / denom)
    # Identity through the readout quantizer (STE) and the latch gain:
    dacc = g * denom * gain
    xf = x_codes.astype(jnp.float32)
    wf = w_codes.astype(jnp.float32)
    if x_codes.shape[0] == 1 and dacc.shape[0] > 1:
        # Shared-input grouped launch: the one x (and x_scale) fed every
        # group tile, so its cotangent sums over the group axis.
        gx = jnp.einsum("gmn,gkn->mk", dacc, wf,
                        preferred_element_type=jnp.float32)[None]
        gw = jnp.einsum("mk,gmn->gkn", xf[0], dacc,
                        preferred_element_type=jnp.float32)
        gxs = jnp.sum(g * z * w_scale[..., None, :], axis=(0, -1))[None]
    else:
        gx = jnp.einsum("emn,ekn->emk", dacc, wf,
                        preferred_element_type=jnp.float32)
        gw = jnp.einsum("emk,emn->ekn", xf, dacc,
                        preferred_element_type=jnp.float32)
        gxs = jnp.sum(g * z * w_scale[..., None, :], axis=-1)
    gws = jnp.sum(g * z * x_scale[..., :, None], axis=-2)
    return gx, gw, gxs, gws


_tdvmm_core.defvjp(_tdvmm_core_fwd, _tdvmm_core_bwd)


def codes_matmul(
    x_codes: jax.Array, w_codes: jax.Array, backend: str,
    interpret: bool | None = None, code_dtype: str = "auto",
) -> jax.Array:
    """Raw (.., M, K) @ (.., K, N) charge accumulation as f32, padded to the
    kernel's block multiples and sliced back.  Differentiable on any backend
    (custom VJP = plain matmul cotangents, matching jnp.dot autodiff).

    A 2-D x against a 3-D (G, K, N) bank runs shared-x grouped: one code
    matrix against G tiles, returning (G, M, N) (no squeeze)."""
    squeeze = x_codes.ndim == 2 and w_codes.ndim == 2
    if x_codes.ndim == 2:
        x_codes = x_codes[None]
    if w_codes.ndim == 2:
        w_codes = w_codes[None]
    m = x_codes.shape[1]
    e, _, n = w_codes.shape
    if interpret is None:
        interpret = not _on_tpu()
    if code_dtype == "auto":
        code_dtype = "int8" if jnp.issubdtype(
            x_codes.dtype, jnp.integer) else "f32"
    ones_m = jnp.ones((x_codes.shape[0], m), jnp.float32)
    ones_n = jnp.ones((e, n), jnp.float32)
    acc = _dispatch(x_codes, w_codes, ones_m, ones_n, 1.0, None, None,
                    resolve_backend(backend), bool(interpret), code_dtype,
                    None)
    return acc[0] if squeeze else acc


def _dispatch(x_codes, w_codes, x_scale, w_scale, gain, out_bits, out_scale,
              backend, interpret, code_dtype, blocks):
    """Route int inputs straight to the impl (no float cotangents exist);
    float inputs go through the shared custom VJP."""
    if jnp.issubdtype(x_codes.dtype, jnp.integer):
        return _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain,
                           out_bits, out_scale, backend, interpret,
                           code_dtype, blocks)
    return _tdvmm_core(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                       out_scale, backend, interpret, code_dtype, blocks)


@functools.partial(
    jax.jit,
    static_argnames=("gain", "out_bits", "out_scale", "backend", "interpret",
                     "code_dtype", "block_sizes"))
def tdvmm_matmul(
    x_codes: jax.Array,      # (M, K) or (E, M, K) signed time codes
    w_codes: jax.Array,      # (K, N) or (E, K, N) signed weight codes
    x_scale: jax.Array,      # (M,) / (E, M) per-row input scales
    w_scale: jax.Array,      # (N,) / (E, N) per-channel weight scales
    gain: float = 1.0,
    out_bits: int | None = None,
    out_scale: float | tuple[float, ...] | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
    code_dtype: str = "auto",
    block_sizes: tuple[int, int, int] | None = None,
) -> jax.Array:
    """Quantized four-quadrant TD-VMM: codes matmul + readout + scale epilogue.

    ``out_scale=None`` calibrates the readout window from the data (§3.1);
    pass the value captured by ``core.layers.calibrate_out_scale`` (or the
    model-wide calibration pass) to skip the per-call max *and* unlock the
    fused-epilogue kernel on the serving path.  A tuple is an (E,)-vector of
    fixed per-expert windows for batched inputs — still static, still fused.
    Arbitrary M/K/N are zero-padded to the kernel's block shape;
    ``block_sizes=None`` consults the autotune table.

    Shared-x grouped: a 2-D (M, K) x against a 3-D (G, K, N) weight bank
    (x_scale (M,), w_scale (G, N)) runs one launch whose G tiles all read
    the same code matrix, returning (G, M, N) un-squeezed.
    """
    backend = resolve_backend(backend)
    if interpret is None:
        interpret = not _on_tpu()
    squeeze = x_codes.ndim == 2 and w_codes.ndim == 2
    if x_codes.ndim == 2:
        x_codes = x_codes[None]
    if w_codes.ndim == 2:
        w_codes = w_codes[None]
    ex, m, _ = x_codes.shape
    e, _, n = w_codes.shape
    if ex not in (e, 1):
        raise ValueError(
            f"batched x/w mismatch: x batch {ex} vs w batch {e} "
            "(shared-x grouped launches carry a single x batch entry)")
    if isinstance(out_scale, tuple) and len(out_scale) != e:
        raise ValueError(
            f"out_scale has {len(out_scale)} per-expert windows for "
            f"E={e} batched tiles")
    if code_dtype == "auto":
        code_dtype = "int8" if jnp.issubdtype(
            x_codes.dtype, jnp.integer) else "f32"
    x_scale = x_scale.reshape(ex, m).astype(jnp.float32)
    w_scale = w_scale.reshape(e, n).astype(jnp.float32)
    y = _dispatch(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                  out_scale, backend, bool(interpret), code_dtype,
                  block_sizes)
    return y[0] if squeeze else y
