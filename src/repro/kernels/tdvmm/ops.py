"""jit'd public wrapper around the TD-VMM matmul kernel (+ scales epilogue).

This is the *integrate + readout* tail of the code-and-scale pipeline
(core/quant.py): integer code matrices in, model-unit outputs out.

    acc = x_codes @ w_codes          charge accumulation (Eq. 1)
    z   = acc * gain                 latch normalization (crossing time)
    z   = readout(z, out_bits)       p-bit shared-counter ADC (Eq. 3, §4.2)
    y   = z * x_scale[:, None] * w_scale[None, :]   digital rescale

The readout happens on the latch-normalized accumulation — the ADC samples
the crossing *time*, before any per-row/per-channel digital rescale — so the
epilogue carries per-row input scales and per-channel weight scales through
without changing what the hardware quantizes.

Backends: ``"pallas"`` runs the Pallas kernel (Mosaic on TPU, interpret mode
elsewhere), ``"jnp"`` runs jnp.dot, ``"auto"`` picks pallas on TPU.  For
integer-valued codes within the f32 exactness envelope (|acc| < 2^24) both
integrate exact integer arithmetic, so they are bit-for-bit identical;
non-integer codes (programming noise) agree only to float tolerance, since
summation order differs.  Gradients flow through a shared custom VJP (plain
matmul cotangents on the STE-wrapped codes), so the Pallas path is trainable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.tdvmm.tdvmm import pad_to_blocks, tdvmm_matmul_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """'auto' | 'jnp' | 'pallas' -> concrete integrate implementation."""
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown TD-VMM backend {backend!r}")
    return backend


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def codes_matmul(
    x_codes: jax.Array, w_codes: jax.Array, backend: str, interpret: bool
) -> jax.Array:
    """(M, K) @ (K, N) integer-valued-f32 charge accumulation, padded to the
    kernel's block multiples and sliced back.  Differentiable on any backend
    (custom VJP = plain matmul cotangents, matching jnp.dot autodiff)."""
    return _codes_matmul_impl(x_codes, w_codes, backend, interpret)


def _codes_matmul_impl(x_codes, w_codes, backend, interpret):
    if backend == "jnp":
        return jnp.dot(x_codes, w_codes, preferred_element_type=jnp.float32)
    m, n = x_codes.shape[0], w_codes.shape[1]
    xp, wp = pad_to_blocks(x_codes, w_codes)
    out = tdvmm_matmul_kernel(xp, wp, interpret=interpret)
    return out[:m, :n]


def _codes_matmul_fwd(x_codes, w_codes, backend, interpret):
    y = _codes_matmul_impl(x_codes, w_codes, backend, interpret)
    return y, (x_codes, w_codes)


def _codes_matmul_bwd(backend, interpret, res, g):
    x_codes, w_codes = res
    gx = jnp.dot(g, w_codes.T, preferred_element_type=jnp.float32)
    gw = jnp.dot(x_codes.T, g, preferred_element_type=jnp.float32)
    return gx, gw


codes_matmul.defvjp(_codes_matmul_fwd, _codes_matmul_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("gain", "out_bits", "out_scale", "backend", "interpret"))
def tdvmm_matmul(
    x_codes: jax.Array,      # (M, K) f32, integer-valued signed time codes
    w_codes: jax.Array,      # (K, N) f32, integer-valued signed weight codes
    x_scale: jax.Array,      # (M,) per-row input scales
    w_scale: jax.Array,      # (N,) per-channel weight scales
    gain: float = 1.0,
    out_bits: int | None = None,
    out_scale: float | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Quantized four-quadrant TD-VMM: codes matmul + readout + scale epilogue.

    ``out_scale=None`` calibrates the readout window from the data (§3.1);
    arbitrary M/K/N are handled by zero-padding to the kernel's block shape.
    """
    backend = resolve_backend(backend)
    if interpret is None:
        interpret = not _on_tpu()
    acc = codes_matmul(
        x_codes.astype(jnp.float32), w_codes.astype(jnp.float32),
        backend, bool(interpret))
    z = acc * gain
    if out_bits is not None:
        z = quant.readout(z, out_bits, scale=out_scale)
    return z * x_scale.reshape(-1, 1) * w_scale.reshape(1, -1)
