"""jit'd public wrapper around the TD-VMM matmul kernel (+ scales epilogue).

This is the *integrate + readout* tail of the code-and-scale pipeline
(core/quant.py): integer code matrices in, model-unit outputs out.

    acc = x_codes @ w_codes          charge accumulation (Eq. 1)
    z   = acc * gain                 latch normalization (crossing time)
    z   = readout(z, out_bits)       p-bit shared-counter ADC (Eq. 3, §4.2)
    y   = z * x_scale[:, None] * w_scale[None, :]   digital rescale

The readout happens on the latch-normalized accumulation — the ADC samples
the crossing *time*, before any per-row/per-channel digital rescale — so the
epilogue carries per-row input scales and per-channel weight scales through
without changing what the hardware quantizes.

Code dtypes (``code_dtype``): ``"int8"`` stores the codes as int8 in HBM
(quarter the f32 bytes) and accumulates exactly in int32 on both backends —
the MXU int8 path on TPU, an s8 x s8 -> s32 dot under XLA elsewhere — so the
backends are bit-for-bit identical for *any* K with |acc| < 2^31, with no
2^24 f32 envelope.  ``"int4"`` (codes with |code| <= 7, p <= 3) additionally
packs two codes per byte for the Pallas stream (``core.quant.pack_int4``,
unpacked in-kernel) — half the int8 bytes, still exact int32 accumulation,
bit-for-bit identical to int8.  ``"f32"`` is the legacy float-code path
(8-bit codes, noise-perturbed analog currents); exact only while
|acc| < 2^24.  ``"auto"`` follows the input arrays' dtypes.

Epilogue placement (Pallas backend): a *fixed* readout window (``out_scale``
given) or no readout runs the whole epilogue inside the kernel's final K
step (tdvmm_fused_kernel); a data-calibrated window (``out_scale=None`` with
``out_bits``) runs the two-phase ``tdvmm_calibrated_kernel``, which folds
the per-slot max|z| reduction into the accumulator walk and applies the
windowed readout in the same launch.  Either way each output tile
materializes in HBM exactly once, already in model units
(``fused_calibration=False`` forces the legacy unfused jnp epilogue for the
calibrated case).  All epilogues evaluate the same expression term for term,
so every pairing is bit-for-bit identical.

Batching: 3-D inputs (E, M, K) x (E, K, N) map the expert dim onto the
kernel's batched grid axis (scales (E, M) / (E, N)); 2-D inputs run as E=1.
A 2-D x against a 3-D (G, K, N) weight bank runs the **shared-input grouped**
grid — the paper's shared-DAC dataflow: one (M, K) code matrix (and one
(M,) scale vector) feeds all G weight tiles in a single launch, returning
(G, M, N).  Per-group w_scale/out_scale ride the same (G, ...) operands as
per-expert batching.

Ragged grouped launches (``group_widths``): G same-input projections of
uneven widths concatenate along N into ONE 2-D (M, K) x (K, sum N_g) launch
— each member zero-padded only to the 128 lane, not to the widest member —
with per-member readout windows addressed by column span (a tuple
``out_scale`` maps per member; data calibration reduces per member).  This
is how ``core.layers.td_grouped_matmul`` runs attn.qkv / ssm.in_proj without
padding every member to max(N_g).

Block sizes: ``plan_kernel`` resolves the backend and consults the
per-platform autotune tables (tdvmm.autotune_lookup), records every lookup
in ``autotune_report()``, and warns ONCE per untuned shape instead of
silently falling back to heuristic blocks.

Gradients flow through a shared custom VJP (plain matmul cotangents on the
STE-wrapped codes, identity through the readout quantizer), so every backend
x dtype x fusion combination is trainable and backend-independent in the
backward pass.  Pass int arrays directly only on no-grad (serving) paths;
the QAT path feeds the f32 STE view and lets the forward cast to int8.
"""
from __future__ import annotations

import functools
import logging
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tdvmm.tdvmm import (
    acc_dtype_for, autotune_blocks, autotune_lookup, autotune_platform,
    pad_to_blocks, tdvmm_calibrated_kernel, tdvmm_fused_kernel,
    tdvmm_matmul_kernel)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """'auto' | 'jnp' | 'pallas' -> concrete integrate implementation.

    Shape-aware form: ``plan_kernel`` additionally consults the block-size
    autotune tables (kernels/tdvmm/autotune_table.py) keyed on
    (M, K, N, dtype).
    """
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown TD-VMM backend {backend!r}")
    return backend


class KernelPlan(NamedTuple):
    """Resolved backend + autotuned block sizes for one codes matmul."""
    backend: str
    bm: int
    bk: int
    bn: int
    code_dtype: str = "f32"
    autotune_hit: bool = False   # False = heuristic fallback (untuned shape)
    platform: str = "interpret"  # which autotune table answered

    @property
    def blocks(self) -> tuple[int, int, int]:
        return (self.bm, self.bk, self.bn)


# Every plan_kernel lookup of this process, keyed (M, K, N, dtype-name) —
# the kernel report that makes untuned (heuristic-fallback) shapes visible
# in BENCH_kernels.json instead of quietly slow.
_AUTOTUNE_LOG: dict[tuple[int, int, int, str], dict] = {}
_AUTOTUNE_WARNED: set[tuple[int, int, int, str]] = set()
_logger = logging.getLogger(__name__)


def plan_kernel(backend: str, m: int, k: int, n: int,
                code_dtype: str = "f32") -> KernelPlan:
    """resolve_backend + the (M, K, N, dtype)-keyed block autotune table.

    Records the lookup (blocks, hit/miss, platform) into
    ``autotune_report()`` and warns once per untuned shape — run
    ``scripts/autotune_tdvmm.py`` to backfill the table."""
    name = "float32" if code_dtype in ("f32", "auto") else code_dtype
    platform = autotune_platform()
    blocks, hit = autotune_lookup(m, k, n, name, platform)
    key = (m, k, n, name)
    _AUTOTUNE_LOG[key] = {"blocks": blocks, "hit": hit, "platform": platform}
    if not hit and key not in _AUTOTUNE_WARNED:
        _AUTOTUNE_WARNED.add(key)
        # One-time log (not warnings.warn: planning runs on hot, otherwise
        # warning-free paths); the miss also lands in autotune_report().
        _logger.warning(
            "TD-VMM autotune miss: no %s table entry for (M, K, N, dtype)="
            "(%d, %d, %d, %s); using heuristic blocks %s.  Run "
            "scripts/autotune_tdvmm.py to tune this shape.",
            platform, m, k, n, name, blocks)
    return KernelPlan(resolve_backend(backend), *blocks,
                      code_dtype=code_dtype, autotune_hit=hit,
                      platform=platform)


def autotune_report() -> dict:
    """Every (M, K, N, dtype) this process planned, with the chosen blocks
    and whether the autotune table answered — benches attach this to their
    JSON report so CI sees exactly which shapes ran untuned."""
    entries = {
        f"{m}x{k}x{n}:{name}": dict(v)
        for (m, k, n, name), v in sorted(_AUTOTUNE_LOG.items())}
    return {"platform": autotune_platform(),
            "entries": entries,
            "misses": sorted(k for k, v in entries.items() if not v["hit"])}


def reset_autotune_report() -> None:
    _AUTOTUNE_LOG.clear()


def _member_window_cols(values, group_widths, n: int) -> jax.Array:
    """(G,) per-member window values -> a (1, 1, N) per-column vector over
    the ragged concat span (pad columns get 1.0 — they only ever multiply
    zero-code outputs)."""
    parts = [jnp.full((wd,), np.float32(v), jnp.float32)
             for v, wd in zip(values, group_widths)]
    tail = n - sum(group_widths)
    if tail:
        parts.append(jnp.ones((tail,), jnp.float32))
    return jnp.concatenate(parts).reshape(1, 1, n)


def _member_window_cols_arr(values: jax.Array, group_widths,
                            n: int) -> jax.Array:
    """Traced sibling of ``_member_window_cols``: a (G,) window *array*
    gathered out to the (1, 1, N) per-column vector (pad columns 1.0).  The
    gather index is host-static, so the expansion adds no data-dependent
    shapes — a hot-swapped window recompiles nothing."""
    idx = []
    for g, wd in enumerate(group_widths):
        idx.extend([g] * wd)
    idx.extend([len(group_widths)] * (n - sum(group_widths)))
    vals = jnp.concatenate([
        jnp.asarray(values, jnp.float32).reshape(-1),
        jnp.ones((1,), jnp.float32)])
    return vals[jnp.asarray(np.asarray(idx, np.int32))].reshape(1, 1, n)


# ---------------------------------------------------------------------------
# Epilogue (unfused form; the fused kernels mirror this term for term)
# ---------------------------------------------------------------------------
def _epilogue(acc, x_scale, w_scale, gain, out_bits, out_scale,
              group_widths=None, out_window=None):
    """gain -> optional p-bit readout -> per-row x per-channel rescale.

    acc: (E, M, N) int32 or f32; x_scale: (E, M); w_scale: (E, N).
    ``out_scale=None`` calibrates the ADC window to max|z| *per expert tile*
    (each expert is its own analog array; E=1 reproduces the global window).
    A tuple ``out_scale`` is an (E,)-vector of fixed per-expert windows —
    one calibrated readout window per expert's analog tile.  With
    ``group_widths`` (ragged concat launch) windows are per *member column
    span* instead: a tuple maps one window per member, and data calibration
    reduces max|z| over each member's columns.  ``out_window`` is the traced
    *array* form of a fixed window (scalar / (E,) / per-member (G,)): same
    expression, window as a runtime operand instead of a baked constant —
    serving hot-swaps calibration values through it without recompiling.
    """
    # Pin the inputs and (acc * gain) as units: under a caller's jit the
    # latch gain and the caller's scale chains are visible to XLA, which
    # sinks their constant factors through the readout multiplies — e.g.
    # (w_scale * 2K) * back reassociates into w_scale * (2K * back), 1 ulp
    # off the eager / in-kernel association.
    x_scale = jax.lax.optimization_barrier(x_scale.astype(jnp.float32))
    w_scale = jax.lax.optimization_barrier(w_scale.astype(jnp.float32))
    z = jax.lax.optimization_barrier(
        acc.astype(jnp.float32) * jnp.float32(gain))
    ws_row = w_scale[..., None, :]
    if out_bits is not None:
        # Bit-for-bit contract: a calibration-pinned window must reproduce
        # the per-call data-calibrated window it was captured from, and the
        # fused Pallas epilogues must match this unfused form exactly.  Two
        # XLA behaviors break that if window-derived factors enter the graph
        # as literals: division by a constant strength-reduces into a
        # 1-ulp-off reciprocal multiply, and constant factors get
        # reassociated (sunk) through neighboring multiply chains.  So the
        # window is always a *runtime* value (constants pass through an
        # optimization_barrier), divisions are explicit, and the post-round
        # rescale chain ``(q * xs) * (ws * back)`` carries no constants —
        # matching the fused kernels' association term for term.
        s = out_scale
        if out_window is not None:
            # Runtime window: already a traced value, so the barrier chain
            # below sees exactly what the static path sees post-barrier —
            # the two programs are the same arithmetic term for term.
            ow = jnp.asarray(out_window, jnp.float32)
            if group_widths is not None:
                s = _member_window_cols_arr(ow, group_widths, z.shape[-1])
            elif ow.ndim >= 1:
                s = ow.reshape(-1, 1, 1)
            else:
                s = ow
        elif s is None:
            if group_widths is not None:
                # Per-member windows over the concat columns: f32 max is
                # exact, so the per-span reduction equals each member's
                # standalone max bit for bit.
                off, segs = 0, []
                for wd in group_widths:
                    seg = jnp.max(jnp.abs(z[..., off:off + wd]),
                                  axis=(-2, -1), keepdims=True, initial=0.0)
                    segs.append(jnp.broadcast_to(
                        seg, seg.shape[:-1] + (wd,)))
                    off += wd
                s = jnp.concatenate(segs, axis=-1)
            else:
                s = jnp.max(jnp.abs(z), axis=(-2, -1), keepdims=True,
                            initial=0.0)
            s = jax.lax.stop_gradient(jnp.maximum(s, 1e-9))
        elif isinstance(s, tuple):
            if group_widths is not None:
                s = _member_window_cols(s, group_widths, z.shape[-1])
            else:
                s = jnp.asarray(s, jnp.float32).reshape(-1, 1, 1)
        else:
            s = jnp.float32(s)
        s = jax.lax.optimization_barrier(s.astype(jnp.float32))
        levels = float((1 << out_bits) - 1)
        # The barrier pins mul(z, inv): XLA otherwise strength-reduces
        # mul(z, div(1, s)) back into div(z, s) — 1 ulp off, and only in
        # programs where s is a scalar broadcast, so a grouped (vector
        # window) launch and its sequential counterpart would disagree.
        inv = jax.lax.optimization_barrier(jnp.float32(1.0) / s)
        z = jnp.round(jnp.clip(z * inv, -1.0, 1.0) * levels)
        back = jax.lax.optimization_barrier(
            s * (np.float32(1.0) / np.float32(levels)))
        ws_row = jax.lax.optimization_barrier(ws_row * back)
    # Pin (z * xs) before the ws_row multiply: with both factors broadcasts,
    # XLA reassociates the chain shape-dependently; the kernels' in-VMEM
    # epilogues evaluate exactly this association, term for term.
    zx = jax.lax.optimization_barrier(z * x_scale[..., :, None])
    return zx * ws_row


def _calib_slots(e: int, n: int, bn: int,
                 group_widths) -> tuple[jax.Array, int]:
    """(slots, nslots) for the calibrated kernel: the readout-slot id of
    every N column block — the expert id for batched launches, the group
    member owning the span for ragged launches (pad-tail blocks fold into
    the last member; their zero accumulators can't move an abs-max)."""
    bn = min(bn, n)
    nn = n // bn
    if group_widths is None:
        ids = jnp.broadcast_to(
            jnp.arange(e, dtype=jnp.int32)[:, None], (e, nn))
        return ids, e
    bounds = np.cumsum(group_widths)
    ids = np.searchsorted(bounds, np.arange(nn) * bn, side="right")
    ids = np.minimum(ids, len(group_widths) - 1).astype(np.int32)
    return jnp.asarray(ids)[None, :], len(group_widths)


def _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                out_scale, out_window, backend, interpret, code_dtype,
                blocks, group_widths, fused_calibration):
    ex, m, k = x_codes.shape
    e, _, n = w_codes.shape
    shared_x = ex == 1 and e > 1
    assert ex == e or shared_x, (x_codes.shape, w_codes.shape)
    if min(e, m, k, n) == 0:
        # Empty expert batch / filtered serving batch / zero-width contraction:
        # zero charge everywhere, and readout(0) * scales == 0 on every path.
        return jnp.zeros((e, m, n), jnp.float32)
    if code_dtype in ("int8", "int4"):
        # Codes are integer-valued within the storage range by the caller's
        # contract (p <= 7 / p <= 3); the cast is exact and XLA fuses it
        # into the producer, so the kernel streams 1-byte codes from HBM.
        xi = x_codes.astype(jnp.int8)
        wi = w_codes.astype(jnp.int8)
    else:
        xi = x_codes.astype(jnp.float32)
        wi = w_codes.astype(jnp.float32)
    if blocks is None:
        blocks = autotune_blocks(
            m, k, n, "int4" if code_dtype == "int4" else xi.dtype)
    bm, bk, bn = blocks

    if backend == "jnp":
        if shared_x:
            # Same contraction (and accumulation order) as the batched form,
            # with the single code matrix broadcast over the G weight tiles.
            acc = jnp.einsum("mk,gkn->gmn", xi[0], wi,
                             preferred_element_type=acc_dtype_for(xi.dtype))
        else:
            acc = jnp.einsum("emk,ekn->emn", xi, wi,
                             preferred_element_type=acc_dtype_for(xi.dtype))
        return _epilogue(acc, x_scale, w_scale, gain, out_bits, out_scale,
                         group_widths, out_window)

    unpack4 = code_dtype == "int4"
    if unpack4:
        # Two codes per byte for the HBM stream; launch geometry (K, bk)
        # switches to packed units — the kernel unpacks per block.
        from repro.core.quant import pack_int4
        xi = pack_int4(xi, axis=-1)
        wi = pack_int4(wi, axis=-2)
        bk = max(bk // 2, 1)
    xp, wp = pad_to_blocks(xi, wi, bm, bk, bn)
    mp, np_ = xp.shape[-2], wp.shape[-1]
    exact = (mp, np_) == (m, n)

    if out_bits is None or out_scale is not None or out_window is not None:
        # Fixed readout window (runtime-operand or static, or no readout):
        # fully fused epilogue — the (bm, bn) tile leaves VMEM exactly once,
        # already in model units.
        xsp = jnp.pad(x_scale, ((0, 0), (0, mp - m)))[..., :, None]
        wsp = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))[..., None, :]
        window, scale_arg = None, out_scale
        if out_bits is not None and out_window is not None:
            ow = jnp.asarray(out_window, jnp.float32)
            if group_widths is not None:
                window = _member_window_cols_arr(ow, group_widths, np_)
            else:
                window = ow.reshape(-1, 1, 1) if ow.ndim >= 1 \
                    else ow.reshape(1, 1, 1)
            scale_arg = None
        elif (out_bits is not None and group_widths is not None
                and isinstance(out_scale, tuple)):
            window, scale_arg = _member_window_cols(
                out_scale, group_widths, np_), None
        y = tdvmm_fused_kernel(
            xp, wp, xsp, wsp, window=window, gain=gain, out_bits=out_bits,
            out_scale=scale_arg, bm=bm, bk=bk, bn=bn, interpret=interpret,
            unpack4=unpack4)
        return y if exact else y[:, :m, :n]
    if fused_calibration:
        # Data-calibrated window, still one launch / one HBM output: the
        # two-phase kernel folds the per-slot max into the accumulator walk.
        xsp = jnp.pad(x_scale, ((0, 0), (0, mp - m)))[..., :, None]
        wsp = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))[..., None, :]
        slots, nslots = _calib_slots(e, np_, bn, group_widths)
        y = tdvmm_calibrated_kernel(
            xp, wp, xsp, wsp, slots, gain=gain, out_bits=out_bits,
            nslots=nslots, bm=bm, bk=bk, bn=bn, interpret=interpret,
            unpack4=unpack4)
        return y if exact else y[:, :m, :n]
    # Legacy two-pass: integrate in the kernel, epilogue unfused in jnp.
    acc = tdvmm_matmul_kernel(
        xp, wp, bm=bm, bk=bk, bn=bn, interpret=interpret, unpack4=unpack4)
    acc = acc if exact else acc[:, :m, :n]
    return _epilogue(acc, x_scale, w_scale, gain, out_bits, out_scale,
                     group_widths, out_window)


# ---------------------------------------------------------------------------
# Shared custom VJP (all backends / dtypes / fusion modes)
# ---------------------------------------------------------------------------
@functools.partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11, 12, 13))
def _tdvmm_core(x_codes, w_codes, x_scale, w_scale, out_window, gain,
                out_bits, out_scale, backend, interpret, code_dtype, blocks,
                group_widths, fused_calibration):
    """Differentiable integrate+epilogue on canonical (E, M, K) shapes.

    ``out_window`` rides as a differentiable-position arg (it is traced —
    nondiff_argnums must stay hashable statics) but is calibration state,
    not a trainable: its cotangent is zeros, matching the static-window
    path where the window never enters the autodiff graph at all."""
    return _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                       out_scale, out_window, backend, interpret, code_dtype,
                       blocks, group_widths, fused_calibration)


def _tdvmm_core_fwd(x_codes, w_codes, x_scale, w_scale, out_window, gain,
                    out_bits, out_scale, backend, interpret, code_dtype,
                    blocks, group_widths, fused_calibration):
    y = _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                    out_scale, out_window, backend, interpret, code_dtype,
                    blocks, group_widths, fused_calibration)
    return y, (x_codes, w_codes, x_scale, w_scale, out_window, y)


def _tdvmm_core_bwd(gain, out_bits, out_scale, backend, interpret,
                    code_dtype, blocks, group_widths, fused_calibration,
                    res, g):
    x_codes, w_codes, x_scale, w_scale, out_window, y = res
    denom = x_scale[..., :, None] * w_scale[..., None, :]
    # Recover the post-readout latch value z = y / (xs * ws); internal
    # callers clamp scales >= 1e-6, so the where() only guards direct API
    # calls with exact-zero scales (whose y, and scale grads, are both 0).
    z = jnp.where(denom == 0.0, 0.0, y / denom)
    # Identity through the readout quantizer (STE) and the latch gain:
    dacc = g * denom * gain
    xf = x_codes.astype(jnp.float32)
    wf = w_codes.astype(jnp.float32)
    if x_codes.shape[0] == 1 and dacc.shape[0] > 1:
        # Shared-input grouped launch: the one x (and x_scale) fed every
        # group tile, so its cotangent sums over the group axis.  (Ragged
        # concat launches are plain 2-D matmuls here: member columns sum
        # into the shared x cotangent through the ordinary contraction.)
        gx = jnp.einsum("gmn,gkn->mk", dacc, wf,
                        preferred_element_type=jnp.float32)[None]
        gw = jnp.einsum("mk,gmn->gkn", xf[0], dacc,
                        preferred_element_type=jnp.float32)
        gxs = jnp.sum(g * z * w_scale[..., None, :], axis=(0, -1))[None]
    else:
        gx = jnp.einsum("emn,ekn->emk", dacc, wf,
                        preferred_element_type=jnp.float32)
        gw = jnp.einsum("emk,emn->ekn", xf, dacc,
                        preferred_element_type=jnp.float32)
        gxs = jnp.sum(g * z * w_scale[..., None, :], axis=-1)
    gws = jnp.sum(g * z * x_scale[..., :, None], axis=-2)
    gwin = None if out_window is None else jnp.zeros_like(out_window)
    return gx, gw, gxs, gws, gwin


_tdvmm_core.defvjp(_tdvmm_core_fwd, _tdvmm_core_bwd)


def codes_matmul(
    x_codes: jax.Array, w_codes: jax.Array, backend: str,
    interpret: bool | None = None, code_dtype: str = "auto",
) -> jax.Array:
    """Raw (.., M, K) @ (.., K, N) charge accumulation as f32, padded to the
    kernel's block multiples and sliced back.  Differentiable on any backend
    (custom VJP = plain matmul cotangents, matching jnp.dot autodiff).

    A 2-D x against a 3-D (G, K, N) bank runs shared-x grouped: one code
    matrix against G tiles, returning (G, M, N) (no squeeze)."""
    squeeze = x_codes.ndim == 2 and w_codes.ndim == 2
    if x_codes.ndim == 2:
        x_codes = x_codes[None]
    if w_codes.ndim == 2:
        w_codes = w_codes[None]
    m = x_codes.shape[1]
    e, _, n = w_codes.shape
    if interpret is None:
        interpret = not _on_tpu()
    if code_dtype == "auto":
        code_dtype = "int8" if jnp.issubdtype(
            x_codes.dtype, jnp.integer) else "f32"
    ones_m = jnp.ones((x_codes.shape[0], m), jnp.float32)
    ones_n = jnp.ones((e, n), jnp.float32)
    acc = _dispatch(x_codes, w_codes, ones_m, ones_n, 1.0, None, None, None,
                    resolve_backend(backend), bool(interpret), code_dtype,
                    None, None, True)
    return acc[0] if squeeze else acc


def _dispatch(x_codes, w_codes, x_scale, w_scale, gain, out_bits, out_scale,
              out_window, backend, interpret, code_dtype, blocks,
              group_widths, fused_calibration):
    """Route int inputs straight to the impl (no float cotangents exist);
    float inputs go through the shared custom VJP."""
    if jnp.issubdtype(x_codes.dtype, jnp.integer):
        return _tdvmm_impl(x_codes, w_codes, x_scale, w_scale, gain,
                           out_bits, out_scale, out_window, backend,
                           interpret, code_dtype, blocks, group_widths,
                           fused_calibration)
    return _tdvmm_core(x_codes, w_codes, x_scale, w_scale, out_window, gain,
                       out_bits, out_scale, backend, interpret, code_dtype,
                       blocks, group_widths, fused_calibration)


@functools.partial(
    jax.jit,
    static_argnames=("gain", "out_bits", "out_scale", "backend", "interpret",
                     "code_dtype", "block_sizes", "group_widths",
                     "fused_calibration"))
def tdvmm_matmul(
    x_codes: jax.Array,      # (M, K) or (E, M, K) signed time codes
    w_codes: jax.Array,      # (K, N) or (E, K, N) signed weight codes
    x_scale: jax.Array,      # (M,) / (E, M) per-row input scales
    w_scale: jax.Array,      # (N,) / (E, N) per-channel weight scales
    gain: float = 1.0,
    out_bits: int | None = None,
    out_scale: float | tuple[float, ...] | None = None,
    backend: str = "auto",
    interpret: bool | None = None,
    code_dtype: str = "auto",
    block_sizes: tuple[int, int, int] | None = None,
    group_widths: Optional[tuple[int, ...]] = None,
    fused_calibration: bool = True,
    out_window: Optional[jax.Array] = None,
) -> jax.Array:
    """Quantized four-quadrant TD-VMM: codes matmul + readout + scale epilogue.

    ``out_scale=None`` calibrates the readout window from the data (§3.1) —
    on the Pallas backend via the fused two-phase ``tdvmm_calibrated_kernel``
    (``fused_calibration=False`` forces the legacy unfused epilogue); pass
    the value captured by ``core.layers.calibrate_out_scale`` (or the
    model-wide calibration pass) to skip the per-call max entirely.  A tuple
    is an (E,)-vector of fixed per-expert windows for batched inputs — still
    static, still fused.  Arbitrary M/K/N are zero-padded to the kernel's
    block shape; ``block_sizes=None`` consults the autotune table.

    ``out_window`` is the *traced-array* form of a fixed window — scalar
    ``()``, per-expert ``(E,)``, or per-member ``(G,)`` on ragged grouped
    launches.  It is NOT a jit-static argument: swapping window values of
    the same shape reuses the compiled program (the serving engine's
    hot-swappable calibration), and the epilogue evaluates the identical
    barrier-pinned expression as the static ``out_scale`` path, so the two
    forms are bit-for-bit interchangeable.  Mutually exclusive with
    ``out_scale``; requires ``out_bits``.

    Shared-x grouped: a 2-D (M, K) x against a 3-D (G, K, N) weight bank
    (x_scale (M,), w_scale (G, N)) runs one launch whose G tiles all read
    the same code matrix, returning (G, M, N) un-squeezed.

    Ragged grouped: ``group_widths=(N_1, ..., N_G)`` declares a 2-D
    (M, K) x (K, sum N_g) launch as the column concat of G same-input
    members; readout windows (tuple ``out_scale``, or data calibration)
    resolve per member column span instead of per launch.
    """
    backend = resolve_backend(backend)
    if interpret is None:
        interpret = not _on_tpu()
    squeeze = x_codes.ndim == 2 and w_codes.ndim == 2
    if x_codes.ndim == 2:
        x_codes = x_codes[None]
    if w_codes.ndim == 2:
        w_codes = w_codes[None]
    ex, m, _ = x_codes.shape
    e, _, n = w_codes.shape
    if ex not in (e, 1):
        raise ValueError(
            f"batched x/w mismatch: x batch {ex} vs w batch {e} "
            "(shared-x grouped launches carry a single x batch entry)")
    if group_widths is not None:
        group_widths = tuple(int(w) for w in group_widths)
        if ex != 1 or e != 1:
            raise ValueError(
                "group_widths describes a 2-D ragged concat launch; got "
                f"batched codes (x batch {ex}, w batch {e})")
        if sum(group_widths) != n:
            raise ValueError(
                f"group_widths {group_widths} sum to {sum(group_widths)} "
                f"but the concat weight bank has N={n}")
        if isinstance(out_scale, tuple) and len(out_scale) != len(group_widths):
            raise ValueError(
                f"out_scale has {len(out_scale)} member windows for "
                f"{len(group_widths)} group members")
    elif isinstance(out_scale, tuple) and len(out_scale) != e:
        raise ValueError(
            f"out_scale has {len(out_scale)} per-expert windows for "
            f"E={e} batched tiles")
    if out_window is not None:
        if out_bits is None:
            raise ValueError("out_window needs out_bits (p-bit readout)")
        if out_scale is not None:
            raise ValueError(
                "out_window and out_scale are mutually exclusive (the "
                "window array is the runtime-operand form of out_scale)")
        out_window = jnp.asarray(out_window, jnp.float32)
        if group_widths is not None:
            if out_window.shape != (len(group_widths),):
                raise ValueError(
                    f"out_window shape {out_window.shape} for a "
                    f"{len(group_widths)}-member grouped launch; "
                    f"expected ({len(group_widths)},)")
        elif out_window.ndim == 1 and out_window.shape[0] != e:
            raise ValueError(
                f"out_window has {out_window.shape[0]} per-expert windows "
                f"for E={e} batched tiles")
        elif out_window.ndim > 1:
            raise ValueError(
                f"out_window must be scalar, (E,) or (G,); got shape "
                f"{out_window.shape}")
    if code_dtype == "auto":
        code_dtype = "int8" if jnp.issubdtype(
            x_codes.dtype, jnp.integer) else "f32"
    x_scale = x_scale.reshape(ex, m).astype(jnp.float32)
    w_scale = w_scale.reshape(e, n).astype(jnp.float32)
    y = _dispatch(x_codes, w_codes, x_scale, w_scale, gain, out_bits,
                  out_scale, out_window, backend, bool(interpret),
                  code_dtype, block_sizes, group_widths,
                  bool(fused_calibration))
    # lax.squeeze, not y[0]: integer indexing lowers to a full-range slice
    # copy of the (M, N) output before the squeeze view.
    return jax.lax.squeeze(y, (0,)) if squeeze else y
