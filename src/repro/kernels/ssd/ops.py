"""jit'd wrapper for the SSD kernel with jnp fallback."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.ssd import ssd_kernel
from repro.models.ssm import ssd_chunked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, chunk: int = 128, interpret: bool | None = None):
    """Mamba-2 SSD scan: returns y (B, L, H, P).

    Pallas kernel on TPU / interpret mode; chunked-jnp path elsewhere."""
    if interpret is None:
        interpret = not _on_tpu()
    if _on_tpu() or interpret:
        return ssd_kernel(x, dt, a_log, b, c, chunk=chunk,
                          interpret=bool(interpret))
    y, _ = ssd_chunked(x, dt, a_log, b, c, chunk)   # pragma: no cover
    return y
