"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

Grid = (B, H, L/Q): batch and heads are parallel; the chunk axis is the
sequential ('arbitrary') dim, carrying the (P, S) recurrent state in VMEM
scratch between chunk steps — the state NEVER visits HBM (a naive scan
lowering writes it back per step).

Per chunk (length Q), with scalar-per-head decay a = -exp(A_log):

    cum_i   = cumsum_j<=i dt_j*a                      (log decay within chunk)
    y_intra = ((C B^T) .* M .* dt) x        M_ij = exp(cum_i - cum_j), j <= i
    y_inter = C_i exp(cum_i) state_prev
    state   = exp(cum_Q) state_prev + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T

All inner products are (Q x S)(S x Q), (Q x Q)(Q x P), (S x Q)(Q x P) matmuls
— MXU work with Q = S = 128-aligned tiles.  B/C are group-shared: the
index_map routes head h to group h // (H/G), so a group's B/C tile is fetched
once per group, not per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, st_ref, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    b = b_ref[0, :, 0, :].astype(jnp.float32)      # (Q, S)
    c = c_ref[0, :, 0, :].astype(jnp.float32)      # (Q, S)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar

    q = x.shape[0]
    dta = dt * a                                   # (Q,) negative log decays
    cum = jnp.cumsum(dta)                          # (Q,)
    total = cum[-1]

    # ---- intra-chunk: masked decay-weighted attention ----
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    causal = ii >= jj
    m = jnp.where(causal, jnp.exp(cum[:, None] - cum[None, :]), 0.0)  # (Q,Q)
    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)           # (Q,Q)
    w = g * m * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)             # (Q,P)

    # ---- inter-chunk: contribution of the carried state ----
    state = st_ref[...]                                               # (P,S)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        c, state.T, preferred_element_type=jnp.float32)               # (Q,P)

    # ---- state update ----
    decay_to_end = jnp.exp(total - cum) * dt                          # (Q,)
    new_state = jnp.dot(
        (x * decay_to_end[:, None]).T, b,
        preferred_element_type=jnp.float32)                           # (P,S)
    st_ref[...] = state * jnp.exp(total) + new_state

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_kernel(
    x: jax.Array,        # (B, L, H, P)
    dt: jax.Array,       # (B, L, H)  post-softplus step sizes
    a_log: jax.Array,    # (H,)
    b: jax.Array,        # (B, L, G, S)
    c: jax.Array,        # (B, L, G, S)
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bsz, L, H, Pd = x.shape
    G, S = b.shape[2], b.shape[3]
    rep = H // G
    q = min(chunk, L)
    assert L % q == 0
    nc = L // q

    return pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, Pd), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, q, 1, S), lambda bi, h, ci: (bi, ci, h // rep, 0)),
            pl.BlockSpec((1, q, 1, S), lambda bi, h, ci: (bi, ci, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, Pd), lambda bi, h, ci: (bi, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, L, H, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((Pd, S), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b, c)
