"""Pure-jnp oracles for the Mamba-2 SSD kernel.

Two references:
  * ssd_naive  — token-by-token recurrence (the definition; exact, slow)
  * ssd_chunked_ref — the chunked algebra (models/ssm.ssd_chunked), already
    validated against ssd_naive in tests/test_models_ssm.py

The Pallas kernel must match ssd_naive to fp32 tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked as ssd_chunked_ref  # noqa: F401


def ssd_naive(x, dt, a_log, b, c):
    """x: (B,L,H,P); dt: (B,L,H); a_log: (H,); b,c: (B,L,G,S).
    Returns (y (B,L,H,P), final_state (B,H,P,S))."""
    B, L, H, Pd = x.shape
    G, S = b.shape[2], b.shape[3]
    rep = H // G
    a = -jnp.exp(a_log)
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp          # (B,H,P), (B,H), (B,H,S), (B,H,S)
        decay = jnp.exp(dtt * a)[..., None, None]          # (B,H,1,1)
        upd = jnp.einsum("bhs,bh,bhp->bhps", bt, dtt, xt.astype(jnp.float32))
        state = state * decay + upd
        y = jnp.einsum("bhs,bhps->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((B, H, Pd, S), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.astype(jnp.float32).transpose(1, 0, 2),
          bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
