# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas-TPU compat helpers for the kernel modules."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` in newer jax;
    build whichever this install has."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
