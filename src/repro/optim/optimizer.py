"""Optimizers (pure-pytree, no optax dependency): AdamW and Adafactor.

Adafactor (factored second moments + optional bf16 first moment) exists for
the 1T-param kimi-k2 config: fp32 Adam moments for 1.03T params would need
8.2 TB (> 16 GB/chip on 512 chips once params+grads are added); factored
moments cut optimizer state to ~1 number per row+col plus a bf16 momentum.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def _adamw_init(params, cfg: OptimizerConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def _adamw_update(grads, inner, params, cfg: OptimizerConfig, step, lr):
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32) + 1.0
    corr = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        u = corr * m_new / (jnp.sqrt(v_new) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, inner["m"], inner["v"], params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}


# --------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored v for >=2D params
# --------------------------------------------------------------------------
def _adafactor_init(params, cfg: OptimizerConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def per_param(p):
        st = {"m": jnp.zeros(p.shape, mdt)}
        if p.ndim >= 2:
            st["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        else:
            st["v"] = jnp.zeros(p.shape, jnp.float32)
        return st

    return jax.tree.map(per_param, params,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def _adafactor_update(grads, inner, params, cfg: OptimizerConfig, step, lr):
    b2 = cfg.b2
    t = step.astype(jnp.float32) + 1.0
    decay = 1.0 - t ** -0.8          # time-dependent decay (original paper)

    def upd(g, st, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * st["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * st["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            u = g / jnp.sqrt(vhat + 1e-30)
            new_v = {"vr": vr, "vc": vc}
        else:
            v = decay * st["v"] + (1 - decay) * g2
            u = g / jnp.sqrt(v + 1e-30)
            new_v = {"v": v}
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        m = cfg.b1 * st["m"].astype(jnp.float32) + (1 - cfg.b1) * u
        u = m
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, {"m": m.astype(st["m"].dtype), **new_v}

    is_state = lambda x: isinstance(x, dict) and "m" in x
    out = jax.tree.map(upd, grads, inner, params,
                       is_leaf=lambda x: isinstance(x, jax.Array))
    # out leaves are (p_new, state) tuples at param positions
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_s = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_s


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Optimizer:
    cfg: OptimizerConfig

    def init(self, params) -> OptState:
        init = _adafactor_init if self.cfg.name == "adafactor" else _adamw_init
        return OptState(step=jnp.zeros((), jnp.int32), inner=init(params, self.cfg))

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        grads, gnorm = clip_by_global_norm(grads, self.cfg.grad_clip)
        lr = lr_schedule(self.cfg, state.step)
        fn = _adafactor_update if self.cfg.name == "adafactor" else _adamw_update
        new_params, new_inner = fn(grads, state.inner, params, self.cfg, state.step, lr)
        return new_params, OptState(state.step + 1, new_inner), {
            "grad_norm": gnorm, "lr": lr}


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return Optimizer(cfg)
