"""Gradient compression for the data-parallel all-reduce (int8 + error
feedback), as an explicit shard_map collective.

Standard GSPMD training reduces gradients implicitly inside backward.  For
cross-pod links (the slow hop on multi-pod meshes) an int8 reduce with error
feedback cuts wire bytes 4x vs f32 at equal convergence (1-bit/8-bit Adam
literature).  We expose:

    compressed_psum(x, axis, state)  — quantize (per-block scale) -> psum ->
                                       dequantize; returns residual for error
                                       feedback.

and wire it into the explicit-DP train path (launch/train.py with
``--grad-compression int8``), where gradients are computed per-DP-shard under
shard_map and reduced manually.  The GSPMD path leaves reduction to XLA (its
backward all-reduces are already overlapped by the latency-hiding scheduler).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 2048


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8: returns (codes int8, scales f32)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def _dequantize_int8(codes: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compressed_psum(x: jax.Array, axis, residual: jax.Array | None = None):
    """int8 psum with error feedback.  Call INSIDE shard_map over `axis`.

    Returns (mean-reduced x, new_residual).  The residual (quantization error)
    is added back into the next step's gradient before quantization — the
    standard convergence-preserving trick."""
    if residual is not None:
        x = x + residual
    codes, scale = _quantize_int8(x)
    deq_local = _dequantize_int8(codes, scale, x.shape, x.size)
    new_residual = x - deq_local
    # wire traffic: int8 codes + f32 per-block scales (~1/4 of f32)
    summed = jax.lax.psum(codes.astype(jnp.float32) * scale, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    reduced = (summed / n).reshape(-1)[: x.size].reshape(x.shape)
    return reduced, new_residual


def compressed_tree_psum(grads, axis, residuals=None):
    """Apply compressed_psum leaf-wise over a gradient pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = (jax.tree_util.tree_flatten(residuals)[0]
                  if residuals is not None else [None] * len(leaves))
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        y, nr = compressed_psum(g, axis, r)
        out.append(y)
        new_res.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out),
            jax.tree_util.tree_unflatten(treedef, new_res))


def wire_bytes_saved(grads) -> float:
    """Diagnostic: f32 vs int8+scales bytes for one DP reduce."""
    total = sum(g.size for g in jax.tree_util.tree_leaves(grads))
    f32 = 4.0 * total
    int8 = 1.0 * total + 4.0 * (total / BLOCK)
    return f32 - int8
