"""Streaming telemetry for the serving engine: metrics sink, rolling
robust statistics, and online regression/spike detection.

The engine's drive loop feeds a :class:`MetricsSink` every tick — step
latency, queue depth, tokens, fJ/Op, page pressure, retry/straggler/drift
counters — and the sink evaluates *alert rules* online:

  * **spike**: value exceeds the rolling **median + k·MAD** of the metric's
    recent window (robust to the occasional outlier in the window itself —
    a mean/stddev detector would be blinded by the very spikes it should
    catch).  ``abs_floor``/``rel_floor`` add a deadband so a near-zero MAD
    on a quiet series can't turn measurement jitter into alerts.
  * **threshold**: value exceeds a fixed limit.
  * **regression**: value exceeds ``baseline * (1 + tol)`` — e.g. fJ/Op
    drifting above the calibrated baseline while serving.

Every per-tick cost is **O(1) in the stream length**: series history lives
in a fixed-capacity ring, and the rolling median/MAD window is a fixed
constant ``window`` (a bisect-maintained sorted snapshot of the last
``window`` values — all work bounded by the window size, independent of how
long the engine has been serving).

Emitters are pluggable observers (in-memory for tests, JSONL for
``launch/serve.py``, stdout for humans).  The sink's dynamic state is a
plain-JSON ``snapshot()``/``restore()`` payload that rides inside
``Engine.snapshot()``'s meta leaf, so telemetry survives the PR 7
preemption contract: a killed engine restored in a fresh process continues
its series and alert history exactly where they stopped.

Statistics are host-side floats between the two compiled steps — telemetry
never adds a third compiled program (``compiled_steps == 2`` holds through
any sink-wired run).
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import os
from collections import deque
from pathlib import Path
from typing import Optional

__all__ = ["Alert", "AlertRule", "RollingSeries", "MetricsSink",
           "MemoryEmitter", "JsonlEmitter", "StdoutEmitter"]


@dataclasses.dataclass(frozen=True)
class Alert:
    """One fired alert: which rule, on what value, against what stats."""
    step: int
    metric: str
    kind: str                    # "spike" | "threshold" | "regression"
    value: float
    limit: float                 # the bound the value crossed
    median: float = 0.0          # rolling stats at evaluation time (spike)
    mad: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """Declarative alert condition on one metric.

    spike:      value > median + max(k * MAD, rel_floor * median, abs_floor)
                evaluated against the window *before* the new value (a spike
                never suppresses itself), only once >= min_samples exist.
    threshold:  value > limit.
    regression: value > baseline * (1 + tol).
    """
    metric: str
    kind: str = "spike"
    k: float = 6.0               # MAD multiplier (spike)
    min_samples: int = 8         # prior samples required before spike eval
    abs_floor: float = 0.0       # spike deadband, absolute
    rel_floor: float = 0.0       # spike deadband, fraction of the median
    limit: Optional[float] = None      # threshold bound
    baseline: Optional[float] = None   # regression reference
    tol: float = 0.1                   # regression tolerance fraction

    def __post_init__(self):
        if self.kind not in ("spike", "threshold", "regression"):
            raise ValueError(f"unknown alert kind {self.kind!r}")
        if self.kind == "threshold" and self.limit is None:
            raise ValueError(f"threshold rule on {self.metric!r} needs limit=")
        if self.kind == "regression" and self.baseline is None:
            raise ValueError(
                f"regression rule on {self.metric!r} needs baseline=")

    def evaluate(self, value: float, median: float, mad: float,
                 n_prior: int, step: int) -> Optional[Alert]:
        if self.kind == "threshold":
            if value > self.limit:
                return Alert(step=step, metric=self.metric, kind=self.kind,
                             value=float(value), limit=float(self.limit))
            return None
        if self.kind == "regression":
            bound = self.baseline * (1.0 + self.tol)
            if value > bound:
                return Alert(step=step, metric=self.metric, kind=self.kind,
                             value=float(value), limit=float(bound))
            return None
        # spike
        if n_prior < self.min_samples:
            return None
        band = max(self.k * mad, self.rel_floor * median, self.abs_floor)
        bound = median + band
        if value > bound:
            return Alert(step=step, metric=self.metric, kind=self.kind,
                         value=float(value), limit=float(bound),
                         median=float(median), mad=float(mad))
        return None


class RollingSeries:
    """Ring-buffered series with a constant-size rolling median/MAD window.

    ``capacity`` bounds the retained history (old samples fall off the
    ring); ``window`` is the rolling-statistics span.  A bisect-maintained
    sorted copy of the window makes the median an O(1) lookup and every
    push O(window) — constant per tick, independent of stream length.
    """

    def __init__(self, capacity: int = 512, window: int = 32):
        if capacity < 1 or window < 1:
            raise ValueError(f"capacity/window must be >= 1, got "
                             f"{capacity}/{window}")
        self.capacity = capacity
        self.window = window
        self.values: deque[float] = deque(maxlen=capacity)
        self.steps: deque[int] = deque(maxlen=capacity)
        self.count = 0                       # lifetime pushes (survives ring)
        self._win: deque[float] = deque()    # last `window` values, FIFO
        self._sorted: list[float] = []       # same values, sorted

    def push(self, step: int, value: float) -> None:
        value = float(value)
        self.values.append(value)
        self.steps.append(int(step))
        self.count += 1
        self._win.append(value)
        bisect.insort(self._sorted, value)
        if len(self._win) > self.window:
            old = self._win.popleft()
            del self._sorted[bisect.bisect_left(self._sorted, old)]

    @property
    def last(self) -> Optional[float]:
        return self.values[-1] if self.values else None

    def median(self) -> float:
        s = self._sorted
        if not s:
            return 0.0
        m = len(s) // 2
        return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])

    def mad(self) -> float:
        """Median absolute deviation of the rolling window (O(window))."""
        s = self._sorted
        if not s:
            return 0.0
        med = self.median()
        devs = sorted(abs(x - med) for x in s)
        m = len(devs) // 2
        return devs[m] if len(devs) % 2 else 0.5 * (devs[m - 1] + devs[m])

    def state_dict(self) -> dict:
        return {"values": list(self.values), "steps": list(self.steps),
                "count": self.count, "win": list(self._win)}

    def load_state_dict(self, state: dict) -> None:
        self.values = deque((float(v) for v in state["values"]),
                            maxlen=self.capacity)
        self.steps = deque((int(s) for s in state["steps"]),
                           maxlen=self.capacity)
        self.count = int(state["count"])
        self._win = deque(float(v) for v in state["win"])
        self._sorted = sorted(self._win)


# --------------------------------------------------------------------------
# Emitters
# --------------------------------------------------------------------------
class MemoryEmitter:
    """Collects everything in lists — the test/inspection emitter."""

    def __init__(self):
        self.metrics: list[tuple[str, int, float]] = []
        self.alerts: list[Alert] = []

    def on_metric(self, metric: str, step: int, value: float) -> None:
        self.metrics.append((metric, step, value))

    def on_alert(self, alert: Alert) -> None:
        self.alerts.append(alert)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlEmitter:
    """Appends one JSON object per metric sample / alert to a file — the
    ``launch/serve.py --metrics-jsonl`` sink, greppable and artifactable."""

    def __init__(self, path):
        self.path = Path(path)
        self._fh = None

    def _handle(self):
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "a")
        return self._fh

    def on_metric(self, metric: str, step: int, value: float) -> None:
        self._handle().write(json.dumps(
            {"t": "metric", "metric": metric, "step": step,
             "value": value}) + "\n")

    def on_alert(self, alert: Alert) -> None:
        fh = self._handle()
        fh.write(json.dumps({"t": "alert", **alert.to_json()}) + "\n")
        fh.flush()                       # alerts are worth a flush

    def flush(self) -> None:
        """Durability point: flush + fsync so the last tick's metrics
        survive a SIGKILL right after a preemption snapshot (the engine
        calls this from ``report()`` and from the snapshot-and-exit
        path)."""
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class StdoutEmitter:
    """Prints alerts (metrics would spam a terminal at one tick each)."""

    def __init__(self, prefix: str = "[telemetry]"):
        self.prefix = prefix

    def on_metric(self, metric: str, step: int, value: float) -> None:
        pass

    def on_alert(self, alert: Alert) -> None:
        print(f"{self.prefix} ALERT {alert.kind} {alert.metric} "
              f"step={alert.step}: value {alert.value:.4g} > "
              f"limit {alert.limit:.4g}")

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


# --------------------------------------------------------------------------
# The sink
# --------------------------------------------------------------------------
class MetricsSink:
    """Streaming metrics hub: per-metric rolling series + online alert
    evaluation + fan-out to emitters.

    ``observe`` is the single entry point (the engine calls it every tick;
    ``fault.StragglerMonitor``/``Heartbeat`` call it on their events).
    Rules evaluate against the window state *before* the new value lands,
    so one spike cannot raise the bound that should catch it.
    """

    def __init__(self, rules=(), window: int = 32, capacity: int = 512,
                 emitters=()):
        self.window = window
        self.capacity = capacity
        self.rules: list[AlertRule] = list(rules)
        self.emitters = list(emitters)
        self.series: dict[str, RollingSeries] = {}
        self.alerts: list[Alert] = []
        self.observations = 0

    def _series(self, metric: str) -> RollingSeries:
        s = self.series.get(metric)
        if s is None:
            s = self.series[metric] = RollingSeries(self.capacity,
                                                    self.window)
        return s

    def observe(self, metric: str, value: float, step: int) -> list[Alert]:
        """Record one sample; returns any alerts it fired."""
        value = float(value)
        s = self._series(metric)
        fired = []
        median, mad, n_prior = s.median(), s.mad(), s.count
        for rule in self.rules:
            if rule.metric != metric:
                continue
            alert = rule.evaluate(value, median, mad, n_prior, step)
            if alert is not None:
                fired.append(alert)
        s.push(step, value)
        self.observations += 1
        for em in self.emitters:
            em.on_metric(metric, step, value)
        for alert in fired:
            self.alerts.append(alert)
            for em in self.emitters:
                em.on_alert(alert)
        return fired

    def flush(self) -> None:
        """Push buffered emitter output to durable storage (fsync for
        ``JsonlEmitter``).  Called by the engine on every ``report()`` and
        on the preemption snapshot-and-exit path, so the final tick's
        metrics are never lost to a buffered file handle on SIGTERM."""
        for em in self.emitters:
            fn = getattr(em, "flush", None)
            if fn is not None:
                fn()

    def alerts_for(self, metric: str, kind: Optional[str] = None
                   ) -> list[Alert]:
        return [a for a in self.alerts if a.metric == metric
                and (kind is None or a.kind == kind)]

    def summary(self) -> dict:
        """Aggregate view for reports: per-metric rolling stats + alert
        counts by (metric, kind)."""
        by_kind: dict[str, int] = {}
        for a in self.alerts:
            key = f"{a.metric}:{a.kind}"
            by_kind[key] = by_kind.get(key, 0) + 1
        return {
            "observations": self.observations,
            "alerts": len(self.alerts),
            "alerts_by_rule": by_kind,
            "metrics": {
                name: {"count": s.count, "last": s.last,
                       "median": s.median(), "mad": s.mad()}
                for name, s in self.series.items()},
        }

    # ------------------------------------------------------------------
    # Snapshot / restore (rides in Engine.snapshot()'s meta leaf)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Dynamic state as plain JSON.  Rules/emitters are *configuration*
        (the restoring process constructs the sink the same way it
        constructs the engine) — only series, alerts, and counters ride."""
        return {
            "version": 1,
            "observations": self.observations,
            "series": {name: s.state_dict()
                       for name, s in self.series.items()},
            "alerts": [a.to_json() for a in self.alerts],
        }

    def restore(self, snap: dict) -> None:
        if not isinstance(snap, dict) or "series" not in snap:
            raise ValueError("not a MetricsSink snapshot")
        self.observations = int(snap["observations"])
        self.series = {}
        for name, state in snap["series"].items():
            self._series(name).load_state_dict(state)
        self.alerts = [Alert(**a) for a in snap["alerts"]]
