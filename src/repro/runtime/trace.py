"""Request-level tracing for the serving engine (Chrome-trace export).

The engine's whole request lifecycle — ``queued -> admitted ->
prefill_chunk[i] -> decode tick -> finished/evicted/rejected/over_budget``
— is recorded as structured spans and events by a :class:`Tracer` threaded
through ``runtime/engine.py``.  Everything is host-side bookkeeping between
the two compiled steps: tracing never adds a compiled program
(``compiled_steps == 2`` holds) and a traced run is bit-identical to an
untraced one.

Export is standard Chrome Trace Event Format (load ``chrome_trace()``'s
JSON in Perfetto / ``chrome://tracing``):

  * **pid 0 "engine"**, tid 0 "ticks": one ``X`` (complete) slice per
    engine tick, named by what the tick did (``prefill_chunk[i]`` /
    ``decode`` / ``idle``) with the real wall-clock duration, plus ``C``
    counter tracks (queue depth, active slots, pages in use, fJ/Op).
  * **pid 1 "requests"**, tid = rid: every request is its own thread with
    a strict ``B``/``E`` span stack — ``queued``, then ``prefill``, then
    ``decode`` — closed by an instant ``finish:<reason>`` marker.  Span
    boundary ``args`` carry the engine step id, slot, dp-rank, and page
    count, so span boundaries can be cross-checked against
    ``EngineReport`` exactly.

Timestamps come from the tracer's own **cumulative engine clock**
(microseconds of summed tick wall-time, advanced only in ``tick_done``),
NOT ``time.time()``: the clock rides ``snapshot()``/``restore()`` together
with all open spans, so a preempted engine restored in a fresh process
continues the *same* trace — one continuous, schema-valid file across a
kill+restore (Engine snapshot meta v4).

``validate_chrome_trace`` is the shared schema check (tests, benchmarks,
CI): integer pid/tid, non-decreasing ``ts`` per (pid, tid), balanced
stack-disciplined ``B``/``E`` pairs.
"""
from __future__ import annotations

import numpy as np

__all__ = ["Tracer", "validate_chrome_trace", "ENGINE_PID", "REQUEST_PID"]

ENGINE_PID = 0
REQUEST_PID = 1

_PHASES = ("B", "E", "X", "C", "i", "M")


class Tracer:
    """Span/event recorder for one engine's request lifecycle.

    ``max_events`` is a soft cap: once reached, *droppable* events (tick
    slices, counters) are counted in ``dropped`` instead of stored, while
    span boundaries, finish markers, and metadata always land — so the
    exported trace stays balanced and schema-valid no matter how long the
    engine serves.
    """

    def __init__(self, max_events: int = 200_000):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self.max_events = int(max_events)
        self.clock_us = 0.0            # cumulative engine wall-time, us
        self.ticks = 0
        self.dropped = 0
        self.events: list[dict] = []
        self._phase: dict[int, str] = {}   # rid -> open span name
        self._req: dict[int, dict] = {}    # rid -> waterfall bookkeeping
        self._named: set[str] = set()      # emitted metadata keys
        self._pending = None               # (name, args) slice of this tick
        self._emit_meta("process_name", ENGINE_PID, 0, "engine")
        self._emit_meta("process_name", REQUEST_PID, 0, "requests")
        self._emit_meta("thread_name", ENGINE_PID, 0, "ticks")

    # ------------------------------------------------------------------
    # Low-level emit
    # ------------------------------------------------------------------
    def _append(self, ev: dict, droppable: bool = False) -> None:
        if droppable and len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def _emit_meta(self, kind: str, pid: int, tid: int, name: str) -> None:
        key = f"{kind}:{pid}:{tid}"
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"ph": "M", "name": kind, "pid": pid, "tid": tid,
                            "ts": 0, "args": {"name": name}})

    def _close_phase(self, rid: int, step: int):
        ph = self._phase.pop(rid, None)
        if ph is None:
            return None
        self._append({"ph": "E", "name": ph, "pid": REQUEST_PID, "tid": rid,
                      "ts": self.clock_us, "args": {"step": step}})
        return ph

    # ------------------------------------------------------------------
    # Engine hooks (all stamped at the current tick's start clock)
    # ------------------------------------------------------------------
    def attach(self, requests) -> None:
        """Reset per-request state for a fresh ``Engine.start`` over these
        requests (a reused tracer appends a new run to the same file;
        ``restore`` does NOT call this — resumed spans stay open)."""
        for r in requests:
            rid = int(r.rid)
            self._phase.pop(rid, None)
            self._req.pop(rid, None)
        self._pending = None

    def note_arrival(self, rid: int, step: int) -> None:
        """A request became visible to the scheduler: open ``queued``.
        Idempotent — later ticks over the same pending request no-op."""
        if rid in self._req:
            return
        self._emit_meta("thread_name", REQUEST_PID, rid, f"req {rid}")
        self._req[rid] = {"queued_us": self.clock_us, "queued_step": step,
                          "chunks": 0}
        self._phase[rid] = "queued"
        self._append({"ph": "B", "name": "queued", "pid": REQUEST_PID,
                      "tid": rid, "ts": self.clock_us,
                      "args": {"step": step}})

    def admitted(self, rid: int, step: int, sid: int, dp_rank: int,
                 pages: int) -> None:
        """``queued -> prefill``: the request took a slot and its pages."""
        if rid not in self._req:       # defensive: arrival was never seen
            self.note_arrival(rid, step)
        self._close_phase(rid, step)
        self._phase[rid] = "prefill"
        self._req[rid].update(admitted_us=self.clock_us, admitted_step=step,
                              slot=sid, dp_rank=dp_rank)
        self._append({"ph": "B", "name": "prefill", "pid": REQUEST_PID,
                      "tid": rid, "ts": self.clock_us,
                      "args": {"step": step, "slot": sid,
                               "dp_rank": dp_rank, "pages": pages}})

    def mark_chunk(self, rid: int, index: int, tokens: int, done: bool,
                   step: int) -> None:
        """One prefill chunk ran this tick; ``done`` moves the request's
        span from ``prefill`` to ``decode``."""
        self._pending = (f"prefill_chunk[{index}]",
                         {"rid": rid, "tokens": tokens, "step": step})
        info = self._req.get(rid)
        if info is not None:
            info["chunks"] = info.get("chunks", 0) + 1
        if done:
            self._close_phase(rid, step)
            self._phase[rid] = "decode"
            if info is not None:
                info["decode_start_us"] = self.clock_us
                info["decode_start_step"] = step
            self._append({"ph": "B", "name": "decode", "pid": REQUEST_PID,
                          "tid": rid, "ts": self.clock_us,
                          "args": {"step": step}})

    def mark_decode(self, rids, step: int) -> None:
        """One batched decode step ran this tick over ``rids``."""
        self._pending = ("decode", {"batch": len(rids),
                                    "rids": [int(r) for r in rids],
                                    "step": step})

    def mark_idle(self, step: int, until: int) -> None:
        """The engine fast-forwarded to the next arrival."""
        self._pending = ("idle", {"from_step": step, "to_step": until,
                                  "skipped": until - step})

    def finished(self, rid: int, step: int, reason: str) -> None:
        """Terminal transition: close whatever span is open and drop an
        instant ``finish:<reason>`` marker (works from any phase —
        ``rejected``/``evicted`` requests die straight out of ``queued``)."""
        self._close_phase(rid, step)
        info = self._req.setdefault(
            rid, {"queued_us": self.clock_us, "queued_step": step,
                  "chunks": 0})
        info.update(finished_us=self.clock_us, finished_step=step,
                    reason=reason)
        self._append({"ph": "i", "name": f"finish:{reason}", "s": "t",
                      "pid": REQUEST_PID, "tid": rid, "ts": self.clock_us,
                      "args": {"step": step}})

    def tick_done(self, step: int, dt: float, counters=None) -> None:
        """End of one engine tick: flush this tick's slice with its real
        wall duration, emit counter samples, advance the engine clock.
        This is the ONLY place the clock moves — every intra-tick event is
        stamped at the tick's start."""
        dur = max(float(dt), 0.0) * 1e6
        if self._pending is not None:
            name, args = self._pending
            self._pending = None
            self._append({"ph": "X", "name": name, "pid": ENGINE_PID,
                          "tid": 0, "ts": self.clock_us, "dur": dur,
                          "args": args}, droppable=True)
        self.clock_us += dur
        self.ticks += 1
        for metric, value in (counters or {}).items():
            self._append({"ph": "C", "name": metric, "pid": ENGINE_PID,
                          "tid": 0, "ts": self.clock_us,
                          "args": {metric: float(value)}}, droppable=True)

    # ------------------------------------------------------------------
    # Export
    # ------------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The trace as a Chrome Trace Event Format document (Perfetto /
        ``chrome://tracing`` loadable).  Spans still open (a preempted or
        in-flight run) are auto-closed at the current clock **on the
        exported copy only** — the live tracer keeps them open so a
        restored engine continues them."""
        evs = list(self.events)
        for rid in sorted(self._phase):
            evs.append({"ph": "E", "name": self._phase[rid],
                        "pid": REQUEST_PID, "tid": rid, "ts": self.clock_us,
                        "args": {"auto_closed": True}})
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def summary(self) -> dict:
        """Per-request latency waterfall (queue-wait vs prefill vs decode,
        in engine-clock us) + p50/p95/p99 across requests — the
        ``EngineReport.trace_summary`` payload and what
        ``scripts/trace_report.py`` renders as markdown."""
        per_req: dict[str, dict] = {}
        cols = {"queue_wait_us": [], "prefill_us": [], "decode_us": [],
                "total_us": []}
        for rid in sorted(self._req):
            info = self._req[rid]
            q = info.get("queued_us")
            a = info.get("admitted_us")
            d = info.get("decode_start_us")
            f = info.get("finished_us")
            row = {
                "queued_step": info.get("queued_step"),
                "admitted_step": info.get("admitted_step"),
                "finished_step": info.get("finished_step"),
                "reason": info.get("reason"),
                "chunks": info.get("chunks", 0),
                "queue_wait_us": a - q if None not in (a, q) else None,
                "prefill_us": d - a if None not in (d, a) else None,
                "decode_us": f - d if None not in (f, d) else None,
                "total_us": f - q if None not in (f, q) else None,
            }
            per_req[str(rid)] = row
            for k in cols:
                if row[k] is not None:
                    cols[k].append(row[k])
        pct = {}
        for k, vs in cols.items():
            if vs:
                pct[k] = {"p50": float(np.percentile(vs, 50)),
                          "p95": float(np.percentile(vs, 95)),
                          "p99": float(np.percentile(vs, 99)),
                          "mean": float(np.mean(vs)), "n": len(vs)}
            else:
                pct[k] = {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                          "mean": 0.0, "n": 0}
        return {"ticks": self.ticks, "events": len(self.events),
                "dropped": self.dropped, "clock_us": self.clock_us,
                "requests": per_req, "percentiles": pct}

    # ------------------------------------------------------------------
    # Snapshot / restore (rides in Engine.snapshot()'s meta leaf, v4)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return {"version": 1,
                "clock_us": self.clock_us,
                "ticks": self.ticks,
                "dropped": self.dropped,
                "events": [dict(e) for e in self.events],
                "phase": {str(r): p for r, p in self._phase.items()},
                "req": {str(r): dict(i) for r, i in self._req.items()},
                "named": sorted(self._named)}

    def restore(self, snap: dict) -> None:
        if not isinstance(snap, dict) or "events" not in snap:
            raise ValueError("not a Tracer snapshot")
        self.clock_us = float(snap["clock_us"])
        self.ticks = int(snap["ticks"])
        self.dropped = int(snap["dropped"])
        self.events = [dict(e) for e in snap["events"]]
        self._phase = {int(r): p for r, p in snap["phase"].items()}
        self._req = {int(r): dict(i) for r, i in snap["req"].items()}
        self._named = set(snap["named"])
        self._pending = None          # the interrupted tick re-runs


# --------------------------------------------------------------------------
# Schema validation (shared by tests, benchmarks, and CI)
# --------------------------------------------------------------------------
def validate_chrome_trace(doc) -> dict:
    """Validate a Chrome Trace Event Format document.

    Checks: known phase types, integer pid/tid on every event, numeric
    non-decreasing ``ts`` per (pid, tid) track, non-negative ``dur`` on
    complete slices, and balanced stack-disciplined ``B``/``E`` pairs whose
    names match.  Raises ``ValueError`` on the first violation; returns
    per-phase event counts on success.
    """
    evs = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(evs, list) or not evs:
        raise ValueError("trace has no traceEvents list")
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list] = {}
    counts: dict[str, int] = {}
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        counts[ph] = counts.get(ph, 0) + 1
        pid, tid = ev.get("pid"), ev.get("tid")
        if not isinstance(pid, int) or not isinstance(tid, int) \
                or isinstance(pid, bool) or isinstance(tid, bool):
            raise ValueError(f"event {i}: pid/tid must be ints, got "
                             f"{pid!r}/{tid!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool):
            raise ValueError(f"event {i}: ts must be numeric, got {ts!r}")
        key = (pid, tid)
        prev = last_ts.get(key)
        if prev is not None and ts < prev:
            raise ValueError(
                f"event {i}: ts {ts} regresses below {prev} on "
                f"pid={pid} tid={tid}")
        last_ts[key] = float(ts)
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: X slice needs dur >= 0, "
                                 f"got {dur!r}")
        elif ph == "B":
            stacks.setdefault(key, []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(f"event {i}: E without open B on "
                                 f"pid={pid} tid={tid}")
            opened = stack.pop()
            name = ev.get("name")
            if name is not None and name != opened:
                raise ValueError(
                    f"event {i}: E {name!r} does not match open B "
                    f"{opened!r} on pid={pid} tid={tid}")
    unbalanced = {k: v for k, v in stacks.items() if v}
    if unbalanced:
        raise ValueError(f"unbalanced B spans left open: {unbalanced}")
    return counts
