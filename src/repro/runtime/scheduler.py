"""Slot scheduler for the continuous-batching TD-VMM serving engine.

Requests stream in with ragged prompts, per-request token budgets, and
arrival times; the engine owns a fixed pool of B decode slots (the batch
dimension of the ONE compiled decode step).  This module is the host-side
bookkeeping: FIFO admission by (arrival_step, rid), per-slot request state,
and the deterministic iteration orders the engine relies on.

Determinism contract: the *values* a request's tokens take depend only on
the request itself (row-wise model math + pinned calibration windows), and
the *schedule* (who is admitted/evicted when) depends only on admission
sequence — never on which physical slot a request landed in.  ``slot_order``
exists to prove that: "fifo" fills the lowest free slot id, "lifo" the
highest, and the regression test asserts identical per-request streams
either way.

The static-batch baseline (``static_baseline``) models the legacy
``launch.serve.serve()`` path on the same trace: uniform batches of B in
arrival order, every sequence padded to the batch max prompt and decoded for
the batch max budget — the wall-step and utilization numbers the engine is
asserted to beat on ragged traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: prompt token ids, a decode budget, and the
    engine step at which it becomes visible to the scheduler.

    The SLA fields are inert unless the engine runs an ``runtime.sla``
    policy (defaults reproduce plain FIFO bit-identically):

    priority:       larger = more urgent; ``SlaScheduler`` ages waiting
                    requests upward so low priority never starves.
    deadline_steps: finish within this many engine steps of arrival.
                    Requests that can never make it (conservatively priced
                    on the full token budget) are rejected at admission.
    joule_budget:   per-request analog energy budget in joules (priced by
                    ``core.energy.serving_energy_model``); a request that
                    exceeds it mid-stream finishes as ``over_budget``.
    """
    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    arrival_step: int = 0
    priority: int = 0
    deadline_steps: Optional[int] = None
    joule_budget: Optional[float] = None

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")
        if self.deadline_steps is not None and self.deadline_steps < 1:
            raise ValueError(f"request {self.rid}: deadline_steps < 1")
        if self.joule_budget is not None and self.joule_budget <= 0.0:
            raise ValueError(f"request {self.rid}: joule_budget <= 0")


@dataclasses.dataclass
class RequestRecord:
    """Engine-owned mutable state + final result for one request.

    finish_reason: "eos" | "max_tokens" | "evicted" (ran out of page budget
    — the engine evicts BEFORE the overflowing cache write can happen, so an
    evicted request still streams every token it produced) | "failed" |
    "rejected" (SLA admission found the request infeasible before any
    compute) | "over_budget" (the request crossed its joule budget
    mid-stream and was finished gracefully)."""
    request: Request
    tokens: list[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    admitted_step: int = -1
    first_token_step: int = -1
    finished_step: int = -1
    analog_ops: float = 0.0
    analog_energy_j: float = 0.0
    reject_reason: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    @property
    def steps_in_system(self) -> int:
        return self.finished_step - self.request.arrival_step

    @property
    def deadline_hit(self) -> Optional[bool]:
        """None if the request declared no deadline; otherwise whether it
        finished (any terminal state except ``rejected``) within
        ``deadline_steps`` of arrival."""
        if self.request.deadline_steps is None:
            return None
        if self.finished_step < 0 or self.finish_reason == "rejected":
            return False
        return self.steps_in_system <= self.request.deadline_steps

    def summary(self) -> dict:
        return {
            "rid": self.request.rid,
            "prompt_len": len(self.request.prompt),
            "max_new_tokens": self.request.max_new_tokens,
            "tokens": list(self.tokens),
            "finish_reason": self.finish_reason,
            "arrival_step": self.request.arrival_step,
            "admitted_step": self.admitted_step,
            "first_token_step": self.first_token_step,
            "finished_step": self.finished_step,
            "steps_in_system": self.steps_in_system,
            "analog_ops": self.analog_ops,
            "analog_energy_j": self.analog_energy_j,
            # --- SLA outcomes -------------------------------------------
            "priority": self.request.priority,
            "deadline_steps": self.request.deadline_steps,
            "deadline_hit": self.deadline_hit,
            "joule_budget": self.request.joule_budget,
            "joules_used": self.analog_energy_j,
            "reject_reason": self.reject_reason,
        }


@dataclasses.dataclass
class Slot:
    """One occupied decode slot."""
    sid: int                  # physical batch row
    seq: int                  # admission sequence number (iteration order)
    record: RequestRecord
    pages: list[int]          # owned page ids, position order
    pos: int = 0              # tokens absorbed into the paged cache
    prefill_done: int = 0     # prompt tokens absorbed so far
    cur_token: int = -1       # next decode step's input token

    @property
    def prompt_len(self) -> int:
        return len(self.record.request.prompt)

    @property
    def prefilling(self) -> bool:
        return self.prefill_done < self.prompt_len


class SlotScheduler:
    """Fixed pool of B slots with FIFO admission by (arrival_step, rid)."""

    def __init__(self, n_slots: int, slot_order: str = "fifo"):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if slot_order not in ("fifo", "lifo"):
            raise ValueError(f"slot_order must be fifo|lifo, got {slot_order!r}")
        self.n_slots = n_slots
        self.slot_order = slot_order
        self.slots: list[Optional[Slot]] = [None] * n_slots
        self.pending: list[Request] = []
        self._seq = 0
        self._head_idx: Optional[int] = None

    def add(self, requests) -> None:
        self.pending.extend(requests)
        self.pending.sort(key=lambda r: (r.arrival_step, r.rid))

    def has_pending(self) -> bool:
        return bool(self.pending)

    def next_arrival(self) -> Optional[int]:
        return min((r.arrival_step for r in self.pending), default=None)

    def head(self, step: int) -> Optional[Request]:
        """Next admissible request (FIFO; None if none has arrived yet).

        Subclasses override the *selection policy* only (which pending
        request is next); they must record the chosen index in
        ``self._head_idx`` so ``pop_head`` removes exactly the request the
        engine just inspected."""
        self._head_idx = None
        if self.pending and self.pending[0].arrival_step <= step:
            self._head_idx = 0
            return self.pending[0]
        return None

    def pop_head(self) -> Request:
        if self._head_idx is None:
            raise RuntimeError("pop_head without a preceding head() hit")
        req = self.pending.pop(self._head_idx)
        self._head_idx = None
        return req

    def free_slot_id(self) -> Optional[int]:
        return next(self.free_slot_ids(), None)

    def free_slot_ids(self):
        """All free slot ids in ``slot_order`` order.  Rank-partitioned
        admission (DP slot pools) walks this until it finds a slot whose
        rank's page region can satisfy the request."""
        order = range(self.n_slots) if self.slot_order == "fifo" \
            else range(self.n_slots - 1, -1, -1)
        return (sid for sid in order if self.slots[sid] is None)

    def place(self, sid: int, record: RequestRecord, pages: list[int]) -> Slot:
        assert self.slots[sid] is None
        slot = Slot(sid=sid, seq=self._seq, record=record, pages=pages)
        self._seq += 1
        self.slots[sid] = slot
        return slot

    def release(self, slot: Slot) -> None:
        assert self.slots[slot.sid] is slot
        self.slots[slot.sid] = None

    def occupied(self) -> list[Slot]:
        """Occupied slots in admission order — every engine-side iteration
        (chunk pick, eviction scan, token harvest) uses this, so scheduling
        decisions are independent of physical slot ids."""
        return sorted((s for s in self.slots if s is not None),
                      key=lambda s: s.seq)


def static_baseline(requests, n_slots: int, chunk: int) -> dict:
    """Simulate the legacy uniform-batch ``serve()`` schedule on a trace.

    Batches of ``n_slots`` in arrival order; each batch pays
    ``ceil(max_prompt / chunk)`` prefill steps (normalized to the engine's
    chunk currency) plus ``max_budget`` decode steps for *every* slot —
    the padding the paged engine exists to reclaim.  Arrival gaps are
    ignored (generous to the baseline).  Decode utilization counts a slot
    step as useful only while its request still wants tokens.
    """
    reqs = sorted(requests, key=lambda r: (r.arrival_step, r.rid))
    wall = decode_steps = useful = 0
    for i in range(0, len(reqs), n_slots):
        batch = reqs[i:i + n_slots]
        max_prompt = max(len(r.prompt) for r in batch)
        max_gen = max(r.max_new_tokens for r in batch)
        wall += -(-max_prompt // chunk) + max_gen
        decode_steps += max_gen
        useful += sum(r.max_new_tokens for r in batch)
    return {
        "wall_steps": wall,
        "decode_steps": decode_steps,
        "generated_tokens": useful,
        "utilization": useful / max(decode_steps * n_slots, 1),
        "batches": -(-len(reqs) // n_slots),
    }
