"""Continuous-batching TD-VMM serving engine.

The paper's system discipline — fixed conversion circuitry, time-multiplexed
inputs — maps onto serving as: keep exactly TWO jit-compiled step functions
(one fixed-shape chunked-prefill step, one fixed-shape batched-decode step,
both closing over the model's pinned ``CalibrationState``) and multiplex a
ragged request stream through them.  Ragged traffic is absorbed by:

  * a fixed pool of B decode **slots** (the decode step's batch dimension),
    admitted FIFO by arrival (``runtime/scheduler.py``);
  * a **paged** KV cache: attention KV lives in fixed-size pages owned per
    request via block tables (``runtime/paged_cache.py``), so short requests
    stop paying ``max_len`` memory and finished requests' pages recycle;
  * **chunked prefill**: prompts are absorbed ``chunk`` tokens per step
    through the single compiled prefill shape, interleaved with decode.

Request lifecycle::

    pending --admit(slot+pages)--> prefilling --last chunk--> decoding
       |                                                         |
       +--> evicted (prompt exceeds page budget)                 +--> eos
                                                                 +--> max_tokens
                                                                 +--> evicted
                                                   (page budget exhausted —
                                                    evicted BEFORE the
                                                    overflowing write)

Capacity overflow is an *admission-control* event here, not a numeric one:
the dense-cache decode path NaN-poisons a row that decodes past capacity
(failing loudly under jit), but the engine never lets that write happen —
a request whose next token has no page is finished with reason "evicted"
before the step runs, so neighbor slots' logits stay NaN-free (regression
test: ``tests/test_engine.py``).

Energy: every processed token is priced by the resolved plan's analog-tile
geometry (``core.energy.serving_energy_model``) into per-request Op counts
and joules — the fJ/Op currency of the paper, measured at request level.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import energy as energy_model
from repro.core.calibration import CalibrationState, apply_calibration
from repro.models import model
from repro.runtime.paged_cache import PagePool, pages_for
from repro.runtime.scheduler import (Request, RequestRecord, SlotScheduler,
                                     static_baseline)

__all__ = ["Engine", "EngineConfig", "EngineReport", "Request",
           "static_baseline"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/capacity knobs (all jit-static: they pin the two
    compiled step shapes)."""
    slots: int = 4                # B — decode batch width
    page_size: int = 16           # tokens per KV page
    num_pages: int = 64           # shared pool size (excludes the trash page)
    max_pages_per_slot: int = 0   # per-request page budget; 0 = num_pages
    chunk: int = 32               # C — prefill tokens absorbed per step
    eos_id: Optional[int] = None  # greedy decode stops on this token
    tile_n: int = 256             # analog tile edge for energy accounting
    slot_order: str = "fifo"      # free-slot pick order (determinism test)
    max_steps: int = 100_000      # runaway guard

    @property
    def resolved_max_pages(self) -> int:
        p = self.max_pages_per_slot or self.num_pages
        return min(p, self.num_pages)


@dataclasses.dataclass
class EngineReport:
    """Aggregate run stats + per-request records (rid order)."""
    requests: list[dict]
    steps: int
    prefill_steps: int
    decode_steps: int
    idle_steps: int
    wall_s: float
    prompt_tokens: int
    generated_tokens: int
    utilization: float
    evictions: int
    nan_logit_steps: int
    page_high_water: int
    page_bytes: int
    kv_high_water_bytes: int
    analog_ops: float
    analog_energy_j: float
    fj_per_op: float
    tokens_per_joule: float
    compiled_steps: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Engine:
    """Continuous-batching serving engine over ONE model + calibration.

    ``calib`` pins every enabled digital-boundary site's readout window at
    jit time.  The engine *requires* pinned windows on enabled sites (or
    ``output_calibration=False``): a data-calibrated per-call window is a
    max over the whole batch, which would couple slots together and break
    the per-request bit-identity contract.
    """

    def __init__(self, cfg: ModelConfig, params,
                 engine_cfg: EngineConfig = EngineConfig(),
                 calib: Optional[CalibrationState] = None):
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise NotImplementedError(
                f"engine supports attention families, not {cfg.family!r} "
                "(use launch.serve --static for SSM/hybrid)")
        if cfg.input_mode != "tokens":
            raise NotImplementedError("engine serves token-input models")
        if cfg.swa_window is not None:
            raise NotImplementedError(
                "engine + sliding-window attention not supported yet")
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg
        self.calib = calib
        self.cfg_serving = apply_calibration(cfg, calib)
        self._check_pinned_windows()
        self.energy = energy_model.serving_energy_model(
            self.cfg_serving, engine_cfg.tile_n)

        self._prefill = jax.jit(
            lambda p, b, c: model.prefill_chunk(p, b, c, cfg, calib=calib),
            donate_argnums=(2,))
        self._decode = jax.jit(
            lambda p, b, c: model.decode_slots(p, b, c, cfg, calib=calib),
            donate_argnums=(2,))

        # Per-page HBM bytes across all layers (for the high-water stat).
        shapes = jax.eval_shape(lambda: model.init_paged_caches(
            cfg, engine_cfg.num_pages, engine_cfg.page_size))
        total = sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                    for leaf in jax.tree.leaves(shapes))
        self.page_bytes = int(total // (engine_cfg.num_pages + 1))

    def _check_pinned_windows(self):
        for site, sc in self.cfg_serving.resolved_tdvmm_plan.sites:
            if (sc.enabled and sc.io_quantize and sc.output_calibration
                    and sc.out_scale is None):
                raise ValueError(
                    f"engine requires a pinned readout window on enabled "
                    f"site {site!r}: per-call data calibration is a max over "
                    f"the whole batch and couples requests together.  Run "
                    f"models.model.calibrate(...) and pass calib=, or set "
                    f"out_scale/output_calibration=False in the plan.")

    def compiled_steps(self) -> int:
        """How many distinct step executables exist (the invariant: 2)."""
        sizes = []
        for fn in (self._prefill, self._decode):
            get = getattr(fn, "_cache_size", None)
            sizes.append(int(get()) if get is not None else -1)
        return sum(sizes) if all(s >= 0 for s in sizes) else -1

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> EngineReport:
        """Serve a trace to completion; returns the report (token streams,
        finish reasons, energy, utilization, memory high-water)."""
        ecfg = self.ecfg
        ps, cap_pages = ecfg.page_size, ecfg.resolved_max_pages
        vocab = self.cfg.vocab_size
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("duplicate request ids in trace")

        caches = model.init_paged_caches(self.cfg, ecfg.num_pages, ps)
        pool = PagePool(ecfg.num_pages, ps)
        sched = SlotScheduler(ecfg.slots, ecfg.slot_order)
        sched.add(requests)
        records = {r.rid: RequestRecord(r) for r in requests}

        steps = prefill_steps = decode_steps = idle_steps = 0
        prompt_tokens = generated_tokens = evictions = nan_steps = 0
        util_samples: list[float] = []
        ops_tok = self.energy["ops_per_token"]
        e_tok = self.energy["energy_per_token_j"]
        t0 = time.time()

        def finish(slot, reason: str):
            nonlocal evictions
            slot.record.finish_reason = reason
            slot.record.finished_step = steps
            if reason == "evicted":
                evictions += 1
            pool.free(slot.pages)
            sched.release(slot)

        def emit(slot, tok: int):
            """Stream one generated token; finish on eos/budget."""
            rec = slot.record
            rec.tokens.append(tok)
            if rec.first_token_step < 0:
                rec.first_token_step = steps
            if ecfg.eos_id is not None and tok == ecfg.eos_id:
                finish(slot, "eos")
            elif len(rec.tokens) >= rec.request.max_new_tokens:
                finish(slot, "max_tokens")
            else:
                slot.cur_token = tok

        def account(rec, n: int):
            rec.analog_ops += n * ops_tok
            rec.analog_energy_j += n * e_tok

        while True:
            if steps > ecfg.max_steps:
                raise RuntimeError(f"engine exceeded max_steps={ecfg.max_steps}")
            # --- admission (FIFO; head-of-line blocks on pool pressure) ---
            while True:
                req = sched.head(steps)
                if req is None:
                    break
                need = pages_for(len(req.prompt), ps)
                if need > cap_pages:
                    # can never fit: reject without occupying a slot
                    sched.pop_head()
                    rec = records[req.rid]
                    rec.admitted_step = rec.finished_step = steps
                    rec.finish_reason = "evicted"
                    evictions += 1
                    continue
                sid = sched.free_slot_id()
                if sid is None:
                    break
                pages = pool.alloc(need)
                if pages is None:
                    break
                sched.pop_head()
                rec = records[req.rid]
                rec.admitted_step = steps
                sched.place(sid, rec, pages)

            occupied = sched.occupied()
            prefilling = [s for s in occupied if s.prefilling]
            decoding = [s for s in occupied if not s.prefilling]

            if prefilling:
                # --- one prefill chunk (oldest admission first) -----------
                slot = prefilling[0]
                prompt = slot.record.request.prompt
                start = slot.prefill_done
                n = min(ecfg.chunk, len(prompt) - start)
                tokens = np.zeros((1, ecfg.chunk), np.int32)
                tokens[0, :n] = prompt[start:start + n]
                row = np.full((cap_pages,), pool.trash_page, np.int32)
                row[:len(slot.pages)] = slot.pages
                batch = {"inputs": jnp.asarray(tokens),
                         "block_row": jnp.asarray(row),
                         "offset": jnp.int32(start), "valid": jnp.int32(n)}
                logits, caches = self._prefill(self.params, batch, caches)
                prefill_steps += 1
                slot.prefill_done += n
                slot.pos += n
                prompt_tokens += n
                account(slot.record, n)
                if not slot.prefilling:
                    row_logits = logits[0, 0]
                    tok = int(jnp.argmax(row_logits[:vocab]))
                    nan_steps += int(bool(jnp.isnan(row_logits).any()))
                    generated_tokens += 1
                    account(slot.record, 1)
                    emit(slot, tok)
                steps += 1

            elif decoding:
                # --- evict-before-poison: secure every slot's write page --
                runnable = []
                for slot in decoding:
                    if slot.pos >= len(slot.pages) * ps:
                        if len(slot.pages) >= cap_pages or \
                                (new := pool.alloc(1)) is None:
                            finish(slot, "evicted")
                            continue
                        slot.pages.extend(new)
                    runnable.append(slot)
                if not runnable:
                    continue          # state changed (evictions); re-plan
                b = ecfg.slots
                tokens = np.zeros((b, 1), np.int32)
                pos = np.zeros((b,), np.int32)
                tables = np.full((b, cap_pages), pool.trash_page, np.int32)
                active = np.zeros((b,), bool)
                for slot in runnable:
                    tokens[slot.sid, 0] = slot.cur_token
                    pos[slot.sid] = slot.pos
                    tables[slot.sid, :len(slot.pages)] = slot.pages
                    active[slot.sid] = True
                batch = {"inputs": jnp.asarray(tokens),
                         "block_tables": jnp.asarray(tables),
                         "pos": jnp.asarray(pos),
                         "active": jnp.asarray(active)}
                logits, caches = self._decode(self.params, batch, caches)
                decode_steps += 1
                util_samples.append(len(runnable) / b)
                toks = np.asarray(jnp.argmax(logits[:, 0, :vocab], axis=-1))
                nans = np.asarray(jnp.isnan(logits[:, 0]).any(axis=-1))
                for slot in runnable:              # admission order
                    nan_steps += int(nans[slot.sid])
                    slot.pos += 1
                    generated_tokens += 1
                    account(slot.record, 1)
                    emit(slot, int(toks[slot.sid]))
                steps += 1

            elif sched.has_pending():
                # nothing runnable: fast-forward to the next arrival
                nxt = sched.next_arrival()
                if nxt is None or nxt <= steps:
                    raise RuntimeError(
                        "scheduler stall: pending request cannot be admitted "
                        "into an empty engine (page budget inconsistency)")
                idle_steps += nxt - steps
                steps = nxt
            else:
                break

        wall = time.time() - t0
        tot_ops = sum(r.analog_ops for r in records.values())
        tot_e = sum(r.analog_energy_j for r in records.values())
        return EngineReport(
            requests=[records[r.rid].summary() for r in requests],
            steps=steps,
            prefill_steps=prefill_steps,
            decode_steps=decode_steps,
            idle_steps=idle_steps,
            wall_s=wall,
            prompt_tokens=prompt_tokens,
            generated_tokens=generated_tokens,
            utilization=(float(np.mean(util_samples)) if util_samples else 0.0),
            evictions=evictions,
            nan_logit_steps=nan_steps,
            page_high_water=pool.high_water,
            page_bytes=self.page_bytes,
            kv_high_water_bytes=(pool.high_water + 1) * self.page_bytes,
            analog_ops=tot_ops,
            analog_energy_j=tot_e,
            fj_per_op=(tot_e / tot_ops * 1e15) if tot_ops else 0.0,
            tokens_per_joule=(generated_tokens / tot_e) if tot_e else 0.0,
            compiled_steps=self.compiled_steps(),
        )
