"""Continuous-batching TD-VMM serving engine.

The paper's system discipline — fixed conversion circuitry, time-multiplexed
inputs — maps onto serving as: keep exactly TWO jit-compiled step functions
(one fixed-shape chunked-prefill step, one fixed-shape batched-decode step)
and multiplex a ragged request stream through them.  Ragged traffic is
absorbed by:

  * a fixed pool of B decode **slots** (the decode step's batch dimension),
    admitted FIFO by arrival (``runtime/scheduler.py``);
  * a **paged** KV cache: attention KV lives in fixed-size pages owned per
    request via block tables (``runtime/paged_cache.py``), so short requests
    stop paying ``max_len`` memory and finished requests' pages recycle;
  * **chunked prefill**: prompts are absorbed ``chunk`` tokens per step
    through the single compiled prefill shape, interleaved with decode.

Calibration enters the compiled steps as **runtime-operand windows**
(``core.calibration.runtime_windows``): the pinned ``CalibrationState``
threads through the two jits as a site -> f32 array dict argument, NOT as
baked jit-static constants — bit-identical to the baked path (the kernels
already pin windows behind optimization barriers), and hot-swappable: a
recaptured state replaces the dict values between steps with zero
recompilation, keeping ``compiled_steps == 2`` under online recalibration.

Request lifecycle::

    pending --admit(slot+pages)--> prefilling --last chunk--> decoding
       |                                                         |
       +--> evicted (prompt exceeds page budget)                 +--> eos
       +--> rejected (SLA admission: deadline- or                +--> max_tokens
            joule-infeasible, before any compute)                +--> evicted
                                                                 +--> failed
                                                                 +--> over_budget
                                                   (evicted: page budget
                                                    exhausted — finished
                                                    BEFORE the overflowing
                                                    write; failed: a
                                                    persistently failing
                                                    compiled step, blamed
                                                    on one request so the
                                                    engine keeps serving;
                                                    over_budget: joule
                                                    budget crossed
                                                    mid-stream under an
                                                    SLA policy)

Fault tolerance (``FaultConfig``): a ``fault.PreemptionGuard`` (or an
injected ``faultinject.PreemptAt``) unwinds the run between steps to a
**snapshot** — the full in-flight state (scheduler queue, slots, block
tables, page-pool free list, paged KV pools, emitted tokens, energy
accounting, runtime windows) as one checkpointable pytree — with the hard
contract that ``restore`` + ``resume`` replays the remaining trace
bit-identically to an uninterrupted run.  ``fault.retry_step`` wraps both
compiled steps (transient failures recover invisibly; persistent ones
degrade to a single ``failed`` request with neighbors bit-equal), and
``StragglerMonitor`` / ``Heartbeat`` feed the report.

Energy: every processed token is priced by the resolved plan's analog-tile
geometry (``core.energy.serving_energy_model``) into per-request Op counts
and joules — the fJ/Op currency of the paper, measured at request level.

Telemetry & SLA (``runtime/telemetry.py`` / ``runtime/sla.py``): pass
``sink=`` to stream per-tick metrics (step latency, queue depth, page
pressure, fJ/Op, retries, drift) through a ``MetricsSink`` with online
spike/regression alerts, and ``sla=`` to schedule with priority-aging
admission, deadline/joule admission control, and mid-stream ``over_budget``
enforcement.  Both are host-side bookkeeping between the two compiled
steps (``compiled_steps == 2`` holds), both ride in ``snapshot()``, and
with both disabled every existing trace replays bit-identically.

Tracing & per-site attribution (``runtime/trace.py`` / PR 10): pass
``tracer=`` to record the whole request lifecycle as Chrome-trace spans
(requests as threads, engine ticks as slices, counter tracks) stamped on a
cumulative engine clock that rides ``snapshot()`` (meta v4) — a killed,
restored engine continues the SAME trace file seamlessly.  Every report
carries ``site_attribution``: the run's priced tokens broken down by plan
site from ``core.energy.site_attribution``, whose per-site table sums
bit-exactly to the aggregate ``analog_ops``/``analog_energy_j``/``fj_per_op``
columns, with chained sites' skipped I/O conversions shown explicitly.
With ``DriftConfig.observe_every`` and a sink, per-site readout clip rates
stream as live ``clip_rate.<site>`` series for ``AlertRule`` wiring.  All
of it is host-side, between the two compiled steps: traced runs are
bit-identical to untraced and ``compiled_steps == 2`` holds.

Mesh-sharded serving: pass ``mesh=`` (axes ``data`` x ``model``) and the two
compiled steps run tensor/expert/data-parallel — params take the training
``launch/sharding._rules`` TP layout (DP replicated: no ZeRO gathers at
inference), paged pools shard their head dims over ``model``
(``sharding.paged_specs``) while the page dim stays replicated, and the DP
axes multiply the slot pool: ``total_slots = dp * ecfg.slots`` with slot id
``dp_rank * ecfg.slots + local_slot`` and one page region per rank
(``PagePool(ranks=dp)``).  The scheduler stays host-side and deterministic;
admission walks free slots in ``slot_order`` and draws pages from the slot's
rank region.  A (1, 1) mesh is bit-identical to no mesh; snapshots are
device_get on save and re-sharded on restore, so the kill-at-any-step
bit-identity contract survives under a mesh.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import energy as energy_model
from repro.core.calibration import (CalibrationState, apply_calibration,
                                    clip_rate_metrics)
from repro.kernels.tdvmm import ops as tdvmm_ops
from repro.launch import meshctx
from repro.launch import sharding as shardlib
from repro.launch.mesh import axis_info
from repro.models import model
from repro.runtime import fault
from repro.runtime import sla as sla_policy
from repro.runtime.paged_cache import PagePool, pages_for
from repro.runtime.scheduler import (Request, RequestRecord, Slot,
                                     SlotScheduler, static_baseline)

__all__ = ["Engine", "EngineConfig", "EngineReport", "FaultConfig",
           "DriftConfig", "Request", "static_baseline"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape/capacity knobs (all jit-static: they pin the two
    compiled step shapes)."""
    slots: int = 4                # B — decode batch width
    page_size: int = 16           # tokens per KV page
    num_pages: int = 64           # shared pool size (excludes the trash page)
    max_pages_per_slot: int = 0   # per-request page budget; 0 = num_pages
    chunk: int = 32               # C — prefill tokens absorbed per step
    eos_id: Optional[int] = None  # greedy decode stops on this token
    tile_n: int = 256             # analog tile edge for energy accounting
    slot_order: str = "fifo"      # free-slot pick order (determinism test)
    max_steps: int = 100_000      # runaway guard

    @property
    def resolved_max_pages(self) -> int:
        p = self.max_pages_per_slot or self.num_pages
        return min(p, self.num_pages)


@dataclasses.dataclass
class DriftConfig:
    """Online drift detection + recalibration policy.

    Every ``check_every`` engine steps the engine runs an *eager* probe pass
    (``models.model.drift_probe`` — the same capture as ``model.calibrate``,
    never a third compiled program) on ``probe_batch`` and compares the
    fresh windows and per-site readout clip rates against the pinned ones.
    Drift is declared when any site clips more than ``clip_threshold`` of
    its |z| mass against its pinned window, or any window moved by more than
    ``window_tol`` in |log ratio|; with ``recalibrate`` the fresh
    ``CalibrationState`` is hot-swapped in between steps (no recompile).

    ``observe_every`` > 0 additionally streams per-site readout clip rates
    into the engine's ``MetricsSink`` as ``clip_rate.<site>`` series every
    that many steps (same eager probe, never a third compiled program) —
    typically much more often than ``check_every``, so an ``AlertRule`` on
    a single site's clip rate fires minutes before the full drift check
    would recalibrate."""
    probe_batch: dict
    check_every: int = 16
    clip_threshold: float = 0.01
    window_tol: float = 0.25
    max_len: int = 0
    recalibrate: bool = True
    observe_every: int = 0


@dataclasses.dataclass
class FaultConfig:
    """Fault wiring for one ``Engine.run`` / ``resume``.

    ``guard`` polls for preemption (install it for real SIGTERM handling;
    injected preemptions use the run's internal guard); ``snapshot_dir``
    makes a preemption exit through ``checkpoint.save_engine_snapshot``.
    ``retries``/``backoff_s``/``backoff_cap_s``/``jitter`` parameterize
    ``fault.retry_step`` around both compiled steps.  ``injector`` is a
    ``faultinject.FaultInjector`` schedule; ``drift`` a ``DriftConfig``."""
    guard: Optional[fault.PreemptionGuard] = None
    snapshot_dir: Optional[str] = None
    snapshot_keep: int = 3
    retries: int = 2
    backoff_s: float = 0.01
    backoff_cap_s: float = 1.0
    jitter: float = 0.1
    heartbeat: Optional[fault.Heartbeat] = None
    monitor: Optional[fault.StragglerMonitor] = None
    injector: Optional[Any] = None
    drift: Optional[DriftConfig] = None


@dataclasses.dataclass
class EngineReport:
    """Aggregate run stats + per-request records (rid order)."""
    requests: list[dict]
    steps: int
    prefill_steps: int
    decode_steps: int
    idle_steps: int
    wall_s: float
    prompt_tokens: int
    generated_tokens: int
    utilization: float
    evictions: int
    nan_logit_steps: int
    page_high_water: int
    page_bytes: int
    kv_high_water_bytes: int
    analog_ops: float
    analog_energy_j: float
    fj_per_op: float
    tokens_per_joule: float
    compiled_steps: int
    # --- fault tolerance & drift (defaults keep old constructors valid) ---
    preempted: bool = False
    snapshot_path: Optional[str] = None
    failed: int = 0
    step_retries: int = 0
    stragglers: int = 0
    straggler_ewma_s: float = 0.0
    heartbeats: int = 0
    recalibrations: int = 0
    drift_events: list = dataclasses.field(default_factory=list)
    # --- SLA & telemetry (PR 8) -------------------------------------------
    rejected: int = 0
    over_budget: int = 0
    deadline_hits: int = 0
    deadline_misses: int = 0
    alerts: int = 0
    telemetry: Optional[dict] = None
    # --- mesh-sharded serving (PR 9) --------------------------------------
    devices: int = 1              # mesh size (1 = meshless engine)
    total_slots: int = 0          # dp_size * ecfg.slots aggregate decode width
    # --- tracing & per-site attribution (PR 10) ---------------------------
    tokens_priced: int = 0        # exact token count behind the energy totals
    site_attribution: Optional[dict] = None   # energy.site_attribution table
    trace_summary: Optional[dict] = None      # Tracer.summary() when tracing
    autotune: Optional[dict] = None           # kernels.tdvmm autotune report

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RunState:
    """Everything one serving run mutates — the snapshot/restore unit
    (device caches + host bookkeeping + cumulative counters)."""
    requests: list[Request]
    records: dict[int, RequestRecord]
    sched: SlotScheduler
    pool: PagePool
    caches: Any
    steps: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    idle_steps: int = 0
    prompt_tokens: int = 0
    generated_tokens: int = 0
    evictions: int = 0
    nan_steps: int = 0
    failed: int = 0
    rejected: int = 0
    over_budget: int = 0
    analog_ops: float = 0.0       # running totals (order-exact for the
    analog_energy_j: float = 0.0  # fj_per_op telemetry stream)
    tokens_priced: int = 0        # exact count of tokens through _account
    step_retries: int = 0
    recalibrations: int = 0
    last_drift_check: int = 0
    last_clip_obs: int = 0
    wall_s: float = 0.0
    util_samples: list = dataclasses.field(default_factory=list)
    drift_events: list = dataclasses.field(default_factory=list)
    preempted: bool = False
    snapshot_path: Optional[str] = None


class Engine:
    """Continuous-batching serving engine over ONE model + calibration.

    ``calib`` pins every enabled digital-boundary site's readout window.
    The engine *requires* pinned windows on enabled sites (or
    ``output_calibration=False``): a data-calibrated per-call window is a
    max over the whole batch, which would couple slots together and break
    the per-request bit-identity contract.  The pinned windows thread into
    the two compiled steps as runtime operands (see module docstring), so
    ``set_calibration`` can hot-swap them between steps without recompiling.
    """

    def __init__(self, cfg: ModelConfig, params,
                 engine_cfg: EngineConfig = EngineConfig(),
                 calib: Optional[CalibrationState] = None,
                 sla: Optional[sla_policy.SlaConfig] = None,
                 sink: Optional[Any] = None,
                 mesh: Optional[Any] = None,
                 tracer: Optional[Any] = None):
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise NotImplementedError(
                f"engine supports attention families, not {cfg.family!r} "
                "(use launch.serve --static for SSM/hybrid)")
        if cfg.input_mode != "tokens":
            raise NotImplementedError("engine serves token-input models")
        if cfg.swa_window is not None:
            raise NotImplementedError(
                "engine + sliding-window attention not supported yet")
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.calib = calib
        self.sla = sla
        self.sink = sink
        self.tracer = tracer

        # --- mesh: TP shards each step's math, DP multiplies the slot pool.
        # The scheduler stays host-side and meshless — slot id =
        # dp_rank * ecfg.slots + local_slot, and every rank's page region
        # mirrors the single-device layout, so a (1,1) mesh is bit-identical
        # to no mesh at all.
        self.mesh = mesh
        if mesh is not None:
            info = axis_info(mesh)
            self._dp_axes = info["dp_axes"]
            self._tp_axis = info["tp_axis"]
            self.dp = shardlib._dp_size(mesh, self._dp_axes)
        else:
            self._dp_axes, self._tp_axis, self.dp = (), None, 1
        self.total_slots = self.dp * engine_cfg.slots

        self.cfg_serving = apply_calibration(cfg, calib)
        self._check_pinned_windows()
        self.energy = energy_model.serving_energy_model(
            self.cfg_serving, engine_cfg.tile_n,
            n_devices=(mesh.size if mesh is not None else 1))

        # Params: TP layout from the training _rules (heads / ffn-hidden /
        # vocab over 'model'); dp_axes=() replicates over DP — serving never
        # wants ZeRO-3 gathers in the step — while expert banks still shard
        # over DP under moe.impl='ep'.
        if mesh is not None:
            p_specs = shardlib.param_specs(
                params, cfg, mesh, dp_axes=(), ep_axes=self._dp_axes)
            params = jax.device_put(params, shardlib.to_named(p_specs, mesh))
        self.params = params

        # Windows as runtime operands: the jits trace over the window dict
        # (same sites + shapes -> same executable), never bake the values.
        self._windows = self._place_windows(
            calib.as_arrays() if calib is not None else {})

        # Per-page HBM bytes across all layers (for the high-water stat) and
        # the paged-pool shardings the two steps are pinned to.
        shapes = jax.eval_shape(lambda: model.init_paged_caches(
            cfg, engine_cfg.num_pages, engine_cfg.page_size, ranks=self.dp))
        total = sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                    for leaf in jax.tree.leaves(shapes))
        self.page_bytes = int(
            total // (self.dp * (engine_cfg.num_pages + 1)))
        self._cache_sh = None
        self._batch_sh = {}
        jit_kw: dict[str, Any] = {}
        if mesh is not None:
            self._cache_sh = shardlib.to_named(
                shardlib.paged_specs(shapes, cfg, mesh), mesh)
            self._batch_sh = {
                kind: shardlib.to_named(shardlib.slot_specs(mesh, kind), mesh)
                for kind in ("prefill", "decode")}
            # Pinning the cache output sharding to the input sharding is what
            # keeps compiled_steps == 2: a drifting output layout would make
            # the next call's donated input a new signature.
            jit_kw["out_shardings"] = (None, self._cache_sh)
        self._prefill = jax.jit(
            lambda p, b, c, w: model.prefill_chunk(p, b, c, cfg, windows=w),
            donate_argnums=(2,), **jit_kw)
        self._decode = jax.jit(
            lambda p, b, c, w: model.decode_slots(p, b, c, cfg, windows=w),
            donate_argnums=(2,), **jit_kw)

        self._st: Optional[RunState] = None
        self._fault: Optional[FaultConfig] = None
        self._guard: Optional[fault.PreemptionGuard] = None

    def _place_windows(self, windows: dict) -> dict:
        """Replicate the window operands across the mesh (meshless: as-is).
        Expert-parallel (E,) slicing happens inside the MoE shard_map, which
        takes these as explicit operands — see models/moe.py."""
        if self.mesh is None or not windows:
            return dict(windows)
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(self.mesh, P())
        return {site: jax.device_put(jnp.asarray(v), rep)
                for site, v in windows.items()}

    def _check_pinned_windows(self):
        for site, sc in self.cfg_serving.resolved_tdvmm_plan.sites:
            if (sc.enabled and sc.io_quantize and sc.output_calibration
                    and sc.out_scale is None):
                raise ValueError(
                    f"engine requires a pinned readout window on enabled "
                    f"site {site!r}: per-call data calibration is a max over "
                    f"the whole batch and couples requests together.  Run "
                    f"models.model.calibrate(...) and pass calib=, or set "
                    f"out_scale/output_calibration=False in the plan.")

    def compiled_steps(self) -> int:
        """How many distinct step executables exist (the invariant: 2)."""
        sizes = []
        for fn in (self._prefill, self._decode):
            get = getattr(fn, "_cache_size", None)
            sizes.append(int(get()) if get is not None else -1)
        return sum(sizes) if all(s >= 0 for s in sizes) else -1

    # ------------------------------------------------------------------
    # Calibration hot-swap
    # ------------------------------------------------------------------
    def set_calibration(self, calib: CalibrationState) -> None:
        """Swap the pinned windows between steps — values only, never
        structure, so the two compiled step executables are reused as-is
        (``compiled_steps`` stays 2)."""
        new = calib.as_arrays()
        if set(new) != set(self._windows):
            raise ValueError(
                f"hot-swap calibration covers sites {sorted(new)} but the "
                f"engine serves {sorted(self._windows)} — site structure is "
                "jit-static; rebuild the engine for a different plan")
        for site, arr in new.items():
            if arr.shape != self._windows[site].shape:
                raise ValueError(
                    f"hot-swap window for site {site!r} has shape "
                    f"{arr.shape}, pinned is {self._windows[site].shape}")
        self._windows = self._place_windows(new)
        self.calib = calib

    def pinned_calibration(self) -> CalibrationState:
        """The currently pinned windows as a ``CalibrationState``."""
        return CalibrationState(windows=dict(self._windows))

    # ------------------------------------------------------------------
    # Preemption
    # ------------------------------------------------------------------
    def request_preemption(self) -> None:
        """Flag the active run for snapshot-and-exit before its next step
        (what a SIGTERM handler — or an injected preemption — calls)."""
        if self._guard is None:
            self._guard = fault.PreemptionGuard()
        self._guard.requested = True

    # ------------------------------------------------------------------
    # Run lifecycle
    # ------------------------------------------------------------------
    def _make_sched(self) -> SlotScheduler:
        ecfg = self.ecfg
        if self.sla is not None:
            return sla_policy.SlaScheduler(self.total_slots, ecfg.slot_order,
                                           self.sla)
        return SlotScheduler(self.total_slots, ecfg.slot_order)

    def start(self, requests: list[Request]) -> None:
        """Initialize a fresh run over a trace (allocates pools/caches)."""
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("duplicate request ids in trace")
        ecfg = self.ecfg
        sched = self._make_sched()
        sched.add(requests)
        caches = model.init_paged_caches(
            self.cfg, ecfg.num_pages, ecfg.page_size, ranks=self.dp)
        if self._cache_sh is not None:
            caches = jax.device_put(caches, self._cache_sh)
        if self.tracer is not None:
            self.tracer.attach(requests)
        self._st = RunState(
            requests=list(requests),
            records={r.rid: RequestRecord(r) for r in requests},
            sched=sched,
            pool=PagePool(ecfg.num_pages, ecfg.page_size, ranks=self.dp),
            caches=caches,
        )

    def run(self, requests: list[Request],
            fault_cfg: Optional[FaultConfig] = None) -> EngineReport:
        """Serve a trace to completion (or preemption); returns the report
        (token streams, finish reasons, energy, utilization, memory
        high-water, fault/drift accounting)."""
        self.start(requests)
        return self._drive(fault_cfg)

    def resume(self,
               fault_cfg: Optional[FaultConfig] = None) -> EngineReport:
        """Continue a run restored by ``restore`` (or a run that exited
        preempted in-process) to completion."""
        if self._st is None:
            raise RuntimeError("no run state: call run() or restore() first")
        self._st.preempted = False
        self._st.snapshot_path = None
        return self._drive(fault_cfg)

    def _drive(self, fault_cfg: Optional[FaultConfig]) -> EngineReport:
        st = self._st
        fc = self._fault = fault_cfg
        guard = (fc.guard if fc is not None else None) \
            or fault.PreemptionGuard()
        self._guard = guard
        t0 = time.time()
        try:
            while True:
                if fc is not None and fc.injector is not None:
                    fc.injector.on_tick(self, st.steps)
                if guard.requested:
                    raise fault.Preempted(f"preempted at step {st.steps}")
                t1 = time.time()
                alive = self.tick()
                dt = time.time() - t1
                if self.tracer is not None:
                    self.tracer.tick_done(st.steps, dt, {
                        "queue_depth": len(st.sched.pending),
                        "active_slots": len(st.sched.occupied()),
                        "pages_in_use": st.pool.in_use,
                        "fj_per_op": (st.analog_energy_j / st.analog_ops
                                      * 1e15) if st.analog_ops else 0.0,
                    })
                if self.sink is not None:
                    self._observe_tick(dt)
                if fc is not None:
                    if fc.monitor is not None:
                        fc.monitor.record(st.steps, dt)
                    if fc.heartbeat is not None:
                        fc.heartbeat.beat(st.steps)
                    if (fc.drift is not None and fc.drift.observe_every
                            and self.sink is not None and st.steps -
                            st.last_clip_obs >= fc.drift.observe_every):
                        st.last_clip_obs = st.steps
                        self._observe_clips(fc.drift)
                    if (fc.drift is not None and st.steps -
                            st.last_drift_check >= fc.drift.check_every):
                        st.last_drift_check = st.steps
                        self._drift_check(fc.drift)
                if not alive:
                    break
        except fault.Preempted:
            st.preempted = True
            st.wall_s += time.time() - t0
            if self.sink is not None:
                self.sink.flush()        # metrics land before the snapshot
            if fc is not None and fc.snapshot_dir is not None:
                from repro.checkpoint import checkpoint as ckpt
                path = ckpt.save_engine_snapshot(
                    self.snapshot(), fc.snapshot_dir, step=st.steps,
                    keep=fc.snapshot_keep)
                st.snapshot_path = str(path)
            return self.report()
        st.wall_s += time.time() - t0
        return self.report()

    def _observe_tick(self, dt: float) -> None:
        """Feed the metrics sink after one tick — pure host-side floats, no
        device sync beyond what ``tick`` already did, so telemetry never
        perturbs the compiled-step story (``compiled_steps == 2``)."""
        st = self._st
        step = st.steps          # the tick just executed landed us here
        sink = self.sink
        sink.observe("step_latency_s", dt, step)
        sink.observe("queue_depth", len(st.sched.pending), step)
        sink.observe("active_slots", len(st.sched.occupied()), step)
        sink.observe("page_in_use", st.pool.in_use, step)
        sink.observe("page_high_water", st.pool.high_water, step)
        sink.observe("generated_tokens", st.generated_tokens, step)
        sink.observe("step_retries", st.step_retries, step)
        if st.analog_ops > 0.0:
            sink.observe("fj_per_op",
                         st.analog_energy_j / st.analog_ops * 1e15, step)

    # ------------------------------------------------------------------
    # One scheduling tick
    # ------------------------------------------------------------------
    def tick(self) -> bool:
        """One engine iteration: admit, then run one prefill chunk OR one
        batched decode step OR fast-forward to the next arrival.  Returns
        False when the trace is fully served.  Engine state is always
        consistent between ticks — snapshots happen exactly here."""
        st = self._st
        ecfg = self.ecfg
        if st.steps > ecfg.max_steps:
            raise RuntimeError(f"engine exceeded max_steps={ecfg.max_steps}")
        if self.tracer is not None:
            for req in st.sched.pending:     # open `queued` spans (idempotent)
                if req.arrival_step <= st.steps:
                    self.tracer.note_arrival(req.rid, st.steps)
        self._admit()
        occupied = st.sched.occupied()
        prefilling = [s for s in occupied if s.prefilling]
        decoding = [s for s in occupied if not s.prefilling]
        if prefilling:
            self._prefill_tick(prefilling[0])
            return True
        if decoding:
            self._decode_tick(decoding)
            return True
        if st.sched.has_pending():
            nxt = st.sched.next_arrival()
            if nxt is None or nxt <= st.steps:
                raise RuntimeError(
                    "scheduler stall: pending request cannot be admitted "
                    "into an empty engine (page budget inconsistency)")
            if self.tracer is not None:
                self.tracer.mark_idle(st.steps, nxt)
            st.idle_steps += nxt - st.steps
            st.steps = nxt
            return True
        return False

    def _admit(self) -> None:
        """Admission (FIFO, or SLA priority-with-aging when ``sla=`` is
        set); head-of-line blocks on pool pressure.  SLA infeasibility is
        checked FIRST — a rejected request never occupies a slot, never
        allocates a page, and never reaches a compiled step."""
        st = self._st
        ecfg = self.ecfg
        cap_pages = ecfg.resolved_max_pages
        while True:
            req = st.sched.head(st.steps)
            if req is None:
                break
            if self.sla is not None:
                verdict = sla_policy.admission_verdict(
                    req, st.steps, ecfg.chunk, self.energy, self.sla)
                if verdict is not None:
                    st.sched.pop_head()
                    rec = st.records[req.rid]
                    rec.admitted_step = rec.finished_step = st.steps
                    rec.finish_reason = "rejected"
                    rec.reject_reason = verdict
                    st.rejected += 1
                    if self.tracer is not None:
                        self.tracer.finished(req.rid, st.steps, "rejected")
                    continue
            need = pages_for(len(req.prompt), ecfg.page_size)
            if need > cap_pages:
                # can never fit: reject without occupying a slot
                st.sched.pop_head()
                rec = st.records[req.rid]
                rec.admitted_step = rec.finished_step = st.steps
                rec.finish_reason = "evicted"
                st.evictions += 1
                if self.tracer is not None:
                    self.tracer.finished(req.rid, st.steps, "evicted")
                continue
            # Walk free slots in slot_order; a slot's DP rank decides which
            # page region serves it (slot id = dp_rank * slots + local), so
            # admission tries each rank's pool until one fits.  With dp=1
            # this is exactly the legacy free_slot_id + alloc sequence.
            sid = pages = None
            for cand in st.sched.free_slot_ids():
                got = st.pool.alloc(need, rank=cand // self.ecfg.slots)
                if got is not None:
                    sid, pages = cand, got
                    break
            if sid is None:
                break
            st.sched.pop_head()
            rec = st.records[req.rid]
            rec.admitted_step = st.steps
            st.sched.place(sid, rec, pages)
            if self.tracer is not None:
                self.tracer.admitted(req.rid, st.steps, sid,
                                     sid // self.ecfg.slots, len(pages))

    def _finish(self, slot: Slot, reason: str) -> None:
        st = self._st
        slot.record.finish_reason = reason
        slot.record.finished_step = st.steps
        if self.tracer is not None:
            self.tracer.finished(slot.record.request.rid, st.steps, reason)
        if reason == "evicted":
            st.evictions += 1
        elif reason == "failed":
            st.failed += 1
        elif reason == "over_budget":
            st.over_budget += 1
        st.pool.free(slot.pages)
        st.sched.release(slot)

    def _emit(self, slot: Slot, tok: int) -> None:
        """Stream one generated token; finish on eos/budget.

        Under an SLA policy a request whose accumulated joules crossed its
        ``joule_budget`` is finished ``over_budget`` — the token it just
        produced still streams (the work was done and priced), the slot and
        pages recycle, and neighbor streams are untouched (the same
        row-isolation argument as the ``failed`` path)."""
        rec = slot.record
        rec.tokens.append(tok)
        if rec.first_token_step < 0:
            rec.first_token_step = self._st.steps
        if self.ecfg.eos_id is not None and tok == self.ecfg.eos_id:
            self._finish(slot, "eos")
        elif (self.sla is not None and rec.request.joule_budget is not None
                and rec.analog_energy_j > rec.request.joule_budget):
            self._finish(slot, "over_budget")
        elif len(rec.tokens) >= rec.request.max_new_tokens:
            self._finish(slot, "max_tokens")
        else:
            slot.cur_token = tok

    def _account(self, rec: RequestRecord, n: int) -> None:
        st = self._st
        ops, e_j = energy_model.token_cost(self.energy, n)
        rec.analog_ops += ops
        rec.analog_energy_j += e_j
        st.analog_ops += ops
        st.analog_energy_j += e_j
        st.tokens_priced += n         # exact int behind site_attribution

    def _run_compiled(self, kind: str, fn, *args):
        """The retry boundary around one compiled step.  Injected faults
        raise before ``fn`` is invoked, so the donated cache buffers of a
        failed attempt were never consumed."""
        fc = self._fault
        st = self._st

        def call():
            if fc is not None and fc.injector is not None:
                fc.injector.check(kind, st.steps)
            if self.mesh is not None:
                # Model code reads the mesh context at trace time (shard_map
                # in moe/common); only the first call per step kind traces,
                # later ones hit the executable cache.
                with meshctx.use_mesh(self.mesh, self._dp_axes,
                                      self._tp_axis):
                    return fn(*args)
            return fn(*args)

        if fc is None:
            return call()

        def on_retry(attempt, e):
            st.step_retries += 1

        return fault.retry_step(
            call, retries=fc.retries, backoff_s=fc.backoff_s,
            backoff_cap_s=fc.backoff_cap_s, jitter=fc.jitter,
            on_retry=on_retry, guard=self._guard)

    def _prefill_tick(self, slot: Slot) -> None:
        """One prefill chunk (oldest admission first)."""
        st = self._st
        ecfg = self.ecfg
        vocab = self.cfg.vocab_size
        prompt = slot.record.request.prompt
        start = slot.prefill_done
        n = min(ecfg.chunk, len(prompt) - start)
        tokens = np.zeros((1, ecfg.chunk), np.int32)
        tokens[0, :n] = prompt[start:start + n]
        row = np.full((ecfg.resolved_max_pages,), st.pool.trash_page,
                      np.int32)
        row[:len(slot.pages)] = slot.pages
        batch = {"inputs": jnp.asarray(tokens),
                 "block_row": jnp.asarray(row),
                 "offset": jnp.int32(start), "valid": jnp.int32(n)}
        if self.mesh is not None:
            batch = jax.device_put(batch, self._batch_sh["prefill"])
        try:
            logits, caches = self._run_compiled(
                "prefill", self._prefill, self.params, batch, st.caches,
                self._windows)
        except RuntimeError as e:
            # Persistent step failure: this slot IS the step's work — finish
            # it as failed (graceful degradation) and re-plan next tick.
            del e
            self._finish(slot, "failed")
            return
        st.caches = caches
        st.prefill_steps += 1
        slot.prefill_done += n
        slot.pos += n
        st.prompt_tokens += n
        self._account(slot.record, n)
        if self.tracer is not None:
            self.tracer.mark_chunk(
                slot.record.request.rid, start // ecfg.chunk, n,
                done=not slot.prefilling, step=st.steps)
        if not slot.prefilling:
            row_logits = logits[0, 0]
            tok = int(jnp.argmax(row_logits[:vocab]))
            st.nan_steps += int(bool(jnp.isnan(row_logits).any()))
            st.generated_tokens += 1
            self._account(slot.record, 1)
            self._emit(slot, tok)
        st.steps += 1

    def _decode_tick(self, decoding: list[Slot]) -> None:
        """One batched decode step over all decoding slots."""
        st = self._st
        ecfg = self.ecfg
        ps, cap_pages = ecfg.page_size, ecfg.resolved_max_pages
        vocab = self.cfg.vocab_size
        # --- evict-before-poison: secure every slot's write page ----------
        runnable = []
        for slot in decoding:
            if slot.pos >= len(slot.pages) * ps:
                if len(slot.pages) >= cap_pages or \
                        (new := st.pool.alloc(
                            1, rank=slot.sid // ecfg.slots)) is None:
                    self._finish(slot, "evicted")
                    continue
                slot.pages.extend(new)
            runnable.append(slot)
        if not runnable:
            return                # state changed (evictions); re-plan
        b = self.total_slots
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        tables = np.full((b, cap_pages), st.pool.trash_page, np.int32)
        active = np.zeros((b,), bool)
        for slot in runnable:
            tokens[slot.sid, 0] = slot.cur_token
            pos[slot.sid] = slot.pos
            tables[slot.sid, :len(slot.pages)] = slot.pages
            active[slot.sid] = True
        batch = {"inputs": jnp.asarray(tokens),
                 "block_tables": jnp.asarray(tables),
                 "pos": jnp.asarray(pos),
                 "active": jnp.asarray(active)}
        if self.mesh is not None:
            batch = jax.device_put(batch, self._batch_sh["decode"])
        try:
            logits, caches = self._run_compiled(
                "decode", self._decode, self.params, batch, st.caches,
                self._windows)
        except RuntimeError as e:
            # Persistent step failure: blame the attributed request (or the
            # oldest runnable slot), finish it failed, re-plan next tick.
            # Decode rows are independent (row-wise math + trash-page
            # isolation), so the survivors' streams are bit-unchanged.
            rid = getattr(e, "rid", None)
            culprit = next(
                (s for s in runnable if s.record.request.rid == rid), None)
            if culprit is None:
                culprit = min(runnable, key=lambda s: s.seq)
            self._finish(culprit, "failed")
            return
        st.caches = caches
        st.decode_steps += 1
        if self.tracer is not None:
            self.tracer.mark_decode(
                [s.record.request.rid for s in runnable], st.steps)
        st.util_samples.append(len(runnable) / b)
        toks = np.asarray(jnp.argmax(logits[:, 0, :vocab], axis=-1))
        nans = np.asarray(jnp.isnan(logits[:, 0]).any(axis=-1))
        for slot in runnable:              # admission order
            st.nan_steps += int(nans[slot.sid])
            slot.pos += 1
            st.generated_tokens += 1
            self._account(slot.record, 1)
            self._emit(slot, int(toks[slot.sid]))
        st.steps += 1

    # ------------------------------------------------------------------
    # Drift detection + online recalibration
    # ------------------------------------------------------------------
    def _observe_clips(self, dc: DriftConfig) -> None:
        """Stream per-site readout clip rates into the sink as live
        ``clip_rate.<site>`` series (``DriftConfig.observe_every``).  Same
        eager ``drift_probe`` capture as the full drift check — host-side,
        never a third compiled program — but run far more often and with
        no recalibration decision attached, so a per-site ``AlertRule``
        sees a rising clip rate well before ``check_every`` comes due."""
        st = self._st
        _, clips = model.drift_probe(
            self.params, dc.probe_batch, self.cfg,
            self.pinned_calibration(), dc.max_len)
        for name, v in clip_rate_metrics(clips).items():
            self.sink.observe(name, v, st.steps)

    def _drift_check(self, dc: DriftConfig) -> None:
        st = self._st
        pinned = self.pinned_calibration()
        fresh, clips = model.drift_probe(
            self.params, dc.probe_batch, self.cfg, pinned, dc.max_len)
        ratios = pinned.drift_ratios(fresh)
        max_clip = max(clips.values(), default=0.0)
        max_dev = max((abs(math.log(max(r, 1e-12)))
                       for r in ratios.values()), default=0.0)
        if self.sink is not None:
            self.sink.observe("drift_max_clip_rate", float(max_clip),
                              st.steps)
            self.sink.observe("drift_max_log_ratio", float(max_dev),
                              st.steps)
            for name, v in clip_rate_metrics(clips).items():
                self.sink.observe(name, v, st.steps)
        drifted = max_clip > dc.clip_threshold or max_dev > dc.window_tol
        if not drifted:
            return
        event = {"step": st.steps, "max_clip_rate": float(max_clip),
                 "max_log_ratio": float(max_dev),
                 "clip_rates": {k: float(v) for k, v in clips.items()},
                 "ratios": {k: float(v) for k, v in ratios.items()},
                 "recalibrated": bool(dc.recalibrate)}
        st.drift_events.append(event)
        if dc.recalibrate:
            self.set_calibration(fresh)
            st.recalibrations += 1

    # ------------------------------------------------------------------
    # Snapshot / restore
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """The full in-flight state as ONE checkpointable pytree.

        Leaves: ``caches/...`` (paged KV pools, host-copied), ``windows/<site>``
        (the currently pinned — possibly recalibrated — readout windows), and
        ``meta`` (a uint8-encoded JSON blob of every host-side structure:
        requests, records, scheduler queue + slots + block tables, page-pool
        free list, cumulative counters).  ``params``/model weights are NOT
        included — weight provenance belongs to the model checkpoint; the
        restoring process constructs the Engine with the same params.

        Only valid between ticks (where the engine always is when a
        preemption unwinds it)."""
        st = self._st
        if st is None:
            raise RuntimeError("no run state to snapshot")
        meta = {
            "version": 4,
            "dp": self.dp,
            "ecfg": dataclasses.asdict(self.ecfg),
            "model": {"vocab_size": self.cfg.vocab_size,
                      "n_layers": self.cfg.n_layers,
                      "d_model": self.cfg.d_model,
                      "family": self.cfg.family},
            "sla": (dataclasses.asdict(self.sla)
                    if self.sla is not None else None),
            "telemetry": (self.sink.snapshot()
                          if self.sink is not None else None),
            "trace": (self.tracer.snapshot()
                      if self.tracer is not None else None),
            "requests": [
                {"rid": r.rid, "prompt": list(r.prompt),
                 "max_new_tokens": r.max_new_tokens,
                 "arrival_step": r.arrival_step,
                 "priority": r.priority,
                 "deadline_steps": r.deadline_steps,
                 "joule_budget": r.joule_budget} for r in st.requests],
            "records": {
                str(rid): {
                    "tokens": list(rec.tokens),
                    "finish_reason": rec.finish_reason,
                    "admitted_step": rec.admitted_step,
                    "first_token_step": rec.first_token_step,
                    "finished_step": rec.finished_step,
                    "analog_ops": rec.analog_ops,
                    "analog_energy_j": rec.analog_energy_j,
                    "reject_reason": rec.reject_reason,
                } for rid, rec in st.records.items()},
            "sched": {
                "pending": [r.rid for r in st.sched.pending],
                "seq": st.sched._seq,
                "slots": [
                    None if s is None else {
                        "sid": s.sid, "seq": s.seq,
                        "rid": s.record.request.rid,
                        "pages": list(s.pages), "pos": s.pos,
                        "prefill_done": s.prefill_done,
                        "cur_token": s.cur_token,
                    } for s in st.sched.slots]},
            "pool": {"free": st.pool.free_lists(),
                     "high_water": st.pool.high_water},
            "counters": {
                "steps": st.steps, "prefill_steps": st.prefill_steps,
                "decode_steps": st.decode_steps,
                "idle_steps": st.idle_steps,
                "prompt_tokens": st.prompt_tokens,
                "generated_tokens": st.generated_tokens,
                "evictions": st.evictions, "nan_steps": st.nan_steps,
                "failed": st.failed, "rejected": st.rejected,
                "over_budget": st.over_budget,
                "analog_ops": st.analog_ops,
                "analog_energy_j": st.analog_energy_j,
                "tokens_priced": st.tokens_priced,
                "step_retries": st.step_retries,
                "recalibrations": st.recalibrations,
                "last_drift_check": st.last_drift_check,
                "last_clip_obs": st.last_clip_obs,
                "wall_s": st.wall_s,
                "util_samples": [float(u) for u in st.util_samples],
                "drift_events": st.drift_events,
            },
        }
        blob = np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8)
        return {
            "caches": jax.tree.map(np.asarray, st.caches),
            "windows": {site: np.asarray(v)
                        for site, v in self._windows.items()},
            "meta": blob,
        }

    def restore(self, snap) -> None:
        """Rebuild in-flight state from ``snapshot()`` output — the nested
        pytree itself or the flat name -> array dict
        ``checkpoint.load_engine_snapshot`` returns.  Validates the engine
        shape (EngineConfig + model identity + window structure) against the
        snapshot; ``resume`` then continues the trace bit-identically."""
        from repro.checkpoint import checkpoint as ckpt
        flat = dict(ckpt.leaf_paths(snap))
        if "meta" not in flat:
            raise ValueError("engine snapshot missing 'meta' leaf")
        meta = json.loads(np.asarray(flat["meta"], np.uint8)
                          .tobytes().decode("utf-8"))
        mine = dataclasses.asdict(self.ecfg)
        if meta["ecfg"] != mine:
            raise ValueError(
                f"engine snapshot was taken with EngineConfig "
                f"{meta['ecfg']}, this engine has {mine} — the config pins "
                "the compiled step shapes and cannot change across resume")
        snap_dp = meta.get("dp", 1)
        if snap_dp != self.dp:
            raise ValueError(
                f"engine snapshot was taken with data-parallel size "
                f"{snap_dp}, this engine has {self.dp} — the DP slot-pool "
                "dimension pins the decode batch and page-pool layout")
        model_id = {"vocab_size": self.cfg.vocab_size,
                    "n_layers": self.cfg.n_layers,
                    "d_model": self.cfg.d_model, "family": self.cfg.family}
        if meta["model"] != model_id:
            raise ValueError(
                f"engine snapshot model {meta['model']} != {model_id}")
        snap_sla = meta.get("sla")
        mine_sla = (dataclasses.asdict(self.sla)
                    if self.sla is not None else None)
        if snap_sla != mine_sla:
            raise ValueError(
                f"engine snapshot was taken under SLA policy {snap_sla}, "
                f"this engine has {mine_sla} — the policy drives admission "
                "order and must match for a bit-identical resume")
        snap_telemetry = meta.get("telemetry")
        if snap_telemetry is not None:
            if self.sink is None:
                raise ValueError(
                    "engine snapshot carries telemetry state but this "
                    "engine has no sink — construct it with sink= to "
                    "resume the metric series and alert history")
            self.sink.restore(snap_telemetry)
        snap_trace = meta.get("trace")
        if snap_trace is not None:
            if self.tracer is None:
                raise ValueError(
                    "engine snapshot carries trace state but this engine "
                    "has no tracer — construct it with tracer= to resume "
                    "the span stream as one continuous trace")
            self.tracer.restore(snap_trace)

        # --- windows (the pinned state at snapshot time, which may be a
        # recalibrated one — restoring it is what keeps resume bit-exact) ---
        win_names = {k[len("windows/"):] for k in flat
                     if k.startswith("windows/")}
        if win_names != set(self._windows):
            raise ValueError(
                f"snapshot windows {sorted(win_names)} != engine sites "
                f"{sorted(self._windows)}")
        restored = {}
        for site in win_names:
            arr = np.asarray(flat[f"windows/{site}"], np.float32)
            if arr.shape != self._windows[site].shape:
                raise ValueError(
                    f"snapshot window {site!r} shape {arr.shape} != "
                    f"{self._windows[site].shape}")
            restored[site] = jnp.asarray(arr)
        self._windows = self._place_windows(restored)
        self.calib = CalibrationState(windows=dict(restored))

        # --- device caches (re-sharded onto the mesh when one is set) -----
        ecfg = self.ecfg
        shapes = jax.eval_shape(lambda: model.init_paged_caches(
            self.cfg, ecfg.num_pages, ecfg.page_size, ranks=self.dp))
        sh_flat = dict(ckpt.leaf_paths(self._cache_sh)) \
            if self._cache_sh is not None else {}
        leaves = []
        for name, sh in ckpt.leaf_paths(shapes):
            arr = flat.get(f"caches/{name}")
            if arr is None:
                raise KeyError(f"engine snapshot missing cache leaf {name}")
            if tuple(arr.shape) != tuple(sh.shape) or \
                    str(arr.dtype) != str(sh.dtype):
                raise ValueError(
                    f"cache leaf {name}: snapshot {arr.shape}/{arr.dtype} "
                    f"!= expected {sh.shape}/{sh.dtype}")
            if name in sh_flat:
                leaves.append(jax.device_put(np.asarray(arr), sh_flat[name]))
            else:
                leaves.append(jnp.asarray(arr))
        caches = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(shapes), leaves)

        # --- host bookkeeping --------------------------------------------
        requests = [Request(rid=r["rid"], prompt=tuple(r["prompt"]),
                            max_new_tokens=r["max_new_tokens"],
                            arrival_step=r["arrival_step"],
                            priority=r.get("priority", 0),
                            deadline_steps=r.get("deadline_steps"),
                            joule_budget=r.get("joule_budget"))
                    for r in meta["requests"]]
        by_rid = {r.rid: r for r in requests}
        records = {}
        for rid_s, rd in meta["records"].items():
            rid = int(rid_s)
            rec = RequestRecord(by_rid[rid])
            rec.tokens = list(rd["tokens"])
            rec.finish_reason = rd["finish_reason"]
            rec.admitted_step = rd["admitted_step"]
            rec.first_token_step = rd["first_token_step"]
            rec.finished_step = rd["finished_step"]
            rec.analog_ops = rd["analog_ops"]
            rec.analog_energy_j = rd["analog_energy_j"]
            rec.reject_reason = rd.get("reject_reason")
            records[rid] = rec
        sched = self._make_sched()
        sched.pending = [by_rid[rid] for rid in meta["sched"]["pending"]]
        sched._seq = meta["sched"]["seq"]
        for sd in meta["sched"]["slots"]:
            if sd is None:
                continue
            slot = Slot(sid=sd["sid"], seq=sd["seq"],
                        record=records[sd["rid"]], pages=list(sd["pages"]),
                        pos=sd["pos"], prefill_done=sd["prefill_done"],
                        cur_token=sd["cur_token"])
            sched.slots[sd["sid"]] = slot
        pool = PagePool(ecfg.num_pages, ecfg.page_size, ranks=self.dp)
        free = meta["pool"]["free"]
        if meta["version"] < 3:       # v2: one flat free list (dp == 1)
            free = [free]
        pool.restore_free(free)
        pool.high_water = meta["pool"]["high_water"]

        c = meta["counters"]
        # tokens_priced landed in meta v4; older snapshots reconstruct it
        # exactly from the (integer-valued) op totals.
        opt = self.energy["ops_per_token"]
        tokens_priced = c.get("tokens_priced")
        if tokens_priced is None:
            ops_total = c.get("analog_ops",
                              sum(r.analog_ops for r in records.values()))
            tokens_priced = int(round(ops_total / opt)) if opt else 0
        self._st = RunState(
            requests=requests, records=records, sched=sched, pool=pool,
            caches=caches, steps=c["steps"],
            prefill_steps=c["prefill_steps"],
            decode_steps=c["decode_steps"], idle_steps=c["idle_steps"],
            prompt_tokens=c["prompt_tokens"],
            generated_tokens=c["generated_tokens"],
            evictions=c["evictions"], nan_steps=c["nan_steps"],
            failed=c["failed"], rejected=c.get("rejected", 0),
            over_budget=c.get("over_budget", 0),
            analog_ops=c.get("analog_ops",
                             sum(r.analog_ops for r in records.values())),
            analog_energy_j=c.get(
                "analog_energy_j",
                sum(r.analog_energy_j for r in records.values())),
            tokens_priced=tokens_priced,
            step_retries=c["step_retries"],
            recalibrations=c["recalibrations"],
            last_drift_check=c["last_drift_check"],
            last_clip_obs=c.get("last_clip_obs", 0), wall_s=c["wall_s"],
            util_samples=list(c["util_samples"]),
            drift_events=list(c["drift_events"]),
        )

    # ------------------------------------------------------------------
    def report(self) -> EngineReport:
        """The report for the current (finished, preempted, or in-flight)
        run state."""
        st = self._st
        if st is None:
            raise RuntimeError("no run state to report")
        fc = self._fault
        records, requests = st.records, st.requests
        if self.sink is not None:
            self.sink.flush()     # buffered emitters reach disk with report
        # Aggregates are DERIVED from the per-site attribution table (the
        # same exact tokens_priced count expanded per site), so the site
        # table sums bit-exactly to analog_ops/analog_energy_j/fj_per_op.
        attr = energy_model.site_attribution(self.energy, st.tokens_priced)
        tot_ops = attr["ops"]
        tot_e = attr["energy_j"]
        # Deadline outcomes over ADMITTED finished requests: a rejection is
        # admission control working (counted in `rejected`), not a miss.
        hits = [r.deadline_hit for r in records.values()
                if r.done and r.finish_reason != "rejected"
                and r.deadline_hit is not None]
        return EngineReport(
            requests=[records[r.rid].summary() for r in requests],
            steps=st.steps,
            prefill_steps=st.prefill_steps,
            decode_steps=st.decode_steps,
            idle_steps=st.idle_steps,
            wall_s=st.wall_s,
            prompt_tokens=st.prompt_tokens,
            generated_tokens=st.generated_tokens,
            utilization=(float(np.mean(st.util_samples))
                         if st.util_samples else 0.0),
            evictions=st.evictions,
            nan_logit_steps=st.nan_steps,
            page_high_water=st.pool.high_water,
            page_bytes=self.page_bytes,
            kv_high_water_bytes=(st.pool.high_water + 1) * self.page_bytes,
            analog_ops=tot_ops,
            analog_energy_j=tot_e,
            fj_per_op=(tot_e / tot_ops * 1e15) if tot_ops else 0.0,
            tokens_per_joule=(st.generated_tokens / tot_e) if tot_e else 0.0,
            compiled_steps=self.compiled_steps(),
            preempted=st.preempted,
            snapshot_path=st.snapshot_path,
            failed=st.failed,
            step_retries=st.step_retries,
            stragglers=(fc.monitor.stragglers
                        if fc is not None and fc.monitor is not None else 0),
            straggler_ewma_s=(fc.monitor.ewma
                              if fc is not None and fc.monitor is not None
                              else 0.0),
            heartbeats=(fc.heartbeat.beats
                        if fc is not None and fc.heartbeat is not None
                        else 0),
            recalibrations=st.recalibrations,
            drift_events=list(st.drift_events),
            rejected=st.rejected,
            over_budget=st.over_budget,
            deadline_hits=sum(1 for h in hits if h),
            deadline_misses=sum(1 for h in hits if not h),
            alerts=(len(self.sink.alerts) if self.sink is not None else 0),
            telemetry=(self.sink.summary()
                       if self.sink is not None else None),
            devices=(self.mesh.size if self.mesh is not None else 1),
            total_slots=self.total_slots,
            tokens_priced=st.tokens_priced,
            site_attribution=attr,
            trace_summary=(self.tracer.summary()
                           if self.tracer is not None else None),
            autotune=tdvmm_ops.autotune_report(),
        )
