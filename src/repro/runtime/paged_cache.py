"""Paged KV cache plumbing: page pool, block tables, and step contexts.

The serving engine replaces the dense per-slot ``init_caches(batch, max_len)``
allocation with a **page pool**: attention KV lives in fixed-size pages of
``page_size`` token positions, and every decode slot owns an ordered list of
page ids (its *block-table row*).  Position ``p`` of a slot lives at
``(row[p // page_size], p % page_size)`` — the same page ids index every
layer's pool, so allocation happens once per slot, not per layer.

Why this matters for the TD-VMM story: the analog tiles are weight-stationary
and the conversion circuitry is fixed, so serving wants ONE compiled prefill
step and ONE compiled decode step with pinned shapes (pinned readout windows
ride along as jit-static calibration).  Paging is what lets ragged requests
multiplex through those fixed shapes without paying ``batch * max_len`` HBM
for every short request: a finished request's pages go back to the pool and
the next request reuses them.

Layout per attention layer (see ``models.attention.init_paged_cache``):

    k, v        (num_pages + 1, page_size, n_kv, head_dim)
    k/v_scale   (num_pages + 1, page_size, n_kv)            int8 KV mode

The **last** page is the trash page: writes from inactive slots (and padded
prefill-chunk rows) are steered there instead of being predicated out, so the
compiled step has no data-dependent control flow.  The trash page is never
read (no block-table row references it as a *valid* position), so its
nondeterministic contents never touch logits.

Under a data-parallel mesh the pool grows a leading rank dimension
(``PagePool(..., ranks=dp)``): the device layout stacks ``ranks`` copies of
the ``num_pages + 1`` region and the global trash page is the last row of the
last rank — see the ``PagePool`` docstring for the id arithmetic.

Host side, ``PagePool`` is a deterministic free-list allocator (lowest free
id first) that tracks the in-use high-water mark — the paged counterpart of
the dense path's ``batch * max_len`` footprint, asserted smaller on ragged
traces by ``benchmarks/bench_serving.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax


class PrefillChunkCtx(NamedTuple):
    """Per-chunk step inputs for one slot's chunked prefill (fixed shapes).

    block_row: (P,) int32 — the slot's page ids, padded with the trash page.
    offset:    ()   int32 — global position of the chunk's first token.
    valid:     ()   int32 — real tokens in this chunk (rest is padding).
    """
    block_row: jax.Array
    offset: jax.Array
    valid: jax.Array


class DecodeCtx(NamedTuple):
    """Per-step inputs for the batched decode over all B slots.

    block_tables: (B, P) int32 — page ids per slot (trash-padded).
    pos:          (B,)   int32 — tokens already absorbed per slot (the new
                                 token's KV is written at position ``pos``).
    active:       (B,)   bool  — occupied decode slots; inactive rows write
                                 to the trash page and their logits are
                                 ignored by the engine.
    """
    block_tables: jax.Array
    pos: jax.Array
    active: jax.Array


class PagePool:
    """Deterministic host-side page allocator (lowest free id first).

    Determinism matters: the scheduler invariant is that the same trace +
    seed produces identical per-request streams regardless of slot
    assignment order, and page ids feed the compiled steps' block tables.

    With ``ranks > 1`` (the DP slot-pool dimension) the pool is partitioned
    into per-rank regions: rank ``r`` owns global page ids
    ``[r*(num_pages+1), r*(num_pages+1) + num_pages)`` — each rank's region
    mirrors the single-rank device layout of ``num_pages`` real pages plus
    one trash row, so rank 0's ids (and thus block tables, and thus streams)
    are bit-identical to the ``ranks=1`` pool.  Allocation is per-rank
    (``alloc(n, rank=r)``); a slot's pages never cross ranks.  Per-rank
    trash rows below the last rank exist in the device layout but are
    unused — only the single *global* trash page is ever written.
    """

    def __init__(self, num_pages: int, page_size: int, ranks: int = 1):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"need >= 1 page of >= 1 token, got "
                             f"{num_pages} x {page_size}")
        if ranks < 1:
            raise ValueError(f"need >= 1 rank, got {ranks}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.ranks = ranks
        self._stride = num_pages + 1
        # Per-rank free lists, each kept sorted ascending (global ids).
        self._free = [list(range(r * self._stride, r * self._stride + num_pages))
                      for r in range(ranks)]
        self.high_water = 0

    @property
    def trash_page(self) -> int:
        """Id of the write-sink page: the LAST device row across all ranks
        (``num_pages`` when ranks == 1, matching the legacy layout)."""
        return self.ranks * self._stride - 1

    @property
    def total_pages(self) -> int:
        """Aggregate real (non-trash) pages across all ranks."""
        return self.ranks * self.num_pages

    @property
    def in_use(self) -> int:
        return self.total_pages - self.free_pages

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    def _rank_of(self, page: int) -> int:
        rank = page // self._stride
        if not (0 <= rank < self.ranks) or page % self._stride >= self.num_pages:
            raise ValueError(f"free of out-of-range page {page}")
        return rank

    def alloc(self, n: int, rank: int = 0) -> Optional[list[int]]:
        """Take the n lowest free page ids of ``rank``, or None (nothing
        taken) if that rank's region can't satisfy the request."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if not (0 <= rank < self.ranks):
            raise ValueError(f"alloc on rank {rank} of {self.ranks}")
        free = self._free[rank]
        if n > len(free):
            return None
        pages, self._free[rank] = free[:n], free[n:]
        self.high_water = max(self.high_water, self.in_use)
        return pages

    def free(self, pages: list[int]) -> None:
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in free: {pages}")
        for p in pages:
            rank = self._rank_of(p)
            if p in self._free[rank]:
                raise ValueError(f"double free of page {p}")
        for p in pages:
            self._free[self._rank_of(p)].append(p)
        for f in self._free:
            f.sort()

    def free_lists(self) -> list[list[int]]:
        """Snapshot of the per-rank free lists (copies, for checkpointing)."""
        return [list(f) for f in self._free]

    def restore_free(self, lists: list[list[int]]) -> None:
        """Restore free lists from a snapshot (inverse of ``free_lists``)."""
        if len(lists) != self.ranks:
            raise ValueError(f"snapshot has {len(lists)} rank free-lists, "
                             f"pool has {self.ranks}")
        self._free = [sorted(int(p) for p in f) for f in lists]


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold n_tokens positions (at least one)."""
    return max(1, -(-n_tokens // page_size))
