"""Serving/runtime subsystem: fault tolerance, paged KV cache, slot
scheduler, and the continuous-batching engine."""
