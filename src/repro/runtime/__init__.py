"""Serving/runtime subsystem: fault tolerance, paged KV cache, slot
scheduler, telemetry, request-level tracing, and the continuous-batching
engine."""

from repro.runtime.trace import Tracer, validate_chrome_trace

__all__ = ["Tracer", "validate_chrome_trace"]
