"""Fault-tolerance runtime: preemption handling, step retry, straggler watch.

Designed for the 1000+-node regime where *something is always failing*:

  * PreemptionGuard — SIGTERM/SIGINT handler: sets a flag the serve/train
    loop polls so it snapshots and exits cleanly inside the eviction grace
    window (``runtime.engine`` snapshots its full in-flight state and the
    resumed engine replays the ragged trace bit-identically).
  * Preempted       — the control-flow exception a polled loop raises to
    unwind to its snapshot-and-exit path.  Deliberately NOT a RuntimeError:
    ``retry_step`` must never swallow a preemption as a transient failure.
  * retry_step      — bounded retry with capped, jittered exponential
    backoff for transient executor failures (on real fleets: ICI timeouts,
    preempted remote workers).  A persistent failure re-raises with the
    attempt count attached; restart then auto-resumes from the latest valid
    checkpoint.  An optional ``guard`` is polled between attempts so a
    preempted process snapshots instead of burning its grace window on
    backoff sleeps.
  * StragglerMonitor — per-step wall-time EWMA + threshold: logs and counts
    outlier steps (on multi-host fleets this feeds the decision to evict a
    slow host and re-shard — here it is the single-process analogue).
  * heartbeat file  — liveness marker an external babysitter can watch.
"""
from __future__ import annotations

import dataclasses
import json
import random
import signal
import time
from pathlib import Path
from typing import Callable, Optional


class Preempted(Exception):
    """Raised by a loop that observed ``PreemptionGuard.requested`` — unwind
    to the snapshot-and-exit path.  Not a RuntimeError on purpose:
    ``retry_step`` retries RuntimeErrors and must let this propagate."""


class PreemptionGuard:
    def __init__(self):
        self.requested = False
        self._installed = False

    def install(self):
        if self._installed:
            return self
        self._prev = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:  # non-main thread (tests)
                pass
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in getattr(self, "_prev", {}).items():
            signal.signal(sig, prev)
        self._prev = {}
        self._installed = False


def retry_step(fn: Callable, *args, retries: int = 2, backoff_s: float = 1.0,
               backoff_cap_s: float = 30.0, jitter: float = 0.1,
               on_retry: Optional[Callable[[int, Exception], None]] = None,
               guard: Optional[PreemptionGuard] = None,
               sleep: Callable[[float], None] = time.sleep,
               rng: Optional[random.Random] = None):
    """Run fn(*args); retry transient failures with exponential backoff.

    The backoff doubles per attempt, is capped at ``backoff_cap_s`` (an
    uncapped 2^k sleep outlives any eviction grace window), and carries
    ``jitter`` (uniform +/- fraction) so a fleet of retriers doesn't
    thundering-herd the recovered resource.  On exhaustion the final
    exception re-raises with ``retry_attempts`` set (and a note on 3.11+)
    so the postmortem knows how many tries burned.

    ``guard`` is polled before every attempt and between backoff sleep
    slices: a preemption raises :class:`Preempted` immediately instead of
    finishing the backoff — the caller's snapshot path gets the whole
    remaining grace window.  ``sleep``/``rng`` are injectable for tests.
    """
    rng = rng if rng is not None else random.Random()
    attempt = 0
    while True:
        if guard is not None and guard.requested:
            raise Preempted(f"preempted before retry attempt {attempt}")
        try:
            return fn(*args)
        except RuntimeError as e:   # JaxRuntimeError subclasses RuntimeError
            attempt += 1
            if attempt > retries:
                e.retry_attempts = attempt
                if hasattr(e, "add_note"):      # py3.11+
                    e.add_note(f"retry_step: failed on attempt {attempt} "
                               f"of {retries + 1}")
                raise
            if on_retry:
                on_retry(attempt, e)
            delay = min(backoff_s * (2 ** (attempt - 1)), backoff_cap_s)
            if jitter:
                delay *= 1.0 + jitter * (2.0 * rng.random() - 1.0)
            # Sleep in slices so a preemption arriving mid-backoff is seen
            # within ~100ms, not after the full (possibly capped-30s) delay.
            deadline = time.monotonic() + delay
            while True:
                if guard is not None and guard.requested:
                    raise Preempted(
                        f"preempted during retry backoff (attempt {attempt})")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                sleep(min(remaining, 0.1))


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x EWMA of recent step wall-times
    ewma_alpha: float = 0.1
    ewma: float = 0.0
    n: int = 0
    stragglers: int = 0
    log: list = dataclasses.field(default_factory=list)
    sink: Optional[object] = None   # telemetry.MetricsSink (optional)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler.

        Warm-up: the first 6 steps only feed the EWMA (compile/cold-cache
        steps would otherwise flag everything after them).  With a ``sink``
        attached every straggler emits a ``straggler_dt_s`` sample (the
        engine separately streams every step's latency — this series
        carries only the outliers the EWMA flagged)."""
        is_straggler = self.n > 5 and dt > self.threshold * self.ewma
        self.ewma = dt if self.n == 0 else \
            (1 - self.ewma_alpha) * self.ewma + self.ewma_alpha * dt
        self.n += 1
        if is_straggler:
            self.stragglers += 1
            self.log.append({"step": step, "dt": dt, "ewma": self.ewma})
            if self.sink is not None:
                self.sink.observe("straggler_dt_s", dt, step)
        return is_straggler


class Heartbeat:
    def __init__(self, path: str | Path, every_s: float = 30.0,
                 sink: Optional[object] = None):
        self.path = Path(path)
        self.every_s = every_s
        self.sink = sink
        self._last = 0.0
        self.beats = 0

    def beat(self, step: int) -> bool:
        """Write the liveness marker if due; returns True when written."""
        now = time.time()
        if now - self._last < self.every_s:
            return False
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(json.dumps({"step": step, "t": now}))
        self._last = now
        self.beats += 1
        if self.sink is not None:
            self.sink.observe("heartbeat", self.beats, step)
        return True
