"""Fault-tolerance runtime: preemption handling, step retry, straggler watch.

Designed for the 1000+-node regime where *something is always failing*:

  * PreemptionGuard — SIGTERM/SIGINT handler: sets a flag the train loop polls
    so it checkpoints and exits cleanly inside the eviction grace window.
  * retry_step      — bounded retry with backoff for transient executor
    failures (on real fleets: ICI timeouts, preempted remote workers).  A
    persistent failure re-raises so the scheduler can reschedule the job;
    restart then auto-resumes from the latest valid checkpoint.
  * StragglerMonitor — per-step wall-time EWMA + threshold: logs and counts
    outlier steps (on multi-host fleets this feeds the decision to evict a
    slow host and re-shard — here it is the single-process analogue).
  * heartbeat file  — liveness marker an external babysitter can watch.
"""
from __future__ import annotations

import dataclasses
import json
import signal
import time
from pathlib import Path
from typing import Callable, Optional


class PreemptionGuard:
    def __init__(self):
        self.requested = False
        self._installed = False

    def install(self):
        if self._installed:
            return self
        self._prev = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:  # non-main thread (tests)
                pass
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in getattr(self, "_prev", {}).items():
            signal.signal(sig, prev)
        self._installed = False


def retry_step(fn: Callable, *args, retries: int = 2, backoff_s: float = 1.0,
               on_retry: Optional[Callable[[int, Exception], None]] = None):
    """Run fn(*args); retry transient failures with exponential backoff."""
    attempt = 0
    while True:
        try:
            return fn(*args)
        except RuntimeError as e:   # JaxRuntimeError subclasses RuntimeError
            attempt += 1
            if attempt > retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            time.sleep(backoff_s * (2 ** (attempt - 1)))


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x median
    ewma_alpha: float = 0.1
    ewma: float = 0.0
    n: int = 0
    stragglers: int = 0
    log: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = self.n > 5 and dt > self.threshold * self.ewma
        self.ewma = dt if self.n == 0 else \
            (1 - self.ewma_alpha) * self.ewma + self.ewma_alpha * dt
        self.n += 1
        if is_straggler:
            self.stragglers += 1
            self.log.append({"step": step, "dt": dt, "ewma": self.ewma})
        return is_straggler


class Heartbeat:
    def __init__(self, path: str | Path, every_s: float = 30.0):
        self.path = Path(path)
        self.every_s = every_s
        self._last = 0.0

    def beat(self, step: int):
        now = time.time()
        if now - self._last >= self.every_s:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self.path.write_text(json.dumps({"step": step, "t": now}))
            self._last = now
