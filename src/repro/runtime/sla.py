"""SLA-aware admission and dispatch for the serving engine.

The paper's headline is an energy *budget* (~7 fJ/Op at N > 200), and the
1T-1R follow-up motivates per-request joule budgets for mobile/edge-class
deployments — but budgets only mean something if they are enforced while
traffic is live.  This module layers an SLA policy over the engine's
``SlotScheduler``:

  * **Priority with aging** (``SlaScheduler``): admission picks the
    pending request with the highest *effective* priority
    ``priority + waited // aging_steps`` — every ``aging_steps`` of queue
    wait promotes a request one level, so with priorities bounded by
    ``P_max`` a lowest-priority request outranks every fresh arrival after
    at most ``(P_max + 1) * aging_steps`` waited steps (no starvation;
    bound proven by test).  Ties break (arrival_step, rid) — with every
    priority at the default 0 the selection IS plain FIFO, so SLA-disabled
    traces replay bit-identically.
  * **Deadline admission control**: a request whose deadline can no longer
    be met even with immediate exclusive service — conservatively priced on
    its full token budget at one chunk/token per engine step — is rejected
    AT ADMISSION, before any compute touches it (``finish_reason ==
    "rejected"``, zero tokens, zero joules).
  * **Joule admission control**: a request whose *minimum* possible work
    (prompt prefill + one generated token, priced by
    ``core.energy.serving_energy_model`` over the resolved plan) already
    exceeds its ``joule_budget`` can never stream a token within budget —
    rejected at admission.  Requests that pass admission but cross their
    budget mid-stream are finished as ``over_budget`` by the engine (the
    same graceful-degradation path as a persistent step failure: pages
    freed, neighbor streams bit-equal).

Everything here is host-side bookkeeping between the two compiled steps:
``compiled_steps == 2`` holds through any SLA-scheduled run, and the
selection depends only on (pending set, engine step) — never on physical
slot ids — so the slot-permutation-invariance contract survives.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import energy as energy_model
from repro.runtime.scheduler import Request, SlotScheduler

__all__ = ["SlaConfig", "SlaScheduler", "admission_verdict",
           "min_steps_to_finish"]


@dataclasses.dataclass(frozen=True)
class SlaConfig:
    """SLA policy knobs for one engine.

    aging_steps:        queue-wait steps per priority level of aging (the
                        no-starvation lever; must be >= 1).
    admission_deadline: reject deadline-infeasible requests at admission.
    admission_energy:   reject joule-infeasible requests at admission.
    """
    aging_steps: int = 16
    admission_deadline: bool = True
    admission_energy: bool = True

    def __post_init__(self):
        if self.aging_steps < 1:
            raise ValueError(f"aging_steps must be >= 1, got "
                             f"{self.aging_steps}")


class SlaScheduler(SlotScheduler):
    """Priority-with-aging admission over the fixed slot pool.

    Selection is a pure function of (pending requests, engine step):
    deterministic, replayable from a snapshot's pending list, and
    independent of slot assignment order."""

    def __init__(self, n_slots: int, slot_order: str = "fifo",
                 sla: SlaConfig = SlaConfig()):
        super().__init__(n_slots, slot_order)
        self.sla = sla

    def effective_priority(self, req: Request, step: int) -> int:
        waited = max(0, step - req.arrival_step)
        return req.priority + waited // self.sla.aging_steps

    def head(self, step: int) -> Optional[Request]:
        """Highest effective priority among arrived requests; ties break
        (arrival_step, rid) so equal-priority traffic stays FIFO."""
        self._head_idx = None
        best = None
        for i, r in enumerate(self.pending):
            if r.arrival_step > step:
                continue
            key = (-self.effective_priority(r, step), r.arrival_step, r.rid)
            if best is None or key < best[0]:
                best = (key, i)
        if best is None:
            return None
        self._head_idx = best[1]
        return self.pending[self._head_idx]


def min_steps_to_finish(req: Request, chunk: int) -> int:
    """Engine steps from admission to finish under immediate exclusive
    service: ``ceil(prompt / chunk)`` prefill chunks (the last one emits the
    first token) plus one decode step per remaining token.  Conservative on
    purpose — an early ``eos`` could finish sooner, but admission cannot
    know that, so deadlines are priced on the full budget."""
    chunks = -(-len(req.prompt) // chunk)
    return chunks + req.max_new_tokens - 1


def admission_verdict(req: Request, step: int, chunk: int,
                      energy: dict, sla: SlaConfig) -> Optional[str]:
    """None = admit; otherwise the rejection reason.

    Called by the engine at the moment a request would occupy a slot —
    BEFORE any pages are allocated or any compiled step sees its tokens.
    ``energy`` is the engine's ``serving_energy_model`` table, so the joule
    check prices the request over the resolved plan's tile geometry."""
    if sla.admission_deadline and req.deadline_steps is not None:
        # Finishing at step s means finished_step == s; admission at `step`
        # can at best start prefill this same step.
        min_finish = step + min_steps_to_finish(req, chunk) - 1
        if min_finish - req.arrival_step > req.deadline_steps:
            return (f"deadline-infeasible: earliest finish "
                    f"{min_finish - req.arrival_step} steps after arrival "
                    f"> deadline {req.deadline_steps}")
    if sla.admission_energy and req.joule_budget is not None:
        bounds = energy_model.request_energy_bounds(
            energy, len(req.prompt), req.max_new_tokens)
        if bounds["min_energy_j"] > req.joule_budget:
            return (f"joule-infeasible: minimum work "
                    f"{bounds['min_energy_j']:.3g} J (prompt + 1 token) "
                    f"> budget {req.joule_budget:.3g} J")
    return None


def wait_bound(sla: SlaConfig, max_priority: int, min_priority: int = 0) -> int:
    """Steps after which a ``min_priority`` request's effective priority
    strictly exceeds ``max_priority`` — from then on it outranks every
    fresh arrival (the aging no-starvation bound the fairness test
    asserts)."""
    if math.isinf(max_priority):
        raise ValueError("unbounded priorities cannot bound waiting")
    levels = max_priority - min_priority + 1
    return levels * sla.aging_steps
