"""Deterministic fault injection for the serving engine.

The tests' hard contracts — an engine killed at *any* step resumes its
ragged trace bit-identically, a persistently failing step degrades to one
``failed`` request with neighbors bit-equal, drifted device currents trigger
an online recalibration without a third compiled program — all need faults
that fire at an exact engine step, the same way every run.  This module is
that harness: declarative events scheduled by step number, consumed by
``runtime.engine.Engine`` through ``FaultConfig.injector``.

Events:

  * :class:`FailStep` — raise :class:`FaultError` when the engine is about
    to run compiled step kind ``k`` at engine step ``step``, ``times`` total
    raises.  ``times <= retries`` models a transient executor failure
    (``fault.retry_step`` recovers it, streams unchanged); ``times`` beyond
    the retry budget models a persistent one (the engine finishes the
    culprit request as ``failed`` and keeps serving).  The raise happens
    *before* the compiled call is invoked, so donated cache buffers are
    never consumed by a failed attempt.
  * :class:`PreemptAt` — flip the run's ``PreemptionGuard`` at step
    ``step``: the engine snapshots and exits exactly as if SIGTERM landed
    between those steps.
  * :class:`DriftAt` — perturb the engine's weight matrices in place via
    ``core.nonideal.perturb_currents`` at step ``step`` (the FG-cell tuning
    drift of section 4.1): max|z| at every TD-VMM site moves, and the
    drift probe's clip rates against the pinned windows go stale.
  * :class:`SlowStep` — sleep ``sleep_s`` inside the compiled-step wrapper
    at engine step ``step``: the tick's wall time inflates exactly once,
    giving the telemetry spike detector (``runtime.telemetry``) a
    deterministic straggler to catch.

All randomness is keyed from explicit seeds; nothing here reads clocks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.constants import TDVMMSpec
from repro.core.nonideal import NonIdealityConfig, perturb_currents

__all__ = ["FaultError", "FailStep", "PreemptAt", "DriftAt", "SlowStep",
           "FaultInjector", "drift_params"]


class FaultError(RuntimeError):
    """Injected step failure.  A RuntimeError on purpose: that is what
    ``fault.retry_step`` treats as transient (JAX's runtime errors subclass
    it), so injected faults exercise the real retry path.  ``rid`` names the
    request whose work the failing step was doing (None = unattributed; the
    engine then blames the oldest runnable slot)."""

    def __init__(self, message: str, rid: Optional[int] = None):
        super().__init__(message)
        self.rid = rid


@dataclasses.dataclass
class FailStep:
    """Raise on compiled-step kind ``kind`` at engine step ``step``,
    ``times`` total raises (consumed across retry attempts)."""
    step: int
    kind: str = "decode"            # "prefill" | "decode" | "any"
    times: int = 1
    rid: Optional[int] = None       # blame this request (None = oldest)
    message: str = "injected step failure"
    fired: int = 0                  # raises consumed so far

    def matches(self, kind: str, step: int) -> bool:
        return (self.fired < self.times and step == self.step
                and self.kind in (kind, "any"))


@dataclasses.dataclass
class PreemptAt:
    """Request preemption once the engine reaches ``step`` (between steps,
    like a SIGTERM inside the eviction grace window)."""
    step: int
    fired: bool = False


@dataclasses.dataclass
class DriftAt:
    """Perturb the engine's weights at ``step`` — deterministic device
    drift.  ``sigma`` scales the lognormal FG tuning error; repeats > 1
    apply the perturbation that many times (compounding drift)."""
    step: int
    sigma: float = 0.05
    seed: int = 0
    repeats: int = 1
    fired: bool = False


@dataclasses.dataclass
class SlowStep:
    """Inflate the wall time of compiled-step kind ``kind`` at engine step
    ``step`` by sleeping ``sleep_s`` before the call — a one-step straggler
    with a step-exact signature for the spike detector.  The compiled call
    itself is untouched, so token streams are bit-identical to a run
    without the event."""
    step: int
    sleep_s: float = 0.25
    kind: str = "any"               # "prefill" | "decode" | "any"
    fired: bool = False

    def matches(self, kind: str, step: int) -> bool:
        return (not self.fired and step == self.step
                and self.kind in (kind, "any"))


class FaultInjector:
    """Deterministic event schedule consumed by ``Engine._drive``.

    ``on_tick(engine, step)`` runs between steps (preempt/drift events);
    ``check(kind, step)`` runs inside the retry wrapper immediately before
    each compiled-step invocation (failure events)."""

    def __init__(self, events):
        self.events = list(events)

    def on_tick(self, engine, step: int) -> None:
        for ev in self.events:
            if isinstance(ev, PreemptAt) and not ev.fired and step >= ev.step:
                ev.fired = True
                engine.request_preemption()
            elif isinstance(ev, DriftAt) and not ev.fired and step >= ev.step:
                ev.fired = True
                spec = _model_spec(engine.cfg)
                engine.params = drift_params(
                    engine.params, jax.random.PRNGKey(ev.seed), spec,
                    NonIdealityConfig(dibl=False, weight_noise=True,
                                      sigma_tune=ev.sigma),
                    repeats=ev.repeats)

    def check(self, kind: str, step: int) -> None:
        for ev in self.events:
            if isinstance(ev, FailStep) and ev.matches(kind, step):
                ev.fired += 1
                raise FaultError(
                    f"{ev.message} (kind={kind}, step={step}, "
                    f"raise {ev.fired}/{ev.times})", rid=ev.rid)
            if isinstance(ev, SlowStep) and ev.matches(kind, step):
                ev.fired = True
                time.sleep(ev.sleep_s)

    def report(self) -> list[dict]:
        out = []
        for ev in self.events:
            d = dataclasses.asdict(ev)
            d["event"] = type(ev).__name__
            out.append(d)
        return out


def _model_spec(cfg) -> TDVMMSpec:
    """The TDVMMSpec drift perturbations are priced against: any enabled
    site's spec (they share the paper's operating point by default)."""
    for _, sc in cfg.resolved_tdvmm_plan.sites:
        if sc.enabled:
            return sc.spec
    return TDVMMSpec()


def drift_params(params, key: jax.Array, spec: TDVMMSpec,
                 nicfg: NonIdealityConfig, subtree: str = "blocks",
                 repeats: int = 1):
    """Apply device-current drift to every weight matrix under
    ``params[subtree]``.

    Each float leaf with ndim >= 2 (the projection matrices the TD-VMM
    tiles hold as programmed currents) is perturbed by
    ``nonideal.perturb_currents`` under a per-leaf key folded from the leaf
    index — deterministic, order-stable, and independent across leaves.
    Returns a new params pytree (input untouched)."""
    target = params[subtree]
    leaves, treedef = jax.tree_util.tree_flatten(target)
    out = []
    for i, leaf in enumerate(leaves):
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            k = jax.random.fold_in(key, i)
            for r in range(repeats):
                leaf = perturb_currents(
                    leaf, jax.random.fold_in(k, r), spec, nicfg
                ).astype(leaf.dtype)
        out.append(leaf)
    new = dict(params)
    new[subtree] = jax.tree_util.tree_unflatten(treedef, out)
    return new
