from repro.models import model, transformer, attention, ffn, moe, ssm, common  # noqa: F401
