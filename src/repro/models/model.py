"""LM wrapper: embedding, stack, head, loss; train/prefill/decode entry points."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, ssm, transformer


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = common.resolve_dtype(cfg.dtype)
    ke, kb, kh = jax.random.split(key, 3)
    params: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = {
            "table": (jax.random.normal(ke, (cfg.padded_vocab, cfg.d_model)) * 0.02
                      ).astype(dtype)}
    params["blocks"] = transformer.init(kb, cfg, dtype)
    params["ln_f"] = common.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = common.dense_init(kh, cfg.d_model, cfg.padded_vocab, dtype,
                                           scale=cfg.d_model ** -0.5)
    return params


def _embed(params, batch: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.input_mode == "tokens":
        return params["embed"]["table"][batch["inputs"]]
    return batch["inputs"].astype(common.resolve_dtype(cfg.dtype))


def _head(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].T
    return common.dense(params["head"], x, cfg.site_tdvmm("head"))


def forward(params, batch: dict, cfg: ModelConfig, key=None):
    """Training forward: full-sequence causal.  Returns (logits, aux)."""
    x = _embed(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _, aux = transformer.apply(params["blocks"], x, cfg, "train", None,
                                  positions, embed0=x, key=key)
    x = common.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _head(params, x, cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig, key=None,
            lb_coef: float = 0.01, z_coef: float = 1e-3):
    """Next-token cross-entropy with padding mask; targets: (B, S) int32,
    positions with target < 0 are masked out."""
    logits, aux = forward(params, batch, cfg, key)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    safe_t = jnp.maximum(targets, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_t[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    total = loss + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
    metrics = {"loss": loss, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"],
               "tokens": mask.sum()}
    return total, metrics


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = common.resolve_dtype(cfg.dtype)

    def one_attn():
        return attention.init_cache(cfg, batch, max_len, dtype)

    def one_ssm():
        return ssm.init_cache(cfg, batch, dtype)

    def stack(mk, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[mk() for _ in range(n)])

    caches: dict[str, Any] = {}
    for i, (kind, n) in enumerate(transformer.segments(cfg)):
        if kind in ("attn_ffn", "attn_moe"):
            caches[f"seg{i}"] = stack(one_attn, n)
        elif kind == "ssm":
            caches[f"seg{i}"] = stack(one_ssm, n)
        elif kind == "hybrid":
            caches[f"seg{i}"] = stack(one_ssm, n)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        caches["shared_attn"] = stack(one_attn, n_groups)
    return caches


def prefill_step(params, batch: dict, caches: dict, cfg: ModelConfig,
                 calib=None):
    """Absorb a prompt.  Returns (logits_last, new_caches).

    ``calib`` (a ``core.calibration.CalibrationState``) pins each TD-VMM
    site's readout window: the per-call max|z| reduction disappears and the
    Pallas fused-epilogue kernel becomes eligible.  Windows are baked in as
    jit-static site overrides, so pass concrete (non-traced) state — close
    over it when jitting, don't thread it as a jit argument."""
    from repro.core.calibration import apply_calibration
    cfg = apply_calibration(cfg, calib)
    x = _embed(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, new_caches, _ = transformer.apply(params["blocks"], x, cfg, "prefill",
                                         caches, positions, embed0=x)
    x = common.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    return _head(params, x, cfg), new_caches


def decode_step(params, batch: dict, caches: dict, cfg: ModelConfig,
                calib=None):
    """One token for every sequence.  batch['inputs']: (B, 1) (or (B,1,d) for
    embedding-input archs).  Returns (logits, new_caches).  ``calib`` as in
    ``prefill_step``."""
    from repro.core.calibration import apply_calibration
    cfg = apply_calibration(cfg, calib)
    x = _embed(params, batch, cfg)
    b = x.shape[0]
    positions = None  # decode blocks read positions from their caches
    x, new_caches, _ = transformer.apply(params["blocks"], x, cfg, "decode",
                                         caches, positions, embed0=x)
    x = common.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _head(params, x, cfg), new_caches


# --------------------------------------------------------------------------
# Paged serving (continuous-batching engine, runtime/engine.py)
# --------------------------------------------------------------------------
def init_paged_caches(cfg: ModelConfig, num_pages: int, page_size: int,
                      ranks: int = 1) -> dict:
    """Page pools for every attention layer (attention families only).

    Unlike ``init_caches`` there is no batch/max_len here: capacity is the
    shared pool, and per-request footprint is decided at admission time by
    the engine's block tables.  All layers share one logical page allocation
    (the same page id addresses the same token range in every layer's pool).
    """
    if cfg.family not in ("dense", "moe", "vlm", "audio"):
        raise NotImplementedError(
            f"paged serving supports attention families, not {cfg.family!r} "
            "(SSM state is O(1) per slot; use the static path)")
    dtype = common.resolve_dtype(cfg.dtype)

    def one_attn():
        return attention.init_paged_cache(cfg, num_pages, page_size, dtype,
                                          ranks=ranks)

    def stack(mk, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[mk() for _ in range(n)])

    caches: dict[str, Any] = {}
    for i, (kind, n) in enumerate(transformer.segments(cfg)):
        if kind not in ("attn_ffn", "attn_moe"):
            raise NotImplementedError(f"paged serving: segment kind {kind!r}")
        caches[f"seg{i}"] = stack(one_attn, n)
    return caches


def prefill_chunk(params, batch: dict, caches: dict, cfg: ModelConfig,
                  calib=None, windows=None):
    """One fixed-shape prefill chunk for ONE slot (the engine's first
    compiled step).  batch: {"inputs": (1, C) tokens, "block_row": (P,),
    "offset": (), "valid": ()}.  Returns (logits at the last valid position
    — shape (1, 1, V) — and the updated page pools).  ``calib`` as in
    ``prefill_step`` (close over concrete state at jit time).

    ``windows`` (site -> f32 window array, ``CalibrationState.as_arrays()``)
    is the *hot-swappable* alternative: the windows enter the compiled
    program as runtime operands (thread the dict as a jit argument), so the
    engine can recalibrate between steps without recompiling — bit-identical
    to the baked ``calib`` path."""
    from repro.core import calibration
    from repro.core.calibration import apply_calibration
    from repro.runtime.paged_cache import PrefillChunkCtx
    cfg = apply_calibration(cfg, calib)
    ctx = PrefillChunkCtx(block_row=batch["block_row"],
                          offset=batch["offset"], valid=batch["valid"])
    with calibration.runtime_windows(windows):
        x = _embed(params, batch, cfg)
        x, new_caches, _ = transformer.apply(params["blocks"], x, cfg,
                                             "prefill_paged", caches, None,
                                             embed0=x, page_ctx=ctx)
        # logits only at the chunk's last real token (== prefill_step's
        # x[:, -1:] on the final chunk); padded rows never reach the head.
        x = jax.lax.dynamic_slice_in_dim(x, ctx.valid - 1, 1, axis=1)
        x = common.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return _head(params, x, cfg), new_caches


def decode_slots(params, batch: dict, caches: dict, cfg: ModelConfig,
                 calib=None, windows=None):
    """One token for every occupied slot (the engine's second compiled
    step).  batch: {"inputs": (B, 1) tokens, "block_tables": (B, P),
    "pos": (B,), "active": (B,) bool}.  Returns (logits (B, 1, V), updated
    page pools); inactive rows produce ignored logits.  ``windows`` as in
    ``prefill_chunk`` (runtime-operand readout windows)."""
    from repro.core import calibration
    from repro.core.calibration import apply_calibration
    from repro.runtime.paged_cache import DecodeCtx
    cfg = apply_calibration(cfg, calib)
    ctx = DecodeCtx(block_tables=batch["block_tables"], pos=batch["pos"],
                    active=batch["active"])
    with calibration.runtime_windows(windows):
        x = _embed(params, batch, cfg)
        x, new_caches, _ = transformer.apply(params["blocks"], x, cfg,
                                             "decode_paged", caches, None,
                                             embed0=x, page_ctx=ctx)
        x = common.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return _head(params, x, cfg), new_caches


def calibrate(params, batch: dict, cfg: ModelConfig, max_len: int = 0):
    """Model-wide §3.1 readout-window calibration (one prefill pass).

    Runs ``prefill_step`` over a representative batch with the calibration
    collector installed: every enabled, digital-boundary TD-VMM site records
    the max|z| of its latch-normalized accumulation — scalar per site,
    ``(E,)`` per-expert for expert-batched sites (one window per analog
    tile; layers scanned into one site max-merge).  Returns the captured
    ``CalibrationState``; persist it with
    ``checkpoint.checkpoint.save_calibration`` and hand it back to
    ``prefill_step`` / ``decode_step`` / ``launch.serve`` for serving.
    """
    from repro.core import calibration
    b, s = batch["inputs"].shape[:2]
    caches = init_caches(cfg, b, max_len or s)
    with calibration.collect() as collected:
        prefill_step(params, batch, caches, cfg)
    return calibration.CalibrationState.from_collected(collected)


def drift_probe(params, batch: dict, cfg: ModelConfig, pinned,
                max_len: int = 0):
    """One eager calibration pass measured *against* pinned windows.

    Same capture as ``calibrate`` but with clip tracking on: every site
    additionally tallies how much of its latch-normalized |z| mass exceeds
    the window currently pinned for serving (``pinned``: a
    ``CalibrationState``).  Returns ``(fresh, clip_rates)`` — the freshly
    captured ``CalibrationState`` and a site -> clip-fraction dict — the two
    signals the engine's drift detector thresholds to decide when the §3.1
    windows have gone stale.  Eager (outside the engine's two compiled
    steps), so probing never adds a compiled program."""
    import numpy as np

    from repro.core import calibration
    b, s = batch["inputs"].shape[:2]
    caches = init_caches(cfg, b, max_len or s)
    ref = {site: np.asarray(v, np.float32)
           for site, v in pinned.windows.items()}
    with calibration.collect(pinned=ref) as collected:
        prefill_step(params, batch, caches, cfg)
    fresh = calibration.CalibrationState.from_collected(collected)
    clips = calibration.last_clips() or {}
    return fresh, calibration.clip_rates(clips)
