"""LM wrapper: embedding, stack, head, loss; train/prefill/decode entry points."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, ssm, transformer


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = common.resolve_dtype(cfg.dtype)
    ke, kb, kh = jax.random.split(key, 3)
    params: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = {
            "table": (jax.random.normal(ke, (cfg.padded_vocab, cfg.d_model)) * 0.02
                      ).astype(dtype)}
    params["blocks"] = transformer.init(kb, cfg, dtype)
    params["ln_f"] = common.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = common.dense_init(kh, cfg.d_model, cfg.padded_vocab, dtype,
                                           scale=cfg.d_model ** -0.5)
    return params


def _embed(params, batch: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.input_mode == "tokens":
        return params["embed"]["table"][batch["inputs"]]
    return batch["inputs"].astype(common.resolve_dtype(cfg.dtype))


def _head(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].T
    return common.dense(params["head"], x, cfg.site_tdvmm("head"))


def forward(params, batch: dict, cfg: ModelConfig, key=None):
    """Training forward: full-sequence causal.  Returns (logits, aux)."""
    x = _embed(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _, aux = transformer.apply(params["blocks"], x, cfg, "train", None,
                                  positions, embed0=x, key=key)
    x = common.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _head(params, x, cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig, key=None,
            lb_coef: float = 0.01, z_coef: float = 1e-3):
    """Next-token cross-entropy with padding mask; targets: (B, S) int32,
    positions with target < 0 are masked out."""
    logits, aux = forward(params, batch, cfg, key)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    safe_t = jnp.maximum(targets, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_t[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    total = loss + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
    metrics = {"loss": loss, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"],
               "tokens": mask.sum()}
    return total, metrics


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = common.resolve_dtype(cfg.dtype)

    def one_attn():
        return attention.init_cache(cfg, batch, max_len, dtype)

    def one_ssm():
        return ssm.init_cache(cfg, batch, dtype)

    def stack(mk, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[mk() for _ in range(n)])

    caches: dict[str, Any] = {}
    for i, (kind, n) in enumerate(transformer.segments(cfg)):
        if kind in ("attn_ffn", "attn_moe"):
            caches[f"seg{i}"] = stack(one_attn, n)
        elif kind == "ssm":
            caches[f"seg{i}"] = stack(one_ssm, n)
        elif kind == "hybrid":
            caches[f"seg{i}"] = stack(one_ssm, n)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        caches["shared_attn"] = stack(one_attn, n_groups)
    return caches


def prefill_step(params, batch: dict, caches: dict, cfg: ModelConfig,
                 calib=None):
    """Absorb a prompt.  Returns (logits_last, new_caches).

    ``calib`` (a ``core.calibration.CalibrationState``) pins each TD-VMM
    site's readout window: the per-call max|z| reduction disappears and the
    Pallas fused-epilogue kernel becomes eligible.  Windows are baked in as
    jit-static site overrides, so pass concrete (non-traced) state — close
    over it when jitting, don't thread it as a jit argument."""
    from repro.core.calibration import apply_calibration
    cfg = apply_calibration(cfg, calib)
    x = _embed(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, new_caches, _ = transformer.apply(params["blocks"], x, cfg, "prefill",
                                         caches, positions, embed0=x)
    x = common.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    return _head(params, x, cfg), new_caches


def decode_step(params, batch: dict, caches: dict, cfg: ModelConfig,
                calib=None):
    """One token for every sequence.  batch['inputs']: (B, 1) (or (B,1,d) for
    embedding-input archs).  Returns (logits, new_caches).  ``calib`` as in
    ``prefill_step``."""
    from repro.core.calibration import apply_calibration
    cfg = apply_calibration(cfg, calib)
    x = _embed(params, batch, cfg)
    b = x.shape[0]
    positions = None  # decode blocks read positions from their caches
    x, new_caches, _ = transformer.apply(params["blocks"], x, cfg, "decode",
                                         caches, positions, embed0=x)
    x = common.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _head(params, x, cfg), new_caches


def calibrate(params, batch: dict, cfg: ModelConfig, max_len: int = 0):
    """Model-wide §3.1 readout-window calibration (one prefill pass).

    Runs ``prefill_step`` over a representative batch with the calibration
    collector installed: every enabled, digital-boundary TD-VMM site records
    the max|z| of its latch-normalized accumulation — scalar per site,
    ``(E,)`` per-expert for expert-batched sites (one window per analog
    tile; layers scanned into one site max-merge).  Returns the captured
    ``CalibrationState``; persist it with
    ``checkpoint.checkpoint.save_calibration`` and hand it back to
    ``prefill_step`` / ``decode_step`` / ``launch.serve`` for serving.
    """
    from repro.core import calibration
    b, s = batch["inputs"].shape[:2]
    caches = init_caches(cfg, b, max_len or s)
    with calibration.collect() as collected:
        prefill_step(params, batch, caches, cfg)
    return calibration.CalibrationState.from_collected(collected)
