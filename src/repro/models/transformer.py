"""Layer stacks: scanned homogeneous segments, remat, hybrid composition.

Stack layouts per family:
  dense/vlm/audio : n_layers x [attn + ffn]                    (one scanned seg)
  moe             : first_k_dense x [attn + ffn] + rest x [attn + moe]
  ssm             : n_layers x [mamba2]
  hybrid (zamba2) : groups of `hybrid_attn_every` mamba2 layers, a SHARED
                    attention+ffn block (single param set, reused) after each
                    group, optionally fed concat(h, embed0) through a fuse
                    projection (Zamba's signature trick).

Scanning keeps the HLO O(1) in depth (compile-time requirement for the 61-layer
1T-param dry-run); jax.checkpoint wraps each block body per cfg.remat_policy.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, ffn, moe, ssm


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "save_dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # 'minimal': save only block inputs


# --------------------------------------------------------------------------
# Block bodies (mode: train | prefill | decode)
# --------------------------------------------------------------------------
def attn_ffn_block(params, x, cfg: ModelConfig, mode: str, cache, positions, key=None,
                   page_ctx=None):
    x = common.constrain_batch(x)
    h = common.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if mode == "train":
        a = attention.apply_train(params["attn"], h, cfg, positions, key)
        new_cache = cache
    elif mode == "prefill":
        a, new_cache = attention.apply_prefill(params["attn"], h, cfg, cache, key)
    elif mode == "prefill_paged":
        a, new_cache = attention.apply_prefill_paged(
            params["attn"], h, cfg, cache, page_ctx, key)
    elif mode == "decode_paged":
        a, new_cache = attention.apply_decode_paged(
            params["attn"], h, cfg, cache, page_ctx, key)
    else:
        a, new_cache = attention.apply_decode(params["attn"], h, cfg, cache, key)
    x = x + a
    h = common.rmsnorm(params["ln2"], x, cfg.norm_eps)
    aux = {}
    if "moe" in params:
        f, aux = moe.apply(params["moe"], h, cfg, key)
    else:
        f = ffn.apply(params["ffn"], h, cfg, key)
    return x + f, new_cache, aux


def ssm_block(params, x, cfg: ModelConfig, mode: str, cache, key=None):
    if mode in ("prefill_paged", "decode_paged"):
        raise NotImplementedError(
            "paged serving covers attention families only for now; SSM state "
            "is O(1) per slot and the engine gates on cfg.family")
    x = common.constrain_batch(x)
    h = common.rmsnorm(params["ln"], x, cfg.norm_eps)
    if mode == "train":
        y = ssm.apply_train(params["ssm"], h, cfg, key)
        new_cache = cache
    elif mode == "prefill":
        y, new_cache = ssm.apply_prefill(params["ssm"], h, cfg, cache, key)
    else:
        y, new_cache = ssm.apply_decode(params["ssm"], h, cfg, cache, key)
    return x + y, new_cache


def _init_attn_ffn(key, cfg: ModelConfig, use_moe: bool, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": common.rmsnorm_init(cfg.d_model, dtype),
        "ln2": common.rmsnorm_init(cfg.d_model, dtype),
        "attn": attention.init(k1, cfg, dtype),
    }
    if use_moe:
        p["moe"] = moe.init(k2, cfg, dtype)
    else:
        p["ffn"] = ffn.init(k3, cfg, dtype=dtype)
    return p


def _init_ssm(key, cfg: ModelConfig, dtype):
    return {
        "ln": common.rmsnorm_init(cfg.d_model, dtype),
        "ssm": ssm.init(key, cfg, dtype),
    }


# --------------------------------------------------------------------------
# Segments: (kind, n_layers) with stacked params
# --------------------------------------------------------------------------
def segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    if cfg.family in ("dense", "vlm", "audio"):
        return [("attn_ffn", cfg.n_layers)]
    if cfg.family == "moe":
        k = cfg.moe.first_k_dense
        segs = []
        if k:
            segs.append(("attn_ffn", k))
        segs.append(("attn_moe", cfg.n_layers - k))
        return segs
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("hybrid", cfg.n_layers)]
    raise ValueError(cfg.family)


def _stacked_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init(key, cfg: ModelConfig, dtype) -> dict:
    params: dict[str, Any] = {}
    ks = jax.random.split(key, len(segments(cfg)) + 2)
    for i, (kind, n) in enumerate(segments(cfg)):
        if kind == "attn_ffn":
            params[f"seg{i}"] = _stacked_init(
                lambda k: _init_attn_ffn(k, cfg, False, dtype), ks[i], n)
        elif kind == "attn_moe":
            params[f"seg{i}"] = _stacked_init(
                lambda k: _init_attn_ffn(k, cfg, True, dtype), ks[i], n)
        elif kind == "ssm":
            params[f"seg{i}"] = _stacked_init(lambda k: _init_ssm(k, cfg, dtype), ks[i], n)
        elif kind == "hybrid":
            params[f"seg{i}"] = _stacked_init(lambda k: _init_ssm(k, cfg, dtype), ks[i], n)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        kshared = jax.random.split(ks[-1], 2)
        params["shared_attn"] = _init_attn_ffn(kshared[0], cfg, False, dtype)
        if cfg.hybrid_concat_embed:
            params["fuse"] = common.dense_init(kshared[1], 2 * cfg.d_model, cfg.d_model, dtype)
    return params


# --------------------------------------------------------------------------
# Apply: scan over stacked segment params
# --------------------------------------------------------------------------
def _scan_segment(body, stacked_params, x, caches, cfg: ModelConfig):
    """caches: stacked pytree with leading layer dim (or None for train)."""
    def step(carry, layer_in):
        p, c = layer_in
        new_x, new_c, aux = body(p, carry, c)
        return new_x, (new_c, aux)

    step = _remat(step, cfg) if cfg.remat_policy != "none" else step
    x, (new_caches, auxs) = jax.lax.scan(step, x, (stacked_params, caches))
    return x, new_caches, auxs


def apply(params, x: jax.Array, cfg: ModelConfig, mode: str,
          caches: Optional[dict], positions, embed0=None, key=None,
          page_ctx=None):
    """Run the full stack.  Returns (x, new_caches, aux_losses).

    ``page_ctx`` (``runtime.paged_cache.PrefillChunkCtx`` / ``DecodeCtx``)
    rides alongside the paged modes: the block table and positions are the
    same for every layer (pages are allocated per slot, not per layer), so
    the context is a loop-invariant side input rather than part of the
    scanned caches."""
    new_caches: dict[str, Any] = {}
    aux_total = {"lb_loss": jnp.zeros((), jnp.float32),
                 "z_loss": jnp.zeros((), jnp.float32)}

    for i, (kind, n) in enumerate(segments(cfg)):
        seg_params = params[f"seg{i}"]
        seg_cache = None if caches is None else caches.get(f"seg{i}")

        if kind in ("attn_ffn", "attn_moe"):
            def body(p, h, c, _kind=kind):
                h2, nc, aux = attn_ffn_block(p, h, cfg, mode, c, positions, key,
                                             page_ctx=page_ctx)
                aux = {k2: aux.get(k2, jnp.zeros((), jnp.float32))
                       for k2 in ("lb_loss", "z_loss")}
                return h2, nc, aux
            x, nc, auxs = _scan_segment(body, seg_params, x, seg_cache, cfg)
            if kind == "attn_moe":
                aux_total = {k2: aux_total[k2] + jnp.sum(auxs[k2]) for k2 in aux_total}
            new_caches[f"seg{i}"] = nc

        elif kind == "ssm":
            def body(p, h, c):
                h2, nc = ssm_block(p, h, cfg, mode, c, key)
                return h2, nc, {"lb_loss": jnp.zeros((), jnp.float32),
                                "z_loss": jnp.zeros((), jnp.float32)}
            x, nc, _ = _scan_segment(body, seg_params, x, seg_cache, cfg)
            new_caches[f"seg{i}"] = nc

        elif kind == "hybrid":
            every = cfg.hybrid_attn_every or n
            n_groups = n // every
            # reshape stacked (n, ...) -> (n_groups, every, ...)
            gp = jax.tree.map(lambda a: a.reshape((n_groups, every) + a.shape[1:]),
                              seg_params)
            gc = None if seg_cache is None else jax.tree.map(
                lambda a: a.reshape((n_groups, every) + a.shape[1:]), seg_cache)
            shared_cache = None if caches is None else caches.get("shared_attn")
            shared_caches_out = []

            def ssm_body(p, h, c):
                h2, nc = ssm_block(p, h, cfg, mode, c, key)
                return h2, nc, {"lb_loss": jnp.zeros((), jnp.float32),
                                "z_loss": jnp.zeros((), jnp.float32)}

            group_caches = []
            for g in range(n_groups):
                gparams = jax.tree.map(lambda a: a[g], gp)
                gcache = None if gc is None else jax.tree.map(lambda a: a[g], gc)
                x, nc, _ = _scan_segment(ssm_body, gparams, x, gcache, cfg)
                group_caches.append(nc)
                # shared attention block (Zamba2): one param set reused
                h_in = x
                if cfg.hybrid_concat_embed and embed0 is not None:
                    h_in = common.dense(
                        params["fuse"],
                        jnp.concatenate([x, embed0], axis=-1),
                        cfg.site_tdvmm("hybrid.fuse"), key)
                sc = None if shared_cache is None else jax.tree.map(
                    lambda a: a[g], shared_cache)
                x, sc_new, _ = attn_ffn_block(
                    params["shared_attn"], h_in, cfg, mode, sc, positions, key)
                shared_caches_out.append(sc_new)
            new_caches[f"seg{i}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs).reshape((n,) + xs[0].shape[1:]) if xs[0] is not None else None,
                *group_caches) if group_caches and group_caches[0] is not None else None
            if shared_caches_out and shared_caches_out[0] is not None:
                new_caches["shared_attn"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *shared_caches_out)

    return x, new_caches, aux_total
