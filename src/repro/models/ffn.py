"""Feed-forward blocks: gated (SiLU-GLU) and non-gated (squared-ReLU / GELU)."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models import common


def init(key, cfg: ModelConfig, d_ff: int | None = None, dtype=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "silu_glu":
        return {
            "w_gate": common.dense_init(k1, d, f, dtype),
            "w_up": common.dense_init(k2, d, f, dtype),
            "w_down": common.dense_init(k3, f, d, dtype),
        }
    return {
        "w_up": common.dense_init(k1, d, f, dtype),
        "w_down": common.dense_init(k2, f, d, dtype),
    }


def apply(params, x: jax.Array, cfg: ModelConfig, key=None) -> jax.Array:
    td_in = cfg.site_tdvmm("ffn.in")
    if "w_gate" in params:
        h = common.activation("silu", common.dense(params["w_gate"], x, td_in, key))
        h = h * common.dense(params["w_up"], x, td_in, key)
    else:
        h = common.activation(cfg.act, common.dense(params["w_up"], x, td_in, key))
    return common.dense_tp_reduce(params["w_down"], h,
                                  cfg.site_tdvmm("ffn.out"), key)
