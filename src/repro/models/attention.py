"""Multi-head attention: GQA/MHA, sliding-window, KV cache prefill/decode.

Weights are stored flattened, (d_model, n_heads*head_dim), so the TP dimension
divides evenly on a 16-way model axis for every assigned arch (e.g. yi-34b's
56 heads x 128 = 7168); GSPMD handles the per-head einsum resharding.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_cache, n_kv, head_dim)  bf16 or int8
    v: jax.Array          # (B, S_cache, n_kv, head_dim)
    pos: jax.Array        # (B,) int32 — tokens absorbed per sequence (ragged
    #                       decode: slots advance independently)
    k_scale: jax.Array | None = None   # (B, S_cache, n_kv) — int8 mode only
    v_scale: jax.Array | None = None


# perf it.9 — int8 KV cache (decode is cache-bandwidth-bound; see
# EXPERIMENTS.md §Roofline "what moves the dominant term" for decode rows).
KV_CACHE_INT8 = False


def set_kv_cache_int8(on: bool):
    global KV_CACHE_INT8
    KV_CACHE_INT8 = on


def _kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., hd) -> int8 codes + per-(token, head) scale."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-6) / 127.0
    codes = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _kv_dequantize(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        "wq": common.dense_init(kq, d, cfg.n_heads * hd, dtype, bias=bias),
        "wk": common.dense_init(kk, d, cfg.n_kv_heads * hd, dtype, bias=bias),
        "wv": common.dense_init(kv, d, cfg.n_kv_heads * hd, dtype, bias=bias),
        "wo": common.dense_init(ko, cfg.n_heads * hd, d, dtype),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _qkv(params, x: jax.Array, cfg: ModelConfig, key):
    """q/k/v projections as ONE grouped TD-VMM launch (site ``attn.qkv``).

    The shared input is encoded once and wq/wk/wv run as three tiles of a
    single batched kernel dispatch — the paper's shared-DAC amortization —
    instead of three ``dense`` calls that each re-encode x."""
    td = cfg.site_tdvmm("attn.qkv")
    hd = cfg.resolved_head_dim
    q, k, v = common.dense_group(
        (params["wq"], params["wk"], params["wv"]), x, td, key)
    return (_split_heads(q, cfg.n_heads, hd),
            _split_heads(k, cfg.n_kv_heads, hd),
            _split_heads(v, cfg.n_kv_heads, hd))


def _merge_heads(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


FLASH_THRESHOLD = 2048   # use online-softmax blocked attention above this S
FLASH_BLOCK_Q = 1024
FLASH_BLOCK_KV = 1024
FLASH_BLOCK_SKIP = False  # perf it.2: iterate only causal/in-window tile pairs


def _attend_flash(q, k, v, cfg: ModelConfig, q_offset: int = 0) -> jax.Array:
    """Blocked causal attention with online softmax (flash-style).

    Never materializes the (Sq, Skv) logits: a double lax.scan over
    (q blocks, kv blocks) carries running (max, denom, acc) — the JAX-level
    equivalent of the VMEM-resident blocking a Pallas kernel would use; XLA
    keeps per-tile buffers at FLASH_BLOCK_Q x FLASH_BLOCK_KV.

    q: (B, Sq, H, D); k, v: (B, Skv, Kv, D).  Causal + optional SWA mask,
    with q global positions offset by q_offset.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kvh = cfg.n_kv_heads
    g = h // kvh
    bq = min(FLASH_BLOCK_Q, sq)
    bkv = min(FLASH_BLOCK_KV, skv)
    # Non-block-multiple lengths: zero-pad to the block grid and mask the
    # key tail (k_pos < skv); padded query rows compute garbage that the
    # final slice drops.
    sq_real, skv_real = sq, skv
    pad_q, pad_kv = (-sq) % bq, (-skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        skv += pad_kv
    nq, nkv = sq // bq, skv // bkv
    scale = d ** -0.5
    window = cfg.swa_window

    qr = q.reshape(b, nq, bq, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)  # (nq,b,kv,g,bq,d)
    kr = k.reshape(b, nkv, bkv, kvh, d).transpose(1, 0, 3, 2, 4)      # (nkv,b,kv,bkv,d)
    vr = v.reshape(b, nkv, bkv, kvh, d).transpose(1, 0, 3, 2, 4)

    def q_block(_, qi_qb):
        qi, qb = qi_qb                     # qb: (b, kv, g, bq, d)
        q_pos = qi * bq + jnp.arange(bq) + q_offset

        def kv_block(carry, ki_kb):
            m, l, acc = carry
            ki, kb, vb = ki_kb
            k_pos = ki * bkv + jnp.arange(bkv)
            logits = jnp.einsum("bkgqd,bktd->bkgqt", qb, kb).astype(jnp.float32)
            logits *= scale
            mask = k_pos[None, :] <= q_pos[:, None]
            if pad_kv:
                mask &= k_pos[None, :] < skv_real
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nkv), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qr))
    # outs: (nq, b, kv, g, bq, d) -> (b, sq, h, d); drop padded query rows
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)[:, :sq_real]


def _attend_flash_blocks(q, k, v, cfg: ModelConfig, q_offset: int = 0) -> jax.Array:
    """Perf it.2: flash attention that iterates ONLY the (q, kv) tile pairs the
    causal/SWA structure makes non-empty, with the tile mask shared as a small
    loop-invariant constant per pair class.

    vs _attend_flash (which visits all nq x nkv pairs and materializes a mask
    per pair): causal halves the tile count; a W-window sweep at length S
    visits ~S*W/B^2 tiles instead of (S/B)^2 — an 8x FLOP cut for Mixtral's
    32k prefill.  Pair classes (full / diagonal / window-edge) run as three
    scans over STATIC index lists, so the HLO trip counts — and the roofline
    terms derived from them — reflect the real work.  Online-softmax merging
    is order-independent, so processing tiles class-by-class is exact."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    assert sq == skv and q_offset == 0, "block-skip path is for self-attention"
    kvh = cfg.n_kv_heads
    g = h // kvh
    bs = min(FLASH_BLOCK_Q, sq)
    # Non-block-multiple S: zero-pad to the tile grid.  Padded key columns
    # only ever appear in diagonal tiles (every off-diagonal pair reads
    # earlier, fully-real key blocks), where the causal mask already excludes
    # them for real query rows (col > row); padded query rows are sliced off.
    sq_real = sq
    pad = (-sq) % bs
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sq += pad
    nq = sq // bs
    scale = d ** -0.5
    w = cfg.swa_window

    qr = q.reshape(b, nq, bs, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, nq, bs, kvh, d).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nq, bs, kvh, d).transpose(1, 0, 3, 2, 4)

    # --- static tile-pair classification -----------------------------------
    full, diag, edges = [], [], {}
    for qi in range(nq):
        for ki in range(qi + 1):
            r = qi - ki
            if w is not None and r * bs >= w + bs - 1:
                continue                       # fully outside the window
            if r == 0:
                diag.append((qi, ki))
            elif w is not None and (r + 1) * bs > w:
                edges.setdefault(r, []).append((qi, ki))   # window boundary
            else:
                full.append((qi, ki))

    ii = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    diag_mask = ii >= jj
    if w is not None:
        diag_mask &= (ii - jj) < w

    def scan_pairs(carry, pairs, mask):
        if not pairs:
            return carry
        idx = jnp.asarray(pairs, jnp.int32)

        def step(c, p):
            m, l, acc = c
            qi, ki = p[0], p[1]
            qb = jax.lax.dynamic_index_in_dim(qr, qi, 0, keepdims=False)
            kb = jax.lax.dynamic_index_in_dim(kr, ki, 0, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ki, 0, keepdims=False)
            logits = jnp.einsum("bkgqd,bktd->bkgqt", qb, kb,
                                preferred_element_type=jnp.float32) * scale
            if mask is not None:
                logits = jnp.where(mask[None, None, None], logits, -1e30)
            mi = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
            li = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
            ai = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
            m_new = jnp.maximum(mi, logits.max(-1))
            p_ = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(mi - m_new)
            l_new = li * corr + p_.sum(-1)
            a_new = ai * corr[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p_.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0),
                    jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0),
                    jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)), None

        carry, _ = jax.lax.scan(step, carry, idx)
        return carry

    m0 = jnp.full((nq, b, kvh, g, bs), -1e30, jnp.float32)
    l0 = jnp.zeros((nq, b, kvh, g, bs), jnp.float32)
    a0 = jnp.zeros((nq, b, kvh, g, bs, d), jnp.float32)
    carry = (m0, l0, a0)
    carry = scan_pairs(carry, full, None)
    carry = scan_pairs(carry, diag, diag_mask)
    for r, pairs in edges.items():
        edge_mask = (r * bs + ii - jj) < w
        carry = scan_pairs(carry, pairs, edge_mask)
    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, d)[
        :, :sq_real].astype(q.dtype)


def _flash(q, k, v, cfg: ModelConfig) -> jax.Array:
    if FLASH_BLOCK_SKIP and q.shape[1] == k.shape[1]:
        return _attend_flash_blocks(q, k, v, cfg)
    return _attend_flash(q, k, v, cfg)


def _attend(q, k, v, mask, cfg: ModelConfig) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,Kv,D); mask: (B,1,Sq,Skv) or broadcastable."""
    hd = q.shape[-1]
    groups = cfg.n_heads // cfg.n_kv_heads
    b, sq, h, _ = q.shape
    skv = k.shape[1]
    q = q.reshape(b, sq, cfg.n_kv_heads, groups, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, hd)


def _causal_mask(sq: int, skv: int, offset: int, window: Optional[int]) -> jax.Array:
    """(1, 1, sq, skv) boolean mask.  offset = absolute position of query 0."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


def apply_train(params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
                key=None) -> jax.Array:
    """Full-sequence causal (optionally sliding-window) attention."""
    q, k, v = _qkv(params, x, cfg, key)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if s > FLASH_THRESHOLD:
        out = _flash(q, k, v, cfg)
    else:
        mask = _causal_mask(s, s, 0, cfg.swa_window)
        out = _attend(q, k, v, mask, cfg)
    return common.dense_tp_reduce(params["wo"], _merge_heads(out),
                                  cfg.site_tdvmm("attn.out"), key)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    """Rolling cache of size min(max_len, window) for SWA archs."""
    size = max_len if cfg.swa_window is None else min(max_len, cfg.swa_window)
    shape = (batch, size, cfg.n_kv_heads, cfg.resolved_head_dim)
    if KV_CACHE_INT8:
        sshape = shape[:-1]
        return KVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                       jnp.zeros((batch,), jnp.int32),
                       jnp.zeros(sshape, jnp.float32),
                       jnp.zeros(sshape, jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def apply_prefill(params, x: jax.Array, cfg: ModelConfig, cache: KVCache,
                  key=None) -> tuple[jax.Array, KVCache]:
    """Process a full prompt, filling the cache (assumes cache.pos == 0)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(params, x, cfg, key)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    if s > FLASH_THRESHOLD:
        out = _flash(q, k, v, cfg)
    else:
        mask = _causal_mask(s, s, 0, cfg.swa_window)
        out = _attend(q, k, v, mask, cfg)

    size = cache.k.shape[1]
    k_store, v_store = k, v
    k_sc = v_sc = None
    if cache.k_scale is not None:
        k_store, k_sc = _kv_quantize(k)
        v_store, v_sc = _kv_quantize(v)
    if size >= s:
        new_k = jax.lax.dynamic_update_slice(
            cache.k, k_store.astype(cache.k.dtype), (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache.v, v_store.astype(cache.v.dtype), (0, 0, 0, 0))
        if k_sc is not None:
            k_sc = jax.lax.dynamic_update_slice(cache.k_scale, k_sc, (0, 0, 0))
            v_sc = jax.lax.dynamic_update_slice(cache.v_scale, v_sc, (0, 0, 0))
    else:  # rolling SWA cache keeps the last `size` tokens, ring-aligned so that
        # absolute position p lives at slot p % size (what decode expects).
        shift = s % size
        new_k = jnp.roll(k_store[:, -size:], shift, axis=1).astype(cache.k.dtype)
        new_v = jnp.roll(v_store[:, -size:], shift, axis=1).astype(cache.v.dtype)
        if k_sc is not None:
            k_sc = jnp.roll(k_sc[:, -size:], shift, axis=1)
            v_sc = jnp.roll(v_sc[:, -size:], shift, axis=1)
    new_cache = KVCache(new_k, new_v, jnp.full((b,), s, jnp.int32), k_sc, v_sc)
    y = common.dense(params["wo"], _merge_heads(out),
                     cfg.site_tdvmm("attn.out"), key)
    return y, new_cache


# --------------------------------------------------------------------------
# Paged KV cache (serving engine): block-table-indexed pages instead of a
# dense (B, max_len) buffer.  See runtime/paged_cache.py for the layout and
# the trash-page convention; the engine (runtime/engine.py) owns allocation.
# --------------------------------------------------------------------------
class PagedKVCache(NamedTuple):
    k: jax.Array          # (num_pages+1, page_size, n_kv, head_dim); last
    #                       page is the write sink for padded/inactive rows
    v: jax.Array
    k_scale: jax.Array | None = None   # (num_pages+1, page_size, n_kv) int8 mode
    v_scale: jax.Array | None = None


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype, ranks: int = 1) -> PagedKVCache:
    """One attention layer's page pool (+1 trash page per rank).  Honors the
    same KV_CACHE_INT8 switch as the dense cache.  ``ranks > 1`` stacks one
    ``num_pages + 1`` region per DP rank (see ``runtime.paged_cache.PagePool``
    for the global page-id arithmetic)."""
    if cfg.swa_window is not None:
        raise NotImplementedError(
            "paged KV cache does not support sliding-window archs yet "
            "(the ring buffer already bounds their dense cache)")
    shape = (ranks * (num_pages + 1), page_size, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    if KV_CACHE_INT8:
        sshape = shape[:-1]
        return PagedKVCache(jnp.zeros(shape, jnp.int8),
                            jnp.zeros(shape, jnp.int8),
                            jnp.zeros(sshape, jnp.float32),
                            jnp.zeros(sshape, jnp.float32))
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _paged_read(cache: PagedKVCache, k_buf, v_buf, k_sc, v_sc, tables, dtype):
    """Gather a slot's pages into position order.  tables: (..., P) page ids
    -> k/v (..., P*page_size, n_kv, head_dim) in the compute dtype."""
    k_read = k_buf[tables]                       # (..., P, ps, kv, hd)
    v_read = v_buf[tables]
    flat = k_read.shape[:-4] + (-1,) + k_read.shape[-2:]
    k_read = k_read.reshape(flat)
    v_read = v_read.reshape(flat)
    if cache.k_scale is not None:
        ks = k_sc[tables].reshape(flat[:-2] + k_sc.shape[-1:])
        vs = v_sc[tables].reshape(flat[:-2] + v_sc.shape[-1:])
        return (_kv_dequantize(k_read, ks, dtype),
                _kv_dequantize(v_read, vs, dtype))
    return k_read.astype(dtype), v_read.astype(dtype)


def apply_prefill_paged(params, x: jax.Array, cfg: ModelConfig,
                        cache: PagedKVCache, ctx, key=None
                        ) -> tuple[jax.Array, PagedKVCache]:
    """One fixed-size prefill chunk for ONE slot (the engine's compiled
    prefill step body).  x: (1, C, d); ctx: runtime.paged_cache.PrefillChunkCtx.

    Tokens [offset, offset + valid) of the slot's prompt are projected,
    rope'd at their global positions, written into the slot's pages via the
    block-table row, and attended against every page the slot owns (earlier
    chunks included) under the global causal mask.  Padded rows (>= valid)
    write to the trash page and their outputs are garbage the engine drops.
    Bit-for-bit identical to ``apply_prefill`` on the whole prompt when the
    chunk covers it AND the cache is not int8-quantized (per-row
    encode/attend; masked tail keys contribute exact zeros).  Under
    KV_CACHE_INT8 this path attends over the quantize->dequantize KV it
    just wrote (earlier chunks can only be read back dequantized), whereas
    dense ``apply_prefill`` attends over the full-precision k/v before
    storing — the engine's isolation contract is therefore engine-vs-solo-
    engine in int8 mode, not engine-vs-dense."""
    _, c, _ = x.shape
    ps = cache.k.shape[1]
    trash = cache.k.shape[0] - 1
    n_rows = ctx.block_row.shape[0]
    gpos = ctx.offset + jnp.arange(c, dtype=jnp.int32)       # (C,) global
    positions = gpos[None]
    q, k, v = _qkv(params, x, cfg, key)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    in_chunk = jnp.arange(c, dtype=jnp.int32) < ctx.valid
    pid = ctx.block_row[jnp.minimum(gpos // ps, n_rows - 1)]
    pid = jnp.where(in_chunk, pid, trash)                    # (C,)
    off = gpos % ps

    def write(buf, val):                                     # val: (C, ...)
        return buf.at[pid, off].set(val.astype(buf.dtype))

    k_sc = v_sc = None
    if cache.k_scale is not None:
        k_q, k_s1 = _kv_quantize(k)
        v_q, v_s1 = _kv_quantize(v)
        new_k = write(cache.k, k_q[0])
        new_v = write(cache.v, v_q[0])
        k_sc = write(cache.k_scale, k_s1[0])
        v_sc = write(cache.v_scale, v_s1[0])
    else:
        new_k = write(cache.k, k[0])
        new_v = write(cache.v, v[0])

    k_read, v_read = _paged_read(cache, new_k, new_v, k_sc, v_sc,
                                 ctx.block_row[None], q.dtype)
    kpos = jnp.arange(n_rows * ps, dtype=jnp.int32)
    mask = (kpos[None, :] <= gpos[:, None]) \
        & (kpos[None, :] < ctx.offset + ctx.valid)
    out = _attend(q, k_read, v_read, mask[None, None], cfg)
    y = common.dense(params["wo"], _merge_heads(out),
                     cfg.site_tdvmm("attn.out"), key)
    return y, PagedKVCache(new_k, new_v, k_sc, v_sc)


def apply_decode_paged(params, x: jax.Array, cfg: ModelConfig,
                       cache: PagedKVCache, ctx, key=None
                       ) -> tuple[jax.Array, PagedKVCache]:
    """Batched one-token decode over all B slots (the engine's compiled
    decode step body).  x: (B, 1, d); ctx: runtime.paged_cache.DecodeCtx.

    Each active slot writes its new KV at position ``pos`` through its
    block-table row and attends over its own gathered pages; inactive slots
    write to the trash page, never advance, and produce ignored outputs.
    There is NO decode-past-capacity poisoning path here: the engine evicts
    a request *before* its next write would overflow its page budget, so an
    overflowing write can never corrupt (or NaN) a neighbor slot."""
    b = x.shape[0]
    ps = cache.k.shape[1]
    trash = cache.k.shape[0] - 1
    n_rows = ctx.block_tables.shape[1]
    pos = ctx.pos
    positions = pos[:, None]
    q, k, v = _qkv(params, x, cfg, key)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    page_idx = jnp.minimum(pos // ps, n_rows - 1)
    pid = jnp.take_along_axis(ctx.block_tables, page_idx[:, None], 1)[:, 0]
    pid = jnp.where(ctx.active, pid, trash)                  # (B,)
    off = pos % ps

    def write(buf, val):                                     # val: (B, ...)
        return buf.at[pid, off].set(val.astype(buf.dtype))

    k_sc = v_sc = None
    if cache.k_scale is not None:
        k_q, k_s1 = _kv_quantize(k)
        v_q, v_s1 = _kv_quantize(v)
        new_k = write(cache.k, k_q[:, 0])
        new_v = write(cache.v, v_q[:, 0])
        k_sc = write(cache.k_scale, k_s1[:, 0])
        v_sc = write(cache.v_scale, v_s1[:, 0])
    else:
        new_k = write(cache.k, k[:, 0])
        new_v = write(cache.v, v[:, 0])

    k_read, v_read = _paged_read(cache, new_k, new_v, k_sc, v_sc,
                                 ctx.block_tables, q.dtype)
    kpos = jnp.arange(n_rows * ps, dtype=jnp.int32)
    mask = (kpos[None, :] <= pos[:, None])[:, None, None, :]  # (B,1,1,cap)
    out = _attend(q, k_read, v_read, mask, cfg)
    y = common.dense(params["wo"], _merge_heads(out),
                     cfg.site_tdvmm("attn.out"), key)
    return y, PagedKVCache(new_k, new_v, k_sc, v_sc)


def apply_decode(params, x: jax.Array, cfg: ModelConfig, cache: KVCache,
                 key=None) -> tuple[jax.Array, KVCache]:
    """One-token decode step.  x: (B, 1, d)."""
    b = x.shape[0]
    pos = cache.pos                                      # (B,) int32
    positions = pos[:, None]                             # (B, 1)
    q, k, v = _qkv(params, x, cfg, key)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    size = cache.k.shape[1]
    if cfg.swa_window is not None:
        slot = pos % size            # ring buffer: every position has a slot
        over = None
    else:
        # A full (non-rolling) cache has exactly `size` slots.  Decoding past
        # capacity used to silently pin slot = size-1, overwriting the last
        # KV entry every step and corrupting attention from then on.  With
        # concrete positions (eager serving) this now raises; under a jit
        # trace the overflowing rows drop their cache write, stop advancing
        # ``pos``, and poison their outputs with NaN — failing loudly
        # instead of decoding against a corrupted cache.
        over = pos >= size
        try:
            if bool(jnp.any(over)):
                raise ValueError(
                    f"attention.apply_decode: KV cache capacity exceeded "
                    f"(pos={pos} >= size={size}); grow max_len or use a "
                    "sliding-window config")
            over = None
        except jax.errors.ConcretizationTypeError:
            pass
        slot = jnp.minimum(pos, size - 1)
    rows = jnp.arange(b)

    def write(buf, val):
        """Write this step's (B, ...) entry to each row's slot; overflowed
        rows re-write the slot's existing value (cache left untouched)."""
        val = val.astype(buf.dtype)
        if over is not None:
            keep = over.reshape((-1,) + (1,) * (val.ndim - 1))
            val = jnp.where(keep, buf[rows, slot], val)
        return buf.at[rows, slot].set(val)

    k_sc = v_sc = None
    if cache.k_scale is not None:
        k_q, k_s1 = _kv_quantize(k)
        v_q, v_s1 = _kv_quantize(v)
        new_k = write(cache.k, k_q[:, 0])
        new_v = write(cache.v, v_q[:, 0])
        k_sc = write(cache.k_scale, k_s1[:, 0])
        v_sc = write(cache.v_scale, v_s1[:, 0])
        k_read = _kv_dequantize(new_k, k_sc, q.dtype)
        v_read = _kv_dequantize(new_v, v_sc, q.dtype)
    else:
        new_k = write(cache.k, k[:, 0])
        new_v = write(cache.v, v[:, 0])
        k_read = new_k.astype(q.dtype)
        v_read = new_v.astype(q.dtype)

    kpos = jnp.arange(size)
    if cfg.swa_window is not None:
        # ring buffer: valid entries were written within the last `size` steps
        age = (slot[:, None] - kpos[None, :]) % size
        valid = age <= jnp.minimum(pos, size - 1)[:, None]
    else:
        valid = kpos[None, :] <= pos[:, None]
    mask = valid[:, None, None, :]                       # (B, 1, 1, S)
    out = _attend(q, k_read, v_read, mask, cfg)
    y = common.dense(params["wo"], _merge_heads(out),
                     cfg.site_tdvmm("attn.out"), key)
    pos_next = pos + 1
    if over is not None:
        y = jnp.where(over[:, None, None], jnp.float32(jnp.nan).astype(y.dtype), y)
        pos_next = jnp.where(over, pos, pos_next)
    return y, KVCache(new_k, new_v, pos_next, k_sc, v_sc)
