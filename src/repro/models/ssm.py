"""Mamba-2 blocks (state-space duality / SSD, arXiv:2405.21060).

Recurrence (per head h, head channels P, state channels S):

    H_t = exp(A * dt_t) * H_{t-1} + dt_t * B_t (x) x_t          H: (P, S)
    y_t = C_t . H_t + D * x_t

Training/prefill uses the chunked SSD form: an intra-chunk quadratic
(attention-like) term plus an inter-chunk state recurrence over L/Q chunks —
the TPU-friendly blocking of the scan (see kernels/ssd for the Pallas tiling;
this module is the reference/pjit path, numerically identical).

Weights are stored as separate projections (z, x, B, C, dt) rather than one
fused in_proj so each output dim TP-shards cleanly; in TD-VMM mode the five
matrices still execute as ONE shared-input grouped launch (site
``ssm.in_proj`` — the input is encoded once for all five tiles).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common


class SSMCache(NamedTuple):
    conv: jax.Array      # (B, d_conv-1, conv_channels) — last conv inputs
    state: jax.Array     # (B, H, P, S) — SSD recurrent state
    pos: jax.Array       # (B,) int32 — per-sequence (ragged decode)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.exp(
        jax.random.uniform(ks[0], (n_heads,)) * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
        + jnp.log(s.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))       # inverse softplus
    return {
        "wz": common.dense_init(ks[1], d, d_inner, dtype),
        "wx": common.dense_init(ks[2], d, d_inner, dtype),
        "wB": common.dense_init(ks[3], d, s.n_groups * s.d_state, dtype),
        "wC": common.dense_init(ks[4], d, s.n_groups * s.d_state, dtype),
        "wdt": common.dense_init(ks[5], d, n_heads, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "conv_w": (jax.random.normal(ks[6], (s.d_conv, 1, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm": common.rmsnorm_init(d_inner, dtype),
        "wo": common.dense_init(ks[7], d_inner, d, dtype),
    }


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array, left_ctx: jax.Array | None = None):
    """Causal depthwise conv.  x: (B, L, C); w: (width, 1, C).

    left_ctx: (B, width-1, C) previous inputs (decode/chunked prefill), else zeros.
    Returns (y, new_left_ctx)."""
    width = w.shape[0]
    if left_ctx is None:
        left_ctx = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([left_ctx, x], axis=1)
    y = jax.lax.conv_general_dilated(
        xp, w.astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[2],
    ) + b.astype(x.dtype)
    new_ctx = xp[:, -(width - 1):, :] if width > 1 else left_ctx
    return y, new_ctx


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """Chunked SSD scan.

    x:  (B, L, H, P)   head inputs
    dt: (B, L, H)      positive step sizes (post-softplus)
    a_log: (H,)        A = -exp(a_log)
    b, c: (B, L, G, S) input/output projections (G groups broadcast over heads)
    Returns (y (B,L,H,P), final_state (B,H,P,S)).
    """
    bsz, L, H, Pd = x.shape
    G = b.shape[2]
    S = b.shape[3]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        # dt=0 padding is inert: decay exp(0)=1 keeps the state, update dt*B*x
        # contributes nothing; padded outputs are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L_pad = L + pad
    nc = L_pad // Q
    rep = H // G

    a = -jnp.exp(a_log)                                    # (H,)
    dta = dt.astype(jnp.float32) * a                       # (B, L, H) log decay
    x_ = x.reshape(bsz, nc, Q, H, Pd)
    dt_ = dt.reshape(bsz, nc, Q, H).astype(jnp.float32)
    dta_ = dta.reshape(bsz, nc, Q, H)
    b_ = b.reshape(bsz, nc, Q, G, S)
    c_ = c.reshape(bsz, nc, Q, G, S)
    # broadcast groups to heads
    bh = jnp.repeat(b_, rep, axis=3)                       # (B,nc,Q,H,S)
    ch = jnp.repeat(c_, rep, axis=3)

    cum = jnp.cumsum(dta_, axis=2)                         # (B,nc,Q,H) L_i
    total = cum[:, :, -1]                                  # (B,nc,H)

    # ---- intra-chunk (quadratic in Q) ----
    # M[i,j] = exp(L_i - L_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H) L_i - L_j
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    m = jnp.where(causal, jnp.exp(diff), 0.0)
    g = jnp.einsum("bnihs,bnjhs->bnijh", ch.astype(jnp.float32), bh.astype(jnp.float32))
    w = g * m * dt_[:, :, None, :, :]                      # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", w, x_.astype(jnp.float32))

    # ---- per-chunk end state ----
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)     # (B,nc,Q,H)
    sc = jnp.einsum(
        "bnqhs,bnqh,bnqhp->bnhps",
        bh.astype(jnp.float32), decay_to_end * dt_, x_.astype(jnp.float32))

    # ---- inter-chunk recurrence over nc ----
    def step(carry, inp):
        s_chunk, tot = inp                                 # (B,H,P,S), (B,H)
        prev = carry
        new = prev * jnp.exp(tot)[:, :, None, None] + s_chunk
        return new, prev

    init_state = jnp.zeros((bsz, H, Pd, S), jnp.float32)
    final, prev_states = jax.lax.scan(
        step,
        init_state,
        (sc.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,S)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(cum)                        # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bnqhs,bnhps,bnqh->bnqhp",
        ch.astype(jnp.float32), prev_states, decay_from_start)

    y = (y_intra + y_inter).reshape(bsz, L_pad, H, Pd)[:, :L]
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, a_log, b, c):
    """Single-token recurrence.  x: (B,H,P); dt: (B,H); b,c: (B,G,S);
    state: (B,H,P,S).  Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    G = b.shape[1]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)    # (B,H,S)
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    a = -jnp.exp(a_log)
    dta = dt.astype(jnp.float32) * a                       # (B,H)
    decay = jnp.exp(dta)[:, :, None, None]
    upd = jnp.einsum("bhs,bh,bhp->bhps", bh, dt.astype(jnp.float32), x.astype(jnp.float32))
    new_state = state * decay + upd
    y = jnp.einsum("bhs,bhps->bhp", ch, new_state)
    return y.astype(x.dtype), new_state


def _project(params, u, cfg: ModelConfig, key):
    """z/x/B/C/dt input projections as ONE grouped TD-VMM launch (site
    ``ssm.in_proj``): u is encoded once and the five weight matrices run as
    five tiles of a single batched kernel dispatch."""
    td = cfg.site_tdvmm("ssm.in_proj")
    return common.dense_group(
        (params["wz"], params["wx"], params["wB"], params["wC"],
         params["wdt"]), u, td, key)


def apply_train(params, u: jax.Array, cfg: ModelConfig, key=None) -> jax.Array:
    """Full-sequence Mamba-2 block.  u: (B, L, d)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    bsz, L, _ = u.shape
    z, xc, bc, cc, dt = _project(params, u, cfg, key)
    xbc = jnp.concatenate([xc, bc, cc], axis=-1)
    xbc, _ = _conv1d(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xc, bc, cc = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xh = xc.reshape(bsz, L, n_heads, s.head_dim)
    bg = bc.reshape(bsz, L, s.n_groups, s.d_state)
    cg = cc.reshape(bsz, L, s.n_groups, s.d_state)
    y, _ = ssd_chunked(xh, dt, params["A_log"], bg, cg, s.chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, L, d_inner)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return common.dense(params["wo"], y, cfg.site_tdvmm("ssm.out"), key)


def init_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        state=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def apply_prefill(params, u: jax.Array, cfg: ModelConfig, cache: SSMCache,
                  key=None) -> tuple[jax.Array, SSMCache]:
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    bsz, L, _ = u.shape
    z, xc, bc, cc, dt = _project(params, u, cfg, key)
    xbc = jnp.concatenate([xc, bc, cc], axis=-1)
    xbc, conv_ctx = _conv1d(xbc, params["conv_w"], params["conv_b"], cache.conv)
    xbc = jax.nn.silu(xbc)
    xc, bc, cc = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xh = xc.reshape(bsz, L, n_heads, s.head_dim)
    bg = bc.reshape(bsz, L, s.n_groups, s.d_state)
    cg = cc.reshape(bsz, L, s.n_groups, s.d_state)
    y, state = ssd_chunked(xh, dt, params["A_log"], bg, cg, s.chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, L, d_inner)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = common.dense(params["wo"], y, cfg.site_tdvmm("ssm.out"), key)
    return out, SSMCache(conv_ctx, state, jnp.full((bsz,), L, jnp.int32))


def apply_decode(params, u: jax.Array, cfg: ModelConfig, cache: SSMCache,
                 key=None) -> tuple[jax.Array, SSMCache]:
    """One-token step.  u: (B, 1, d)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = _dims(cfg)
    bsz = u.shape[0]
    z, xc, bc, cc, dt = _project(params, u, cfg, key)
    xbc = jnp.concatenate([xc, bc, cc], axis=-1)           # (B, 1, conv_ch)
    xbc, conv_ctx = _conv1d(xbc, params["conv_w"], params["conv_b"], cache.conv)
    xbc = jax.nn.silu(xbc)[:, 0]
    xc1, bc1, cc1 = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    xh = xc1.reshape(bsz, n_heads, s.head_dim)
    bg = bc1.reshape(bsz, s.n_groups, s.d_state)
    cg = cc1.reshape(bsz, s.n_groups, s.d_state)
    y, state = ssd_decode_step(cache.state, xh, dt1, params["A_log"], bg, cg)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = common.dense(params["wo"], y, cfg.site_tdvmm("ssm.out"), key)
    return out, SSMCache(conv_ctx, state, cache.pos + 1)
