"""Shared model components: norms, rotary embeddings, initialized dense layers.

All modules are functional pytrees: ``init(key, ...) -> params`` and
``apply(params, x, ...) -> y``.  Every dense matmul goes through
``core.layers.td_matmul`` so any linear can execute in TD-VMM mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import TDVMMLayerConfig, td_grouped_matmul, td_matmul
from repro.launch import compat


def resolve_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def constrain_batch(x: jax.Array) -> jax.Array:
    """Anchor activations' batch dim to the DP axes (no-op without a mesh).

    Batch size 1 (long_500k) stays replicated — GSPMD can't split it."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import meshctx

    mesh = meshctx.get_mesh()
    if mesh is None:
        return x
    dp = meshctx.dp_axes()
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    if x.shape[0] % n != 0:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings (rotate-half convention)
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Matmul output dtype control (perf knob; see EXPERIMENTS.md §Perf it.1)
# --------------------------------------------------------------------------
# When set to bf16, every dense matmul emits bf16 partial sums
# (preferred_element_type), so GSPMD's tensor-parallel all-reduces move half
# the bytes.  MXU still accumulates in f32 internally on TPU.
_MATMUL_OUT_DTYPE = None


def set_matmul_out_dtype(dtype):
    global _MATMUL_OUT_DTYPE
    _MATMUL_OUT_DTYPE = dtype


def matmul_out_dtype():
    return _MATMUL_OUT_DTYPE


# --------------------------------------------------------------------------
# Dense (TD-VMM-aware)
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, bias: bool = False,
               scale: float | None = None):
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x: jax.Array, td: TDVMMLayerConfig, key=None) -> jax.Array:
    y = td_matmul(x, params["w"], td, key)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def dense_group(param_group, x: jax.Array, td: TDVMMLayerConfig,
                key=None) -> tuple[jax.Array, ...]:
    """G same-input dense projections as ONE shared-input TD-VMM launch.

    The grouped sites (``attn.qkv``: wq/wk/wv, ``ssm.in_proj``:
    wz/wx/wB/wC/wdt) project the same activation through several matrices;
    this encodes x once and runs all G members as a single ragged column
    concat launch (``core.layers.td_grouped_matmul`` — each member padded
    only to the 128 lane, not to the widest member, so uneven GQA widths
    carry no padding overhead) instead of G ``dense`` calls.  Biases stay
    per-member digital adds."""
    ys = td_grouped_matmul(x, tuple(p["w"] for p in param_group), td, key)
    return tuple(
        y + p["b"].astype(y.dtype) if "b" in p else y
        for p, y in zip(param_group, ys))


# --------------------------------------------------------------------------
# Explicit-TP reduction matmul (perf it.1b — EXPERIMENTS.md §Perf)
# --------------------------------------------------------------------------
# GSPMD places the tensor-parallel all-reduce directly after the partial-sum
# dot, which the CPU backend legalizes to f32 — and on TPU is also f32 when
# the dot accumulates in f32.  For the two reduction matmuls of each block
# (attn wo, ffn w_down) this wrapper makes the collective EXPLICIT: local
# (f/tp) x (f/tp, d) matmul, cast to bf16, psum over the model axis — halving
# the dominant wire bytes.  Weights arrive FSDP+TP sharded; the FSDP gather
# over dp is explicit too (bf16).
TP_EXPLICIT = False


def set_tp_explicit(on: bool):
    global TP_EXPLICIT
    TP_EXPLICIT = on


def dense_tp_reduce(params, x: jax.Array, td: TDVMMLayerConfig, key=None) -> jax.Array:
    """x: (..., f) with f TP-shardable; w: (f, d).  Falls back to dense()
    when explicit TP is off, no mesh is active, or TD-VMM mode is on."""
    from jax.sharding import PartitionSpec as P
    from repro.launch import meshctx

    mesh = meshctx.get_mesh()
    if not TP_EXPLICIT or mesh is None or td.enabled:
        return dense(params, x, td, key)
    dp = meshctx.dp_axes()
    tp = meshctx.tp_axis()
    w = params["w"]
    f, d_out = w.shape
    tpn = mesh.shape[tp]
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    if f % tpn or x.shape[0] % dpn or w.shape[0] % tpn or d_out % dpn:
        return dense(params, x, td, key)

    def inner(x_loc, w_loc):
        # w_loc: (f/tp, d/dp) -> gather FSDP shards (bf16 wire)
        w_full = jax.lax.all_gather(w_loc, dp, axis=1, tiled=True)
        y = jnp.dot(x_loc, w_full)                  # (..., f/tp) @ (f/tp, d)
        y = jax.lax.psum(y.astype(jnp.bfloat16), tp)
        return y

    batch_spec = P(dp, *([None] * (x.ndim - 2)), tp)
    y = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(batch_spec, P(tp, dp)),
        out_specs=P(dp, *([None] * (x.ndim - 1))),
        check_vma=False,
    )(x, w)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "sq_relu":
        r = jax.nn.relu(x)
        return r * r
    if name == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)
