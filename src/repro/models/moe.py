"""Mixture-of-Experts with sort-based capacity dispatch.

Two distribution modes (cfg.moe.impl):

  'local' — experts replicated across the DP axes, expert-FFN hidden dim
            TP-sharded over `model` (fits small expert counts, e.g. Mixtral's
            8 experts on a 16-wide model axis).  Tokens never leave their DP
            shard; the only collective is the down-projection psum over
            `model`.

  'ep'    — expert tables sharded over the DP axes (E_loc = E / dp per shard;
            Kimi-K2: 384/16 = 24 per shard single-pod), hidden dim TP-sharded
            over `model`.  Tokens are routed to the shard owning their expert
            via all_to_all over the DP axes and routed back after the expert
            FFN — classic expert parallelism.

Dispatch is sort-based (argsort by expert id + rank-in-group + scatter into an
(E, capacity, d) buffer): no one-hot dispatch tensors, so it scales to E=384.
Both modes run inside shard_map; on a single device (tests) the same math runs
without collectives.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import calibration
from repro.launch import compat, meshctx
from repro.models import common


def init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 5)
    gated = cfg.act == "silu_glu"
    scale = d ** -0.5

    def expert_bank(k, n):
        ks = jax.random.split(k, 3)
        p = {
            "w_up": (jax.random.normal(ks[0], (n, d, m.d_ff)) * scale).astype(dtype),
            "w_down": (jax.random.normal(ks[1], (n, m.d_ff, d)) * (m.d_ff ** -0.5)).astype(dtype),
        }
        if gated:
            p["w_gate"] = (jax.random.normal(ks[2], (n, d, m.d_ff)) * scale).astype(dtype)
        return p

    p = {
        "router": common.dense_init(keys[0], d, m.n_experts, jnp.float32),
        "experts": expert_bank(keys[1], m.n_experts),
    }
    if m.n_shared_experts:
        p["shared"] = expert_bank(keys[2], m.n_shared_experts)
    return p


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts) + 1
    return max(c, 4)


def _expert_ffn(bank, x, cfg: ModelConfig, tp_axis: Optional[str], key=None,
                site_prefix: str = "moe.expert"):
    """x: (E, C, d) -> (E, C, d).  Hidden dim is TP-sharded when tp_axis given;
    the down-projection partial sums are reduced over tp (in bf16 when the
    matmul-out knob is set — halves the psum wire bytes).

    The up/gate projections resolve the ``<site_prefix>.in`` TD-VMM site and
    the down projection ``<site_prefix>.out`` (routed experts are
    ``moe.expert.*``, always-on shared experts ``moe.shared.*``).  With a
    site enabled, its matmul executes through the QuantizedTensor path
    (core/layers.td_expert_matmul): the expert dim maps onto the TD-VMM
    kernel's batched grid axis — one analog tile per expert — with int8 code
    storage, the backend knob, and (when calibrated) a per-expert
    (E,)-vector readout window honored.  Capacity-padded (ragged) expert
    rows are all-zero codes and contribute zero charge, so the dispatch
    buffer's padding stays exact.  ``key`` (train-time) draws independent
    programming noise per projection when the site's noise flag is on.
    """
    td_in = cfg.site_tdvmm(site_prefix + ".in")
    td_out = cfg.site_tdvmm(site_prefix + ".out")
    keys = iter(jax.random.split(key, 3)) if key is not None else None
    pet = common.matmul_out_dtype()
    kw = {"preferred_element_type": pet} if pet is not None else {}

    def mm(a, wmat, td):
        if td.enabled:
            from repro.core import layers as td_layers
            k = next(keys) if keys is not None else None
            return td_layers.td_expert_matmul(a, wmat, td, key=k)
        return jnp.einsum("ecd,edf->ecf", a, wmat, **kw)

    if "w_gate" in bank:
        h = jax.nn.silu(mm(x, bank["w_gate"], td_in))
        h = h * mm(x, bank["w_up"], td_in)
    else:
        h = common.activation(cfg.act, mm(x, bank["w_up"], td_in))
    y = mm(h, bank["w_down"], td_out)
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    return y


def _route(params, x_flat, cfg: ModelConfig):
    """Router: returns (ids (T,K), gates (T,K), aux losses)."""
    m = cfg.moe
    logits = (x_flat.astype(jnp.float32) @ params["router"]["w"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss.  Expert counts via
    # scatter-add, NOT one_hot: a (T, K, E) one-hot is ~100 MB per layer per
    # microbatch at kimi-k2 scale (perf it.4, EXPERIMENTS.md §Perf).
    me = jnp.mean(probs, axis=0)                                       # (E,)
    counts = jnp.zeros((m.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    ce = counts / ids.shape[0]
    lb_loss = m.n_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return ids, gates.astype(x_flat.dtype), {"lb_loss": lb_loss, "z_loss": z_loss}


def _dispatch_indices(ids: jax.Array, top_k: int):
    """Sort-based dispatch bookkeeping.

    Returns (sorted_expert, pos_in_expert, order, token_idx): entry j of the
    sorted stream goes to buffer slot [sorted_expert[j], pos_in_expert[j]] and
    came from token token_idx[j]."""
    flat = ids.reshape(-1)                                             # (T*K,)
    order = jnp.argsort(flat)                                          # stable
    sorted_expert = flat[order]
    ranks = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    pos = jnp.arange(flat.shape[0]) - ranks
    token_idx = order // top_k
    return sorted_expert, pos, order, token_idx


def _scatter_to_buffer(x_flat, sorted_expert, pos, token_idx, n_experts, capacity):
    buf = jnp.zeros((n_experts, capacity) + x_flat.shape[1:], x_flat.dtype)
    return buf.at[sorted_expert, pos].set(x_flat[token_idx], mode="drop")


def _gather_from_buffer(buf, sorted_expert, pos, order, gates, top_k):
    """Inverse of the scatter; returns (T, d) combined output.

    Unsorting uses the inverse permutation as a GATHER (perf it.4): a scatter
    into a zeros buffer costs an extra zero-fill + random-write pass."""
    vals = buf[sorted_expert, jnp.minimum(pos, buf.shape[1] - 1)]      # (T*K, d)
    vals = jnp.where((pos < buf.shape[1])[:, None], vals, 0.0)
    inv_order = jnp.argsort(order)
    unsorted = vals[inv_order]
    per_k = unsorted.reshape(-1, top_k, vals.shape[-1])
    return jnp.sum(per_k * gates[..., None].astype(vals.dtype), axis=1)


def _moe_local(params, x_flat, cfg: ModelConfig, tp_axis, key=None):
    """Experts replicated over DP; only collective is the tp psum."""
    m = cfg.moe
    ids, gates, aux = _route(params, x_flat, cfg)
    cap = _capacity(x_flat.shape[0], m.top_k, m.n_experts, m.capacity_factor)
    se, pos, order, tok = _dispatch_indices(ids, m.top_k)
    buf = _scatter_to_buffer(x_flat, se, pos, tok, m.n_experts, cap)
    out = _expert_ffn(params["experts"], buf, cfg, tp_axis, key=key)
    y = _gather_from_buffer(out, se, pos, order, gates, m.top_k)
    return y, aux


def _moe_ep(params, x_flat, cfg: ModelConfig, tp_axis, dp_axes, dp_size,
            key=None):
    """Experts sharded over the DP axes; all_to_all routes tokens to owners."""
    m = cfg.moe
    e_loc = m.n_experts // dp_size
    ids, gates, aux = _route(params, x_flat, cfg)
    cap = _capacity(x_flat.shape[0], m.top_k, m.n_experts, m.capacity_factor)
    se, pos, order, tok = _dispatch_indices(ids, m.top_k)
    # send buffer grouped by destination shard: (E, C, d) == (dp, E_loc, C, d)
    buf = _scatter_to_buffer(x_flat, se, pos, tok, m.n_experts, cap)
    buf = buf.reshape(dp_size, e_loc, cap, -1)
    buf = jax.lax.all_to_all(buf, dp_axes, split_axis=0, concat_axis=0, tiled=False)
    # buf: (dp_src, E_loc, C, d) — tokens from every source shard for my experts
    buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, dp_size * cap, -1)
    out = _expert_ffn(params["experts"], buf, cfg, tp_axis, key=key)
    out = out.reshape(e_loc, dp_size, cap, -1).transpose(1, 0, 2, 3)
    out = jax.lax.all_to_all(out, dp_axes, split_axis=0, concat_axis=0, tiled=False)
    out = out.reshape(m.n_experts, cap, -1)
    y = _gather_from_buffer(out, se, pos, order, gates, m.top_k)
    return y, aux


def apply(params, x: jax.Array, cfg: ModelConfig, key=None) -> tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux_losses).  ``key`` enables train-time TD-VMM
    programming noise on the expert (and shared-expert) matmuls when the
    resolved ``moe.expert.*`` / ``moe.shared.*`` site configs set noise."""
    m = cfg.moe
    b, s, d = x.shape
    mesh = meshctx.get_mesh()

    def _noisy(prefix):
        return any(td.enabled and td.noise for td in
                   (cfg.site_tdvmm(prefix + ".in"),
                    cfg.site_tdvmm(prefix + ".out")))

    # Split once so routed and shared experts draw independent noise; the
    # routed key is replicated into shard_map (noise must agree across tp
    # shards of one expert, and experts draw independently via array shape).
    k_shared = k_routed = None
    if key is not None and (_noisy("moe.expert") or _noisy("moe.shared")):
        k_shared, k_routed = jax.random.split(key)
    shared_y = 0.0
    if m.n_shared_experts:
        flat = x.reshape(1, b * s, d)
        shared_y = _expert_ffn(
            {k: v for k, v in params["shared"].items()}, flat, cfg, None,
            key=k_shared, site_prefix="moe.shared",
        ).reshape(b, s, d)
        # NB: shared-expert tp reduction is handled by GSPMD outside shard_map.

    if mesh is None:
        y, aux = _moe_local(params, x.reshape(-1, d), cfg, None, key=k_routed)
        return y.reshape(b, s, d) + shared_y, aux

    dp = meshctx.dp_axes()
    tp = meshctx.tp_axis()
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    # batch=1 decode (long_500k) can't split over dp: run replicated (the
    # dispatch is then redundant across dp shards but numerically identical).
    batch_spec = P(dp, None, None) if b % dp_size == 0 else P(None, None, None)
    e_ax = dp if m.impl == "ep" else None
    expert_spec = {
        k: (P(e_ax, tp, None) if k == "w_down" else P(e_ax, None, tp))
        for k in params["experts"]
    }
    router_spec = jax.tree.map(lambda _: P(None, None), params["router"])

    # Calibrated windows for the routed-expert sites ride in as EXPLICIT
    # shard_map operands, not closures: under impl='ep' a per-expert (E,)
    # window must arrive as each shard's local (E_loc,) slice — same layout
    # as the expert bank's leading dim — and a closure would capture the
    # full outer array on every shard.
    win_map = calibration.runtime_window_map() or {}
    expert_wins = {s: win_map[s] for s in ("moe.expert.in", "moe.expert.out")
                   if s in win_map}

    def _win_spec(w):
        nd = getattr(w, "ndim", 0)
        if e_ax is not None and nd == 1:    # (E,) sliced with the expert dim
            return P(e_ax)
        return P(*((None,) * nd))

    win_specs = {k: _win_spec(v) for k, v in expert_wins.items()}

    def inner(xb, experts, router, wins, *maybe_key):
        p = {"experts": experts, "router": router}
        kk = maybe_key[0] if maybe_key else None
        flat = xb.reshape(-1, d)
        # Re-install the expert windows from the per-shard operands so the
        # TD-VMM sites resolved inside this body see local slices (the outer
        # runtime_windows context still holds the unsharded arrays).
        with calibration.runtime_windows(wins if wins else None):
            if m.impl == "ep":
                if kk is not None:
                    # Each dp shard owns a *different* expert slice: fold the
                    # shard index in so experts draw independent noise.  (Local
                    # mode must NOT fold — experts there are replicated and all
                    # shards need bitwise-identical noise.)
                    for a in dp:
                        kk = jax.random.fold_in(kk, jax.lax.axis_index(a))
                y, aux = _moe_ep(p, flat, cfg, tp, dp, dp_size, key=kk)
            else:
                y, aux = _moe_local(p, flat, cfg, tp, key=kk)
        aux = jax.tree.map(lambda v: jax.lax.pmean(v, dp), aux)
        return y.reshape(xb.shape), aux

    in_specs = (batch_spec, expert_spec, router_spec, win_specs)
    args = (x, params["experts"], params["router"], expert_wins)
    if k_routed is not None:
        in_specs += (P(),)          # noise key: replicated across the mesh
        args += (k_routed,)
    y, aux = compat.shard_map(
        inner,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(batch_spec, P()),
        check_vma=False,
    )(*args)
    return y + shared_y, aux
