"""Config system: model architectures, input shapes, run settings.

TD-VMM configuration is **site-addressable**: every analog matmul in a model
has a canonical site name (``attn.qkv``, ``ffn.in``, ``moe.expert.out``,
``head``, ...) and a ``TDVMMPlan`` maps ordered glob-pattern rules onto
per-site ``TDVMMLayerConfig`` overrides.  ``ModelConfig.tdvmm`` survives as
the plan's default rule — a legacy config with only ``tdvmm`` set resolves
every site to that one config, bit-for-bit identical to the pre-plan API.
Resolution (pattern matching, chain validation, the precision report) lives
in ``repro.configs.plan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

# repro.core.constants has no repro-internal imports (and repro.core's
# __init__ re-exports layer objects lazily), so this does NOT recurse back
# into this module.
from repro.core.constants import TDVMMSpec

def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Frozen (hashable) singleton default: resolved site configs key caches and
# serve as jit-static arguments, so every field must be hashable and two
# default configs must compare (and hash) equal.
_DEFAULT_SPEC = TDVMMSpec()


@dataclasses.dataclass(frozen=True)
class TDVMMLayerConfig:
    """Per-site TD-VMM settings (consumed by core.layers.td_matmul).

    The code-and-scale pipeline (core/quant.py) is encode -> program ->
    integrate -> readout; ``backend`` picks who runs the integrate stage:

      "pallas"  kernels/tdvmm Pallas kernel — Mosaic on TPU, interpret
                (Python-level, slow but exact) elsewhere
      "jnp"     jnp.dot on the same integer codes
      "auto"    pallas on TPU, jnp elsewhere (default)

    Code storage is chosen per call (core/layers.plan_matmul): codes with
    p <= 7 (incl. the default p = 6) store as int8 — quarter the HBM bytes,
    MXU int8 path, *exact* int32 accumulation for any K, so both backends
    are bit-for-bit identical with no envelope caveat.  p = 8 or noisy codes
    fall back to integer-valued f32, exact while |acc| < 2^24 (6-bit codes
    up to K = 4096; td_matmul warns past it).  Noise mode perturbs codes off
    the integer grid, where f32 summation order matters — backends then
    agree only to float tolerance.

    ``out_scale`` caches a calibration-time readout window (see
    ``TDVMMLinear.calibrate`` / ``calibrate_out_scale`` / the model-wide
    ``models.model.calibrate`` pass): serving calls skip the per-call max|z|
    reduction, and the Pallas backend fuses the whole readout + rescale
    epilogue into the kernel.  Expert-batched sites (``moe.expert.*``) may
    carry an ``(E,)`` tuple — one calibrated window per expert tile.

    ``chain`` declares the paper's time-domain chaining: the site's output
    stays in the time domain and feeds the adjacent downstream site directly
    (Fig. 2), dropping the intermediate p-bit readout.  Plan resolution
    validates the pairing (only adjacent tile pairs like ``ffn.in`` ->
    ``ffn.out`` can chain) and rewrites the upstream site to
    ``io_quantize=False``.
    """
    enabled: bool = False
    bits: int = 6                 # time-code (input/output) precision p
    weight_bits: int = 6          # FG programming precision
    backend: str = "auto"         # integrate stage: auto | jnp | pallas
    io_quantize: bool = True      # digital tile boundary (False = time-chained)
    per_channel: bool = True      # per-output-column weight scale
    output_calibration: bool = True  # scale weights so outputs fill the [T,2T]
    # window (section 3.1: "slope ... controlled by appropriate scaling of VMM
    # weights"); modeled as a stop-grad per-tensor output gain.
    out_scale: Optional[float | tuple[float, ...]] = None  # cached calibrated
    # readout window: scalar, or per-expert (E,) tuple on expert-batched sites
    # (overrides output_calibration's per-call max; captured by calibrate())
    noise: bool = False           # stochastic DIBL + tuning noise (train-time)
    chain: bool = False           # declared time-domain chain into the
    # adjacent downstream site (plan-resolved to io_quantize=False upstream)
    site: str = ""                # canonical site name (set by plan resolution)
    spec: TDVMMSpec = _DEFAULT_SPEC

    def replace(self, **kw) -> "TDVMMLayerConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class TDVMMRule:
    """One ordered plan rule: sites matching ``pattern`` (fnmatch glob over
    canonical site names) take the field ``overrides``.  Build with
    ``tdvmm_rule(pattern, **overrides)``; overrides are stored as a sorted
    tuple of pairs so rules stay hashable (jit-static / cache-key safe)."""
    pattern: str
    overrides: tuple[tuple[str, Any], ...] = ()


def tdvmm_rule(pattern: str, **overrides) -> TDVMMRule:
    """``tdvmm_rule("ffn.*", bits=7, backend="pallas")`` — validated rule."""
    valid = {f.name for f in dataclasses.fields(TDVMMLayerConfig)} - {"site"}
    norm = []
    for name in sorted(overrides):
        if name not in valid:
            raise ValueError(
                f"unknown TDVMMLayerConfig field {name!r} in rule for "
                f"{pattern!r} (valid: {sorted(valid)})")
        value = overrides[name]
        if isinstance(value, (list, tuple)):
            value = tuple(float(v) for v in value)
        norm.append((name, value))
    return TDVMMRule(pattern, tuple(norm))


@dataclasses.dataclass(frozen=True)
class TDVMMPlan:
    """Site-addressable TD-VMM plan: ordered glob rules over site names.

    Resolution (``repro.configs.plan.resolve_plan``) starts every site from
    ``default`` (or ``ModelConfig.tdvmm`` when ``default`` is None — the
    deprecation shim that keeps legacy single-config models working), then
    applies each matching rule's overrides in order — later rules win, so
    calibration state can be baked in as appended exact-site rules.

    A rule whose pattern matches no site in the model is legal by default
    (generic plans like ``ffn.*`` apply across families where some sites
    don't exist); resolution reports them in ``ResolvedPlan.unmatched`` /
    ``report()``, and ``strict=True`` turns them into a resolve-time error
    (catches typos like ``atn.qkv``).
    """
    rules: tuple[TDVMMRule, ...] = ()
    default: Optional[TDVMMLayerConfig] = None
    strict: bool = False

    def with_rules(self, *rules: TDVMMRule) -> "TDVMMPlan":
        return dataclasses.replace(self, rules=self.rules + tuple(rules))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden size
    n_shared_experts: int = 0       # always-on experts (Kimi-K2 / DeepSeek style)
    capacity_factor: float = 1.25
    first_k_dense: int = 0          # leading dense layers before MoE starts
    impl: str = "local"             # 'local' (E replicated over dp, TP inside)
    #                                 or 'ep' (experts sharded over dp, all_to_all)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128                # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    act: str = "silu_glu"           # silu_glu | sq_relu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    swa_window: Optional[int] = None    # sliding-window attention (Mistral/Mixtral)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0      # zamba2: shared attn block every k ssm layers
    hybrid_concat_embed: bool = False  # zamba2 concatenates embedding into shared blk
    input_mode: str = "tokens"      # tokens | embeddings (vlm/audio frontend stub)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    tdvmm: TDVMMLayerConfig = dataclasses.field(default_factory=TDVMMLayerConfig)
    # Site-addressable plan; None = legacy shim (every site takes ``tdvmm``).
    tdvmm_plan: Optional[TDVMMPlan] = None
    remat_policy: str = "minimal"   # none | minimal | full
    scan_layers: bool = True

    def site_tdvmm(self, site: str) -> TDVMMLayerConfig:
        """Resolved TD-VMM config for one canonical site name.

        Every analog matmul call site asks for its own config here instead of
        reading the shared ``cfg.tdvmm``; with no plan set this returns
        ``tdvmm`` itself (tagged with the site name), so legacy configs are
        unchanged."""
        from repro.configs import plan as _plan
        return _plan.site_config(self, site)

    @property
    def resolved_tdvmm_plan(self):
        """The concrete site table (``repro.configs.plan.ResolvedPlan``)."""
        from repro.configs import plan as _plan
        return _plan.resolve_plan(self)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad_multiple)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM/hybrid or sliding-window attn)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory checks)."""
        d, hd = self.d_model, self.resolved_head_dim
        v = self.padded_vocab
        n = 0
        n += v * d                                   # embed
        if not self.tie_embeddings:
            n += d * v                               # lm head
        per_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.qkv_bias:
            per_attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        def ffn_params(dff):
            if self.act == "silu_glu":
                return 3 * d * dff
            return 2 * d * dff
        if self.family in ("dense", "vlm", "audio"):
            n += self.n_layers * (per_attn + ffn_params(self.d_ff) + 2 * d)
        elif self.family == "moe":
            m = self.moe
            moe_layers = self.n_layers - m.first_k_dense
            n += self.n_layers * (per_attn + 2 * d)
            n += m.first_k_dense * ffn_params(self.d_ff)
            n += moe_layers * (m.n_experts + m.n_shared_experts) * ffn_params(m.d_ff)
            n += moe_layers * d * m.n_experts        # router
        elif self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_inner = s.expand * d
            n_ssm_heads = d_inner // s.head_dim
            per_ssm = d * (2 * d_inner + 2 * s.n_groups * s.d_state + n_ssm_heads) \
                + d_inner * d + 3 * n_ssm_heads + 2 * d \
                + s.d_conv * (d_inner + 2 * s.n_groups * s.d_state)
            n += self.n_layers * per_ssm
            if self.family == "hybrid" and self.hybrid_attn_every:
                shared = per_attn + ffn_params(self.d_ff) + 2 * d
                if self.hybrid_concat_embed:
                    shared += 2 * d * d
                n += shared                          # one shared block
        n += d                                       # final norm
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        def ffn_params(dff):
            return (3 if self.act == "silu_glu" else 2) * d * dff
        total = self.param_count()
        moe_layers = self.n_layers - m.first_k_dense
        inactive = moe_layers * (m.n_experts - m.top_k) * ffn_params(m.d_ff)
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    microbatch_per_shard: int = 0   # 0 -> auto (see launch/train.py)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"             # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # bf16 moments for the 1T-param config
    grad_compression: str = "none"  # none | int8  (error-feedback all-reduce)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    seed: int = 0
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
