from repro.configs.archs import ARCHS, get_config, smoke
from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    TDVMMLayerConfig,
)

__all__ = [
    "ARCHS", "get_config", "smoke", "ModelConfig", "MoEConfig",
    "OptimizerConfig", "RunConfig", "ShapeConfig", "SHAPES", "SSMConfig",
    "TDVMMLayerConfig",
]
