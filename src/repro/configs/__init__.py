from repro.configs.archs import ARCHS, get_config, smoke
from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    TDVMMLayerConfig,
    TDVMMPlan,
    TDVMMRule,
    tdvmm_rule,
)
from repro.configs.plan import ResolvedPlan, model_sites, resolve_plan

__all__ = [
    "ARCHS", "get_config", "smoke", "ModelConfig", "MoEConfig",
    "OptimizerConfig", "RunConfig", "ShapeConfig", "SHAPES", "SSMConfig",
    "TDVMMLayerConfig", "TDVMMPlan", "TDVMMRule", "tdvmm_rule",
    "ResolvedPlan", "model_sites", "resolve_plan",
]
