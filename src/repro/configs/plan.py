"""Site-addressable TD-VMM plan resolution.

Every analog matmul in a model has a **canonical site name**; a
``TDVMMPlan`` is an ordered list of (glob pattern -> field overrides) rules
resolved once per model into a concrete site table.  The paper's system
claim — tiles "chained together to implement large-scale circuits completely
in a time domain" — becomes a declared plan property: a site with
``chain=True`` pairs with its adjacent downstream tile and drops the
intermediate digital (p-bit readout) boundary.

Canonical sites by model family:

    dense / vlm / audio   attn.qkv  attn.out  ffn.in  ffn.out  head
    moe                   attn.qkv  attn.out  [ffn.* if first_k_dense]
                          moe.expert.in  moe.expert.out
                          [moe.shared.in  moe.shared.out]  head
    ssm                   ssm.in_proj  ssm.out  head
    hybrid (zamba2)       ssm.in_proj  ssm.out  [attn.* ffn.* hybrid.fuse
                          for the shared block]  head

(``head`` is absent for tied-embedding models — the tied head is a transpose
of the embedding table and never routes through ``td_matmul``.)

Resolution: each site starts from ``plan.default`` (or ``ModelConfig.tdvmm``
when the plan has no default — the deprecation shim), then every matching
rule's overrides apply in order (later rules win).  ``chain=True`` sites are
validated here: only adjacent tile pairs (``CHAINABLE``) can chain, both
ends must be enabled, and the upstream site is rewritten to
``io_quantize=False`` — its latch output feeds the next tile as turn-on
times instead of round-tripping through the shared-counter ADC.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
from typing import Optional

from repro.configs.base import (
    ModelConfig, TDVMMLayerConfig, TDVMMPlan, TDVMMRule)

# Adjacent tile pairs whose intermediate boundary may go analog (the
# downstream matmul consumes the upstream matmul's output directly, with only
# element-wise ops in between — attention and the SSD scan are not
# element-wise, so attn.qkv -> attn.out / ssm.in_proj -> ssm.out cannot
# chain).
CHAINABLE: dict[str, str] = {
    "ffn.in": "ffn.out",
    "moe.expert.in": "moe.expert.out",
    "moe.shared.in": "moe.shared.out",
}

# Grouped sites: one site name covering G same-input projection matrices
# that execute as a single shared-input TD-VMM launch
# (``core.layers.td_grouped_matmul``) — the input is encoded once and feeds
# all G tiles, and calibration records one (G,) window vector for the site
# (member order below).  Width 1 (everything else) is a plain 2-D launch.
GROUPED_SITES: dict[str, tuple[str, ...]] = {
    "attn.qkv": ("wq", "wk", "wv"),
    "ssm.in_proj": ("wz", "wx", "wB", "wC", "wdt"),
}


def site_group_width(site: str) -> int:
    """How many projection matrices one launch of this site covers."""
    return len(GROUPED_SITES.get(site, ())) or 1


def model_sites(cfg: ModelConfig) -> tuple[str, ...]:
    """Canonical site names present in this model, in stack order."""
    sites: list[str] = []
    attn = ("attn.qkv", "attn.out")
    ffn = ("ffn.in", "ffn.out")
    if cfg.family in ("dense", "vlm", "audio"):
        sites += [*attn, *ffn]
    elif cfg.family == "moe":
        sites += list(attn)
        if cfg.moe is not None and cfg.moe.first_k_dense:
            sites += list(ffn)
        sites += ["moe.expert.in", "moe.expert.out"]
        if cfg.moe is not None and cfg.moe.n_shared_experts:
            sites += ["moe.shared.in", "moe.shared.out"]
    elif cfg.family == "ssm":
        sites += ["ssm.in_proj", "ssm.out"]
    elif cfg.family == "hybrid":
        sites += ["ssm.in_proj", "ssm.out"]
        if cfg.hybrid_attn_every:
            sites += [*attn, *ffn]
            if cfg.hybrid_concat_embed:
                sites += ["hybrid.fuse"]
    else:
        raise ValueError(f"unknown model family {cfg.family!r}")
    if not cfg.tie_embeddings:
        sites += ["head"]
    return tuple(sites)


def site_linear_shapes(cfg: ModelConfig) -> dict[str, dict]:
    """Per-site weight-matrix shapes applied **per token**, with layer
    multiplicity — the geometry ``core.energy.serving_energy_model`` maps
    onto TD-VMM tiles for the engine's per-request energy accounting.

    Returns ``site -> {"matrices": ((d_in, d_out), ...), "per_token": n}``
    where ``matrices`` lists the weight matrices one application of the site
    touches for one token (MoE experts: only the activated top-k + shared)
    and ``per_token`` is how many layer instances apply per token.
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    n_in = 2 if cfg.act == "silu_glu" else 1
    shapes: dict[str, dict] = {}

    def attn_ffn(layers: int, d_ff: int):
        return {
            "attn.qkv": {"matrices": ((d, cfg.n_heads * hd),
                                      (d, cfg.n_kv_heads * hd),
                                      (d, cfg.n_kv_heads * hd)),
                         "per_token": layers},
            "attn.out": {"matrices": ((cfg.n_heads * hd, d),),
                         "per_token": layers},
            "ffn.in": {"matrices": ((d, d_ff),) * n_in, "per_token": layers},
            "ffn.out": {"matrices": ((d_ff, d),), "per_token": layers},
        }

    if cfg.family in ("dense", "vlm", "audio"):
        shapes.update(attn_ffn(cfg.n_layers, cfg.d_ff))
    elif cfg.family == "moe":
        m = cfg.moe
        base = attn_ffn(cfg.n_layers, cfg.d_ff)
        if not m.first_k_dense:
            base.pop("ffn.in"), base.pop("ffn.out")
        else:
            base["ffn.in"]["per_token"] = m.first_k_dense
            base["ffn.out"]["per_token"] = m.first_k_dense
        shapes.update(base)
        moe_layers = cfg.n_layers - m.first_k_dense
        shapes["moe.expert.in"] = {
            "matrices": ((d, m.d_ff),) * (n_in * m.top_k),
            "per_token": moe_layers}
        shapes["moe.expert.out"] = {
            "matrices": ((m.d_ff, d),) * m.top_k, "per_token": moe_layers}
        if m.n_shared_experts:
            shapes["moe.shared.in"] = {
                "matrices": ((d, m.d_ff),) * (n_in * m.n_shared_experts),
                "per_token": moe_layers}
            shapes["moe.shared.out"] = {
                "matrices": ((m.d_ff, d),) * m.n_shared_experts,
                "per_token": moe_layers}
    elif cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        d_inner = s.expand * d
        n_ssm_heads = d_inner // s.head_dim
        gs = s.n_groups * s.d_state
        shapes["ssm.in_proj"] = {
            "matrices": ((d, d_inner), (d, d_inner), (d, gs), (d, gs),
                         (d, n_ssm_heads)),
            "per_token": cfg.n_layers}
        shapes["ssm.out"] = {"matrices": ((d_inner, d),),
                             "per_token": cfg.n_layers}
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            groups = cfg.n_layers // cfg.hybrid_attn_every
            shapes.update(attn_ffn(groups, cfg.d_ff))
            if cfg.hybrid_concat_embed:
                shapes["hybrid.fuse"] = {"matrices": ((2 * d, d),),
                                         "per_token": groups}
    if not cfg.tie_embeddings:
        shapes["head"] = {"matrices": ((d, cfg.padded_vocab),), "per_token": 1}
    return shapes


def plan_launch_shapes(
    cfg: ModelConfig, m: int
) -> tuple[tuple[int, int, int, str], ...]:
    """The deduplicated (M, K, N, code_dtype) kernel launch shapes this
    model's resolved plan emits for an M-token step — the autotune work list
    ``scripts/autotune_tdvmm.py`` sweeps.

    Grouped sites emit their ragged concat launch (one (K, sum of
    lane-rounded member widths) shape, exactly what
    ``core.layers.td_grouped_matmul`` dispatches); everything else emits its
    distinct (d_in, d_out) weight shapes.  ``code_dtype`` is the noise-free
    serving storage the plan would pick (noisy codes force f32 at runtime
    but are a training-only path, not a tuning target).  Sites are included
    whether or not the resolved plan currently enables them — the work list
    is the geometry TD-VMM *would* run on this model, so tuning is not
    invalidated by flipping a site on.
    """
    from repro.core.layers import _plan_code_dtype
    from repro.kernels.tdvmm import tdvmm

    plan = resolve_plan(cfg)
    out: dict[tuple[int, int, int, str], None] = {}
    for site, info in site_linear_shapes(cfg).items():
        sc = plan.get(site)
        if sc is None:
            continue
        mats = info["matrices"]
        if site in GROUPED_SITES:
            k = mats[0][0]
            n_total = sum(
                tdvmm.padded_size(n_g, tdvmm.LANE, tdvmm.LANE)
                for _, n_g in mats)
            shapes = [(k, n_total)]
        else:
            shapes = sorted(set(mats))
        for k, n in shapes:
            out[(m, k, n, _plan_code_dtype(sc, k, noisy=False))] = None
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ResolvedPlan:
    """Concrete site table: every site in the model mapped to its config.

    ``chains`` lists the validated analog boundaries as (upstream,
    downstream) site pairs — the tile borders that skip the intermediate
    p-bit readout entirely.
    """
    sites: tuple[tuple[str, TDVMMLayerConfig], ...]
    chains: tuple[tuple[str, str], ...]
    unmatched: tuple[str, ...] = ()   # rule patterns matching no model site

    @functools.cached_property
    def table(self) -> dict[str, TDVMMLayerConfig]:
        return dict(self.sites)

    def __getitem__(self, site: str) -> TDVMMLayerConfig:
        return self.table[site]

    def get(self, site: str) -> Optional[TDVMMLayerConfig]:
        return self.table.get(site)

    def report(self) -> dict:
        """Plan-level precision report: per-site word widths and which tile
        boundaries stay analog (time-chained) vs digital (p-bit readout)."""
        chained_up = {up for up, _ in self.chains}
        per_site = {}
        for site, c in self.sites:
            if not c.enabled:
                boundary = "digital (td-vmm off)"
            elif site in chained_up:
                boundary = "analog (time-chained)"
            elif not c.io_quantize:
                boundary = "analog (no readout)"
            else:
                boundary = f"digital ({c.bits}-bit readout)"
            per_site[site] = {
                "enabled": c.enabled,
                "bits": c.bits,
                "weight_bits": c.weight_bits,
                "backend": c.backend,
                "boundary": boundary,
                "out_scale": c.out_scale,
                "group": site_group_width(site),
            }
        return {"sites": per_site,
                "analog_boundaries": list(self.chains),
                # Only enabled sites actually run as one grouped launch —
                # with TD-VMM off the members execute as G plain dots.
                "grouped_sites": {
                    s: list(GROUPED_SITES[s]) for s, c in self.sites
                    if s in GROUPED_SITES and c.enabled},
                "n_digital_boundaries": sum(
                    1 for _, c in self.sites if c.enabled and c.io_quantize),
                "unmatched_rules": list(self.unmatched),
                }

    def describe(self) -> str:
        rep = self.report()
        lines = ["site                 bits  group  backend  boundary"]
        for site, r in rep["sites"].items():
            grp = f"x{r['group']}" if r["group"] > 1 else "-"
            lines.append(f"{site:<20} {r['bits']:>4}  {grp:>5}  "
                         f"{r['backend']:<7}  {r['boundary']}")
        if rep["grouped_sites"]:
            grouped = ", ".join(
                f"{s} ({'+'.join(members)}: one launch)"
                for s, members in rep["grouped_sites"].items())
            lines.append(f"grouped launches: {grouped}")
        if rep["analog_boundaries"]:
            pairs = ", ".join(f"{a}->{b}" for a, b in rep["analog_boundaries"])
            lines.append(f"time-domain chains: {pairs}")
        if rep["unmatched_rules"]:
            lines.append("rules matching no site: "
                         + ", ".join(rep["unmatched_rules"]))
        return "\n".join(lines)


def _apply_rules(plan: TDVMMPlan, default: TDVMMLayerConfig,
                 site: str) -> TDVMMLayerConfig:
    cfg = plan.default if plan.default is not None else default
    for rule in plan.rules:
        if fnmatch.fnmatchcase(site, rule.pattern):
            cfg = cfg.replace(**dict(rule.overrides))
    return cfg.replace(site=site)


@functools.lru_cache(maxsize=256)
def _resolve(plan: Optional[TDVMMPlan], default: TDVMMLayerConfig,
             sites: tuple[str, ...]) -> ResolvedPlan:
    plan = plan if plan is not None else TDVMMPlan()
    for rule in plan.rules:
        if not isinstance(rule, TDVMMRule):
            raise TypeError(f"plan rules must be TDVMMRule, got {rule!r}")
    table = {s: _apply_rules(plan, default, s) for s in sites}
    # Rules that matched nothing: fine for generic cross-family plans
    # (``ffn.*`` on an SSM model), fatal under strict (catches typos that
    # would otherwise silently serve a default-configured site).
    unmatched = tuple(
        r.pattern for r in plan.rules
        if not any(fnmatch.fnmatchcase(s, r.pattern) for s in sites))
    if plan.strict and unmatched:
        raise ValueError(
            f"strict plan: rule pattern(s) {list(unmatched)} match no site "
            f"of this model (sites: {sorted(sites)})")
    # Chain validation: declared time-domain chains must pair adjacent,
    # enabled tiles; the upstream boundary then goes analog.
    chains: list[tuple[str, str]] = []
    for site, cfg in table.items():
        if not cfg.chain:
            continue
        down = CHAINABLE.get(site)
        if down is None:
            raise ValueError(
                f"site {site!r} declares chain=True but has no adjacent "
                f"downstream tile (chainable: {sorted(CHAINABLE)})")
        if down not in table:
            raise ValueError(
                f"site {site!r} chains into {down!r}, which this model does "
                f"not have (sites: {sorted(table)})")
        if not cfg.enabled or not table[down].enabled:
            raise ValueError(
                f"time-domain chain {site!r}->{down!r} needs TD-VMM enabled "
                f"on both sites (got {cfg.enabled} -> {table[down].enabled})")
        table[site] = cfg.replace(io_quantize=False)
        chains.append((site, down))
    return ResolvedPlan(sites=tuple((s, table[s]) for s in sites),
                        chains=tuple(chains), unmatched=unmatched)


def resolve_plan(cfg: ModelConfig) -> ResolvedPlan:
    """Resolve a model's plan into its concrete site table (cached — configs
    are frozen/hashable, so identical configs share one resolution)."""
    return _resolve(cfg.tdvmm_plan, cfg.tdvmm, model_sites(cfg))


def site_config(cfg: ModelConfig, site: str) -> TDVMMLayerConfig:
    """Per-site config lookup (the backing impl of ModelConfig.site_tdvmm).

    Unknown site names (not in ``model_sites``) still resolve against the
    rule list — without chain validation — so auxiliary matmuls can opt into
    plan-addressed settings without being first-class sites."""
    hit = resolve_plan(cfg).get(site)
    if hit is not None:
        return hit
    plan = cfg.tdvmm_plan if cfg.tdvmm_plan is not None else TDVMMPlan()
    return _apply_rules(plan, cfg.tdvmm, site)
