"""The 10 assigned architectures, exact configs from the public literature.

Every entry is selectable via ``--arch <id>`` in the launchers.  Sources are
noted per config (see task assignment).  ``smoke(cfg)`` derives the reduced
same-family variant used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig


def yi_34b() -> ModelConfig:
    # [arXiv:2403.04652] llama-arch GQA
    return ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168, n_heads=56,
        n_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
        act="silu_glu", rope_theta=5_000_000.0)


def qwen2_5_14b() -> ModelConfig:
    # [hf:Qwen/Qwen2.5-*] GQA with QKV bias
    return ModelConfig(
        name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=13824, vocab_size=152064,
        act="silu_glu", qkv_bias=True, rope_theta=1_000_000.0)


def qwen1_5_0_5b() -> ModelConfig:
    # [hf:Qwen/Qwen1.5-0.5B] MHA (kv=16), QKV bias
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, head_dim=64, d_ff=2816, vocab_size=151936,
        act="silu_glu", qkv_bias=True, tie_embeddings=True)


def nemotron_4_15b() -> ModelConfig:
    # [arXiv:2402.16819] GQA, squared-ReLU (non-gated) FFN
    return ModelConfig(
        name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144, n_heads=48,
        n_kv_heads=8, head_dim=128, d_ff=24576, vocab_size=256000, act="sq_relu")


def llava_next_mistral_7b() -> ModelConfig:
    # [hf:llava-hf/llava-v1.6-mistral-7b-hf] Mistral-7B backbone (SWA 4096);
    # anyres vision tiling is the stubbed frontend: inputs are patch embeddings.
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
        act="silu_glu", swa_window=4096, input_mode="embeddings")


def musicgen_large() -> ModelConfig:
    # [arXiv:2306.05284] decoder-only over EnCodec tokens; frame-embedding stub.
    return ModelConfig(
        name="musicgen-large", family="audio", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=2048,
        act="gelu", input_mode="embeddings")


def mamba2_1_3b() -> ModelConfig:
    # [arXiv:2405.21060] SSD, attention-free
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048, n_heads=1,
        n_kv_heads=1, d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128))


def mixtral_8x7b() -> ModelConfig:
    # [arXiv:2401.04088] 8 experts top-2, SWA
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
        act="silu_glu", swa_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=14336, impl="local"))


def kimi_k2_1t_a32b() -> ModelConfig:
    # [arXiv:2501.kimi2, paper table] trillion-param MoE: 384 experts top-8
    # (+1 shared), GQA kv=8.  head_dim = 7168/64 = 112.
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, head_dim=112, d_ff=2048, vocab_size=163840,
        act="silu_glu",
        moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared_experts=1,
                      impl="ep"))


def zamba2_2_7b() -> ModelConfig:
    # [arXiv:2411.15242] Mamba2 backbone + shared attention block (with the
    # concat-embedding fuse), every 6 SSM layers.
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560, n_heads=32,
        n_kv_heads=32, head_dim=80, d_ff=10240, vocab_size=32000, act="silu_glu",
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
        hybrid_attn_every=6, hybrid_concat_embed=True)


ARCHS = {
    "yi-34b": yi_34b,
    "qwen2.5-14b": qwen2_5_14b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "nemotron-4-15b": nemotron_4_15b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "musicgen-large": musicgen_large,
    "mamba2-1.3b": mamba2_1_3b,
    "mixtral-8x7b": mixtral_8x7b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "zamba2-2.7b": zamba2_2_7b,
}


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = ARCHS[name]()
    return cfg.replace(**overrides) if overrides else cfg


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (small dims, few layers)."""
    kw = dict(
        n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16, d_ff=128, vocab_size=512, vocab_pad_multiple=16,
        dtype="float32", remat_policy="none",
    )
    if cfg.swa_window is not None:
        kw["swa_window"] = 8
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff=32,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            first_k_dense=min(cfg.moe.first_k_dense, 1))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, expand=2, chunk=8)
    if cfg.family == "hybrid":
        kw["n_layers"] = 4
        kw["hybrid_attn_every"] = 2
    return cfg.replace(**kw)
