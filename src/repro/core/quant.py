"""Unified quantized-code subsystem: the one QuantizedTensor path from
encoding to the TD-VMM kernel.

The paper's multiplier is an *integer-code* machine: p-bit time codes in,
current codes as weights, charge accumulation, p-bit readout.  Every
quantization boundary in the repo routes through this module so that the jnp
reference path, the Pallas kernel, and the event-driven simulator all agree on
what the digital words are.

Stage -> paper mapping (arXiv:1711.10673):

    encode_input      Eq. 2 / section 4.2 — the shared-counter DAC converts a
                      normalized activation into a p-bit rising-edge time code
                      on the grid T0 = T / 2^p (sign = differential wire pair).
    program_weights   sections 2, 4.1 — floating-gate tuning programs each
                      cell's current to one of 2^p_w levels; per-output-column
                      scaling is the "appropriate scaling of VMM weights" of
                      section 3.1.
    (integrate)       Eq. 1 — charge accumulation; lives in kernels/tdvmm
                      (Pallas on TPU / interpret elsewhere) or jnp.dot.
    readout           Eq. 3 / section 4.2 — the comparator-latch + shared
                      counter reads the crossing time back out as a p-bit code
                      over a calibrated output window.

Codes are carried as *integer-valued float32* arrays (the MXU consumes f32;
integer dot products are exact in f32 while |acc| < 2^24 — e.g. 6-bit codes up
to K = 4096).  Every quantizer is wrapped in a straight-through estimator, so
models stay trainable (standard QAT) no matter which backend integrates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import encoding as enc


def ste(x_quant: jax.Array, x: jax.Array) -> jax.Array:
    """Straight-through estimator: forward ``x_quant``, backward identity."""
    return x + jax.lax.stop_gradient(x_quant - x)


def signed_codes(x: jax.Array, bits: int) -> jax.Array:
    """Value in [-1, 1] -> integer-valued signed code in [-L, L], L = 2^p - 1.

    The sign folds the differential (+/-) wire pair of the four-quadrant
    multiplier.  STE in the code domain: forward is the rounded code, backward
    is d(code)/d(x) = L, so dequantizing (code * scale / L) has identity
    gradient in the value domain — exactly the seed fake-quant STE.
    """
    levels = float((1 << bits) - 1)
    q = enc.quantize_code_signed(x, bits).astype(jnp.float32)
    return ste(q, x * levels)


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Integer codes + the scale that maps them back to model units.

    codes:  f32, integer-valued in [-levels, levels] (STE-wrapped, so codes
            are differentiable in the QAT sense).  Programming noise makes
            them non-integer — that models analog current perturbation and is
            still valid kernel input.
    scale:  f32, broadcastable against the dequantized value — per-row
            ``(..., 1)`` for activations, per-channel ``(1, N)`` or per-tensor
            ``(1, 1)`` for weights.  Always stop-gradient.
    bits:   static code width p.
    """

    codes: jax.Array
    scale: jax.Array
    bits: int

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1

    def dequantize(self) -> jax.Array:
        """Back to model units: codes / L * scale."""
        return self.codes * (self.scale / float(self.levels))


jax.tree_util.register_dataclass(
    QuantizedTensor, data_fields=["codes", "scale"], meta_fields=["bits"])


def encode_input(x: jax.Array, bits: int, axis: int = -1) -> QuantizedTensor:
    """Input stage (Eq. 2): per-row range normalization + p-bit time codes.

    The scale is the per-example input range max|x| along ``axis`` (the analog
    front-end normalizes each sample into the [0, T] window); it is
    stop-gradient, matching the seed layer.
    """
    xf = x.astype(jnp.float32)
    # initial=0.0 is an identity for |x| maxes and keeps zero-size batches
    # (e.g. a serving batch filtered to nothing) from hitting the no-identity
    # reduction error; the 1e-6 clamp then supplies the scale.
    s = jax.lax.stop_gradient(jnp.maximum(
        jnp.max(jnp.abs(xf), axis=axis, keepdims=True, initial=0.0), 1e-6))
    return QuantizedTensor(codes=signed_codes(xf / s, bits), scale=s, bits=bits)


def program_weights(
    w: jax.Array, bits: int, per_channel: bool = True
) -> QuantizedTensor:
    """Weight stage (sections 2, 4.1): FG current codes + column scaling.

    ``per_channel`` scales each output column independently (axis 0 of the
    (N_in, N_out) matrix is reduced); otherwise one scale for the whole tile.
    """
    wf = w.astype(jnp.float32)
    axes = 0 if per_channel else None
    w_max = jax.lax.stop_gradient(jnp.maximum(
        jnp.max(jnp.abs(wf), axis=axes, keepdims=True, initial=0.0), 1e-6))
    # No explicit clip: signed_codes' forward already clips to the code range,
    # and the STE linear term must stay unclipped — a clip here would halve
    # the gradient of every per-channel max-magnitude weight (the clip
    # boundary is a min/max tie at exactly |w| == w_max).
    codes = signed_codes(wf / w_max, bits)
    return QuantizedTensor(codes=codes, scale=w_max, bits=bits)


def program_noise(qw: QuantizedTensor, spec, key: jax.Array) -> QuantizedTensor:
    """Stochastic DIBL + FG tuning noise on programmed current codes.

    Multiplicative, so it is identical in the code and value domains; the
    perturbed codes are intentionally non-integer (analog currents).
    """
    from repro.core import nonideal

    err = nonideal.relative_error(
        spec.i_max, jnp.asarray(spec.v_sg), jnp.asarray(spec.delta_vd))
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, qw.codes.shape, minval=-1.0, maxval=1.0)
    codes = qw.codes * (1.0 + err * u)
    codes = codes * jnp.exp(0.003 * jax.random.normal(k2, qw.codes.shape))
    return QuantizedTensor(codes=codes, scale=qw.scale, bits=qw.bits)


def readout(
    y: jax.Array, bits: int, scale: jax.Array | float | None = None
) -> jax.Array:
    """Readout stage (Eq. 3 / section 4.2): p-bit ADC over the output window.

    ``scale=None`` calibrates the window to max|y| (stop-gradient) — the
    section-3.1 weight-scaling calibration that fills [T, 2T] before the
    shared-counter ADC samples it.  Pass an explicit ``scale`` for a fixed
    window (e.g. 0.5 for the raw differential range of a normalized tile).
    Forward is the quantized value, backward identity (STE).
    """
    if scale is None:
        scale = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(jnp.abs(y), initial=0.0), 1e-9))
    levels = float((1 << bits) - 1)
    return signed_codes(y / scale, bits) * (scale / levels)
