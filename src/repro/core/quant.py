"""Unified quantized-code subsystem: the one QuantizedTensor path from
encoding to the TD-VMM kernel.

The paper's multiplier is an *integer-code* machine: p-bit time codes in,
current codes as weights, charge accumulation, p-bit readout.  Every
quantization boundary in the repo routes through this module so that the jnp
reference path, the Pallas kernel, and the event-driven simulator all agree on
what the digital words are.

Stage -> paper mapping (arXiv:1711.10673):

    encode_input      Eq. 2 / section 4.2 — the shared-counter DAC converts a
                      normalized activation into a p-bit rising-edge time code
                      on the grid T0 = T / 2^p (sign = differential wire pair).
    program_weights   sections 2, 4.1 — floating-gate tuning programs each
                      cell's current to one of 2^p_w levels; per-output-column
                      scaling is the "appropriate scaling of VMM weights" of
                      section 3.1.
    (integrate)       Eq. 1 — charge accumulation; lives in kernels/tdvmm
                      (Pallas on TPU / interpret elsewhere) or jnp.dot.
    readout           Eq. 3 / section 4.2 — the comparator-latch + shared
                      counter reads the crossing time back out as a p-bit code
                      over a calibrated output window.

Code storage: codes with |code| <= 127 (p <= 7, including the default p = 6)
are stored as **int8** — the canonical digital word of the paper's machine.
int8 codes stream from HBM at a quarter of the f32 bytes and take the MXU's
int8 x int8 -> int32 path, where charge accumulation is *exact* for any K
with |acc| < 2^31 (no 2^24 f32 envelope).  p = 8 codes (|code| <= 255) and
noise-perturbed analog currents don't fit int8 and fall back to
integer-valued float32 storage (exact while |acc| < 2^24 — e.g. 6-bit codes
up to K = 4096).

QAT still works on int8 storage: ``QuantizedTensor.view()`` returns the f32
straight-through-estimator view (forward = the stored codes, backward =
identity via the retained linear term), which is what ``dequantize`` and the
kernel's gradient path consume.  Every quantizer is STE-wrapped, so models
stay trainable (standard QAT) no matter which backend integrates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import encoding as enc

# Signed-magnitude codes span [-(2^p - 1), 2^p - 1]: int8 holds p <= 7.
INT8_MAX_BITS = 7
# A signed nibble holds [-8, 7] ⊇ [-7, 7]: p <= 3 packs two codes per byte.
INT4_MAX_BITS = 3


def storage_dtype(bits: int):
    """Canonical code storage: int8 when the signed code range fits."""
    return jnp.int8 if bits <= INT8_MAX_BITS else jnp.float32


def pack_int4(codes: jax.Array, axis: int) -> jax.Array:
    """Pack int8 codes with |code| <= 7 (p <= 3) two-per-byte along ``axis``.

    Byte ``kp`` holds code ``2*kp`` in the low nibble and ``2*kp + 1`` in the
    high nibble.  An odd-length axis is zero-padded to even first — a zero
    code is an inert (never-on) current source, so the pad contributes no
    charge and the unpacked tail column multiplies to exactly zero.  The
    result is an int8 array of half the (even-padded) extent: the HBM word
    the Pallas kernel streams and unpacks in-VMEM (``tdvmm._unpack_nibbles``).
    """
    axis = axis % codes.ndim
    k = codes.shape[axis]
    if k % 2:
        pad = [(0, 0)] * codes.ndim
        pad[axis] = (0, 1)
        codes = jnp.pad(codes, pad)
    codes = codes.astype(jnp.int8)
    idx_lo = [slice(None)] * codes.ndim
    idx_hi = [slice(None)] * codes.ndim
    idx_lo[axis] = slice(0, None, 2)
    idx_hi[axis] = slice(1, None, 2)
    lo = codes[tuple(idx_lo)]
    hi = codes[tuple(idx_hi)]
    return (lo & jnp.int8(0x0F)) | (hi << 4).astype(jnp.int8)


def unpack_int4(packed: jax.Array, k: int, axis: int) -> jax.Array:
    """Inverse of ``pack_int4``: int8 nibble pairs -> ``k`` int8 codes.

    Arithmetic shifts sign-extend the nibbles ((v << 4) >> 4 for the low,
    v >> 4 for the high), then the even/odd columns interleave back along
    ``axis``; a pad column from an odd ``k`` is dropped.
    """
    axis = axis % packed.ndim
    packed = packed.astype(jnp.int8)
    lo = ((packed << 4).astype(jnp.int8) >> 4).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=axis + 1)
    shape = list(packed.shape)
    shape[axis] = 2 * packed.shape[axis]
    out = out.reshape(shape)
    idx = [slice(None)] * out.ndim
    idx[axis] = slice(0, k)
    return out[tuple(idx)]


def ste(x_quant: jax.Array, x: jax.Array) -> jax.Array:
    """Straight-through estimator: forward ``x_quant``, backward identity."""
    return x + jax.lax.stop_gradient(x_quant - x)


def signed_codes(x: jax.Array, bits: int) -> jax.Array:
    """Value in [-1, 1] -> integer-valued signed code in [-L, L], L = 2^p - 1.

    The sign folds the differential (+/-) wire pair of the four-quadrant
    multiplier.  STE in the code domain: forward is the rounded code, backward
    is d(code)/d(x) = L, so dequantizing (code * scale / L) has identity
    gradient in the value domain — exactly the seed fake-quant STE.
    """
    levels = float((1 << bits) - 1)
    q = enc.quantize_code_signed(x, bits).astype(jnp.float32)
    return ste(q, x * levels)


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Integer codes + the scale that maps them back to model units.

    codes:  int8 in [-levels, levels] when p <= 7 (the canonical storage —
            quarter of the f32 HBM bytes, feeds the kernel's exact int32
            accumulation path), else f32.  f32 codes are STE-wrapped and
            directly differentiable in the QAT sense; they may also be
            non-integer (programming noise models analog current
            perturbation) and are still valid kernel input.
    scale:  f32, broadcastable against the dequantized value — per-row
            ``(..., 1)`` for activations, per-channel ``(1, N)`` or per-tensor
            ``(1, 1)`` for weights.  Always stop-gradient.
    bits:   static code width p.
    ste:    optional f32 linear term (the unrounded ``x * levels``) retained
            for QAT alongside int8 storage; ``view()`` splices it into a
            straight-through estimator.  None on serving paths (and dead
            code the compiler drops whenever gradients aren't taken).
    """

    codes: jax.Array
    scale: jax.Array
    bits: int
    ste: Optional[jax.Array] = None

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1

    def view(self) -> jax.Array:
        """f32 STE view of the codes: forward = stored codes, backward =
        identity (through ``ste`` when present).  This is what the compute
        and gradient paths consume; ``codes`` itself is the storage word."""
        if jnp.issubdtype(self.codes.dtype, jnp.floating):
            return self.codes          # f32 codes already carry their STE
        qf = self.codes.astype(jnp.float32)
        if self.ste is None:
            return qf
        # qf + (ste - sg(ste)), not ste + sg(qf - ste): the correction term
        # is exactly +0.0 in IEEE arithmetic, so the forward value is the
        # *integer* code — float summation over integer products is then
        # order-independent, which is what keeps ragged/blocked launches
        # bit-for-bit with their sequential counterparts even under QAT.
        # The old form rounds twice and lands an ulp off the code grid.
        return qf + (self.ste - jax.lax.stop_gradient(self.ste))

    def dequantize(self) -> jax.Array:
        """Back to model units: codes / L * scale."""
        return self.view() * (self.scale / float(self.levels))


jax.tree_util.register_dataclass(
    QuantizedTensor, data_fields=["codes", "scale", "ste"],
    meta_fields=["bits"])


def _store(normalized: jax.Array, bits: int) -> tuple[jax.Array, Optional[jax.Array]]:
    """(codes, ste) for a normalized value in [-1, 1]: int8 storage + retained
    f32 linear term when the code range fits int8, else STE-wrapped f32
    (``signed_codes`` — the single source of the STE convention)."""
    if storage_dtype(bits) == jnp.int8:
        lin = normalized * float((1 << bits) - 1)
        return enc.quantize_code_signed(normalized, bits).astype(jnp.int8), lin
    return signed_codes(normalized, bits), None


def encode_input(x: jax.Array, bits: int, axis: int = -1) -> QuantizedTensor:
    """Input stage (Eq. 2): per-row range normalization + p-bit time codes.

    The scale is the per-example input range max|x| along ``axis`` (the analog
    front-end normalizes each sample into the [0, T] window); it is
    stop-gradient, matching the seed layer.
    """
    xf = x.astype(jnp.float32)
    # initial=0.0 is an identity for |x| maxes and keeps zero-size batches
    # (e.g. a serving batch filtered to nothing) from hitting the no-identity
    # reduction error; the 1e-6 clamp then supplies the scale.
    s = jax.lax.stop_gradient(jnp.maximum(
        jnp.max(jnp.abs(xf), axis=axis, keepdims=True, initial=0.0), 1e-6))
    codes, lin = _store(xf / s, bits)
    return QuantizedTensor(codes=codes, scale=s, bits=bits, ste=lin)


def program_weights(
    w: jax.Array, bits: int, per_channel: bool = True
) -> QuantizedTensor:
    """Weight stage (sections 2, 4.1): FG current codes + column scaling.

    ``per_channel`` scales each output column independently (the N_in axis of
    a (N_in, N_out) matrix — axis -2, so stacked (E, N_in, N_out) expert
    banks get per-expert-per-column scales); otherwise one scale per weight
    tile (per expert for stacked banks).
    """
    wf = w.astype(jnp.float32)
    axes = (-2,) if per_channel else (-2, -1)
    w_max = jax.lax.stop_gradient(jnp.maximum(
        jnp.max(jnp.abs(wf), axis=axes, keepdims=True, initial=0.0), 1e-6))
    # No explicit clip: the stored code already clips to the code range, and
    # the STE linear term must stay unclipped — a clip here would halve
    # the gradient of every per-channel max-magnitude weight (the clip
    # boundary is a min/max tie at exactly |w| == w_max).
    codes, lin = _store(wf / w_max, bits)
    return QuantizedTensor(codes=codes, scale=w_max, bits=bits, ste=lin)


def stack_group(qws: "list[QuantizedTensor] | tuple[QuantizedTensor, ...]",
                n_to: int) -> QuantizedTensor:
    """Stack G programmed (K, N_g) weight members into one (G, K, n_to) bank.

    The grouped TD-VMM launch (``core.layers.td_grouped_matmul``) runs one
    shared input against G same-input projection matrices; uneven output
    widths are zero-padded up to ``n_to`` (the group's block-rounded max-N).
    Zero codes are inert — a never-on current source — so padded columns
    integrate zero charge and their sliced-off outputs are exactly zero.
    Padded scale entries are 1.0 (never multiplied against a nonzero code).

    Members must share the code width; per-channel ``(1, N_g)`` and
    per-tensor ``(1, 1)`` scales both stack to a ``(G, 1, n_to)`` scale.  STE
    linear terms stack alongside the codes (zero-padded — identity gradient
    through a zero pad is still zero).
    """
    if not qws:
        raise ValueError("stack_group needs at least one member")
    bits = qws[0].bits
    if any(q.bits != bits for q in qws):
        raise ValueError(
            f"grouped members must share a code width, got "
            f"{[q.bits for q in qws]}")
    if any(q.codes.ndim != 2 for q in qws):
        raise ValueError("stack_group stacks 2-D (K, N) weight members")
    if any(q.codes.shape[-1] > n_to for q in qws):
        raise ValueError(
            f"n_to={n_to} smaller than a member width "
            f"{[q.codes.shape[-1] for q in qws]}")

    def pad_codes(c):
        return jnp.pad(c, ((0, 0), (0, n_to - c.shape[-1])))

    codes = jnp.stack([pad_codes(q.codes) for q in qws])
    scale = jnp.stack([jnp.pad(
        jnp.broadcast_to(q.scale, (1, q.codes.shape[-1])),
        ((0, 0), (0, n_to - q.codes.shape[-1])), constant_values=1.0)
        for q in qws])
    stes = None
    if all(q.ste is not None for q in qws):
        stes = jnp.stack([pad_codes(q.ste) for q in qws])
    return QuantizedTensor(codes=codes, scale=scale, bits=bits, ste=stes)


def concat_group(qws: "list[QuantizedTensor] | tuple[QuantizedTensor, ...]",
                 widths: "tuple[int, ...]") -> QuantizedTensor:
    """Concatenate G programmed (K, N_g) members along N into one ragged bank.

    The ragged grouped TD-VMM launch (``core.layers.td_grouped_matmul``) runs
    one shared input against the column concat of G same-input projections —
    a single 2-D (K, sum widths) launch in which member g owns the
    ``widths[g]``-wide column span.  Each member zero-pads only up to its own
    ``widths[g]`` (its lane-rounded width), NOT to the widest member — that
    per-member rounding is the whole point versus ``stack_group``'s
    (G, K, max-N) batched bank under uneven widths (heavy GQA).  Zero codes
    are inert, so pad columns integrate zero charge; padded scale entries are
    1.0 (never multiplied against a nonzero code).  STE linear terms concat
    alongside the codes.
    """
    if not qws:
        raise ValueError("concat_group needs at least one member")
    if len(widths) != len(qws):
        raise ValueError(f"{len(widths)} widths for {len(qws)} members")
    bits = qws[0].bits
    if any(q.bits != bits for q in qws):
        raise ValueError(
            f"grouped members must share a code width, got "
            f"{[q.bits for q in qws]}")
    if any(q.codes.ndim != 2 for q in qws):
        raise ValueError("concat_group concatenates 2-D (K, N) members")
    if any(q.codes.shape[-1] > wd for q, wd in zip(qws, widths)):
        raise ValueError(
            f"member widths {[q.codes.shape[-1] for q in qws]} exceed the "
            f"declared spans {tuple(widths)}")

    def pad_codes(c, wd):
        return jnp.pad(c, ((0, 0), (0, wd - c.shape[-1])))

    codes = jnp.concatenate(
        [pad_codes(q.codes, wd) for q, wd in zip(qws, widths)], axis=-1)
    scale = jnp.concatenate(
        [jnp.pad(jnp.broadcast_to(q.scale, (1, q.codes.shape[-1])),
                 ((0, 0), (0, wd - q.codes.shape[-1])), constant_values=1.0)
         for q, wd in zip(qws, widths)], axis=-1)
    stes = None
    if all(q.ste is not None for q in qws):
        stes = jnp.concatenate(
            [pad_codes(q.ste, wd) for q, wd in zip(qws, widths)], axis=-1)
    return QuantizedTensor(codes=codes, scale=scale, bits=bits, ste=stes)


def program_noise(qw: QuantizedTensor, spec, key: jax.Array) -> QuantizedTensor:
    """Stochastic DIBL + FG tuning noise on programmed current codes.

    Multiplicative, so it is identical in the code and value domains; the
    perturbed codes are intentionally non-integer (analog currents), so the
    result always carries f32 codes — int8 storage (and the kernel's int
    path) is for noise-free digital words only.
    """
    from repro.core import nonideal

    err = nonideal.relative_error(
        spec.i_max, jnp.asarray(spec.v_sg), jnp.asarray(spec.delta_vd))
    k1, k2 = jax.random.split(key)
    view = qw.view()
    # Explicit f32 draws: the code pipeline is f32 end-to-end, independent of
    # the process-wide jax_enable_x64 flag (which would silently promote the
    # perturbed codes to f64).
    u = jax.random.uniform(
        k1, view.shape, jnp.float32, minval=-1.0, maxval=1.0)
    codes = view * (1.0 + err.astype(jnp.float32) * u)
    codes = codes * jnp.exp(
        0.003 * jax.random.normal(k2, view.shape, jnp.float32))
    return QuantizedTensor(codes=codes, scale=qw.scale, bits=qw.bits)


def readout(
    y: jax.Array, bits: int, scale: jax.Array | float | None = None
) -> jax.Array:
    """Readout stage (Eq. 3 / section 4.2): p-bit ADC over the output window.

    ``scale=None`` calibrates the window to max|y| (stop-gradient) — the
    section-3.1 weight-scaling calibration that fills [T, 2T] before the
    shared-counter ADC samples it.  Pass an explicit ``scale`` for a fixed
    window (e.g. 0.5 for the raw differential range of a normalized tile).
    Forward is the quantized value, backward identity (STE).
    """
    if scale is None:
        scale = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(jnp.abs(y), initial=0.0), 1e-9))
    levels = float((1 << bits) - 1)
    return signed_codes(y / scale, bits) * (scale / levels)
