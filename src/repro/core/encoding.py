"""Time-domain encoding of values (paper Eq. 2-3 and the pulse-duration variant).

Conventions
-----------
Normalized values live in [0, 1].  A value ``x`` is encoded as the turn-on time

    t_on = T * (1 - x)            (rising-edge encoding, Eq. 2)

inside the input window [0, T]: the largest value turns on at t=0, the smallest
(zero) never contributes charge (turn-on at t=T, and V stays ON during [T, 2T]
so every source contributes for the full readout phase regardless).

The dot-product output is the latch crossing time ``T + t_sigma`` in [T, 2T]
(Eq. 3), decoded as  y = (T - t_sigma) / T.

Section 3.1's pulse-duration encoding (used between chained VMMs, where the
ReLU AND-gate emits a pulse of duration d) is equivalent: charge contributed is
I * d, so  x = d / T.  Both encodings are provided.

Quantization: a p-bit digital I/O converter (shared counter + comparator-latch,
section 4.2) realizes t_on on a grid of 2^p slots of width T/2^p == T0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_code(x: jax.Array, bits: int) -> jax.Array:
    """Normalized value in [0,1] -> integer time code in {0, ..., 2^p - 1}.

    Code k represents the value k / (2^p - 1); this is the digital word the
    shared-counter DAC compares against.
    """
    levels = (1 << bits) - 1
    x = jnp.clip(x, 0.0, 1.0)
    return jnp.round(x * levels).astype(jnp.int32)


def dequantize_code(code: jax.Array, bits: int) -> jax.Array:
    levels = (1 << bits) - 1
    return code.astype(jnp.float32) / levels


def fake_quant(x: jax.Array, bits: int) -> jax.Array:
    """Round-trip through the p-bit time grid (value domain)."""
    return dequantize_code(quantize_code(x, bits), bits)


def quantize_code_signed(x: jax.Array, bits: int) -> jax.Array:
    """Signed value in [-1, 1] -> signed integer code in {-L, ..., L}.

    The sign carries the differential (+,-) wire pair of the four-quadrant
    multiplier (section 2); |code| is the unsigned p-bit time code.  Equal to
    round(clip(x, -1, 1) * L) since round-half-even is symmetric.
    """
    return jnp.sign(x).astype(jnp.int32) * quantize_code(jnp.abs(x), bits)


def value_to_onset(x: jax.Array, t_window: float) -> jax.Array:
    """x in [0,1] -> rising-edge time t_on in [0, T]  (Eq. 2: T - t_i ~ x_i)."""
    return t_window * (1.0 - jnp.clip(x, 0.0, 1.0))


def onset_to_value(t_on: jax.Array, t_window: float) -> jax.Array:
    return 1.0 - t_on / t_window


def crossing_to_value(t_cross: jax.Array, t_window: float) -> jax.Array:
    """Latch crossing time (absolute, in [T, 2T]) -> output value (Eq. 3)."""
    t_sigma = t_cross - t_window
    return 1.0 - t_sigma / t_window


def value_to_duration(x: jax.Array, t_window: float) -> jax.Array:
    """Pulse-duration encoding (section 3.1): x in [0,1] -> pulse length in [0,T]."""
    return t_window * jnp.clip(x, 0.0, 1.0)


def duration_to_value(d: jax.Array, t_window: float) -> jax.Array:
    return d / t_window


def four_quadrant_split(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Signed value -> differential (positive-wire, negative-wire) pair.

    x = x_plus - x_minus with both components in [0, |x|].  The circuit drives
    both wires; here we use the canonical rectified split.
    """
    return jnp.maximum(x, 0.0), jnp.maximum(-x, 0.0)


def four_quadrant_merge(x_plus: jax.Array, x_minus: jax.Array) -> jax.Array:
    return x_plus - x_minus
