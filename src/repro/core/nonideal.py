"""Non-ideality models (paper section 4.1, Fig. 4).

The dominant precision limiter is DIBL: the subthreshold drain current of the
FG cell depends on the drain-line voltage, which swings by Delta_V_D during
integration.  The paper quantifies it as

    Error = |I(V_RESET) - I(V_RESET - Delta_V_D)| / I(V_RESET)

measured over (I_max, V_SG, V_D).  We reproduce the *measured trends* of
Fig. 4 with a behavioral subthreshold model; constants marked [fitted] are
calibrated to the paper's reported anchor points:

  * distinct optimum at V_SG ~ 0.8 V (shorter effective channel at higher
    V_SG -> more DIBL; source-side voltage-divider at lower V_SG),
  * error decreasing with I_max up to ~1 uA, bounded above by the exit from
    the subthreshold regime,
  * Error < 2% at the optimum  =>  >= 5-6 bit computing precision.

Everything else (V_TH latch mismatch, weight-tuning noise, retention drift,
capacitive coupling) is modeled as in section 4.1, including which of them are
*compensable* by re-tuning the FG currents.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.constants import (
    DELTA_VD,
    DIBL_ERROR_AT_OPT,
    I_MAX_OPT,
    TDVMMSpec,
    V_RESET,
    V_SG_OPT,
    V_T_THERMAL,
    VTH_MISMATCH_RMS,
)


@dataclasses.dataclass(frozen=True)
class NonIdealityConfig:
    dibl: bool = True
    weight_noise: bool = True
    latch_mismatch: bool = False     # compensable (section 4.1) -> off by default
    sigma_tune: float = 0.003        # relative FG tuning accuracy (ref [15], ~8 bit)
    compensate_systematic: bool = True  # re-tuning removes input-independent error
    seed_salt: int = 0


# --- DIBL behavioral model ---------------------------------------------------
# [fitted] constants calibrated to Fig. 4 anchors (see module docstring).
_LAMBDA_OPT = 0.105      # DIBL coefficient at (I_max=1uA, V_SG=0.8) [1/V]
_VSG_CURVATURE = 25.0    # (1 + c*(V_SG-0.8)^2): ~2x error 0.2 V away from optimum
_I_EXPONENT = 0.36       # error ~ (I_ref/I)^beta below the optimum
_I_SUB_EDGE = 3.0e-6     # upper edge of subthreshold conduction [A]
_EDGE_SHARPNESS = 4.0


def dibl_lambda(i_max: jax.Array, v_sg: jax.Array) -> jax.Array:
    """Effective DIBL coefficient lambda(I, V_SG) [1/V]."""
    vsg_term = 1.0 + _VSG_CURVATURE * (v_sg - V_SG_OPT) ** 2
    i_term = (I_MAX_OPT / jnp.maximum(i_max, 1e-12)) ** _I_EXPONENT
    # leaving subthreshold: sensitivity blows up as I approaches the edge
    edge = 1.0 + (jnp.maximum(i_max, 1e-12) / _I_SUB_EDGE) ** _EDGE_SHARPNESS
    return _LAMBDA_OPT * vsg_term * i_term * edge


def drain_current(i_prog: jax.Array, v_d: jax.Array, lam: jax.Array) -> jax.Array:
    """Subthreshold drain current vs drain voltage:
    I(V_D) = I_prog * (1 - exp(-V_D / V_T)) * (1 + lambda*V_D), normalized so
    that I(V_RESET) = I_prog."""
    shape = (1.0 - jnp.exp(-v_d / V_T_THERMAL)) * (1.0 + lam * v_d)
    norm = (1.0 - jnp.exp(-V_RESET / V_T_THERMAL)) * (1.0 + lam * V_RESET)
    return i_prog * shape / norm


def relative_error(i_max: jax.Array, v_sg: jax.Array, delta_vd: jax.Array) -> jax.Array:
    """The paper's Error metric (Fig. 4):
    |I(V_RESET) - I(V_RESET - dV)| / I(V_RESET)."""
    lam = dibl_lambda(i_max, v_sg)
    i_hi = drain_current(i_max, jnp.asarray(V_RESET), lam)
    i_lo = drain_current(i_max, jnp.asarray(V_RESET) - delta_vd, lam)
    return jnp.abs(i_hi - i_lo) / jnp.maximum(i_hi, 1e-30)


def effective_bits(err: jax.Array) -> jax.Array:
    """Precision: number of distinguishable levels, log2(1/err), floored.

    Matches the paper's convention: Error < 2%  =>  'at least 5 bits'
    (log2(1/0.02) = 5.6).
    """
    return jnp.floor(-jnp.log2(jnp.maximum(err, 1e-12)))


# --- Applying non-idealities to programmed currents --------------------------
def perturb_currents(
    i_mat: jax.Array,
    key: jax.Array,
    spec: TDVMMSpec,
    cfg: NonIdealityConfig,
) -> jax.Array:
    """Return the *effective* currents seen during integration.

    DIBL: during integration the drain voltage slews from V_RESET down to the
    latch threshold, so the time-averaged current deviates from the programmed
    one by up to Error (input-dependent through the crossing time — the one
    error the paper says cannot be compensated).  We model it as a
    multiplicative perturbation uniform in [-Error, +Error] per source, plus a
    compensable systematic part that re-tuning removes when
    ``compensate_systematic`` is set.

    Weight noise: lognormal relative tuning error of ref [15].
    """
    eff = i_mat
    if cfg.dibl:
        err = relative_error(spec.i_max, jnp.asarray(spec.v_sg), jnp.asarray(spec.delta_vd))
        k1, key = jax.random.split(key)
        u = jax.random.uniform(k1, i_mat.shape, minval=-1.0, maxval=1.0)
        if not cfg.compensate_systematic:
            u = u + 0.5  # un-compensated systematic shift toward lower current
        eff = eff * (1.0 + err * u)
    if cfg.weight_noise:
        k2, key = jax.random.split(key)
        eff = eff * jnp.exp(cfg.sigma_tune * jax.random.normal(k2, i_mat.shape))
    return eff


def latch_time_offset(
    key: jax.Array, shape: tuple[int, ...], n_inputs: int, spec: TDVMMSpec
) -> jax.Array:
    """Crossing-time offset from S-R latch V_TH mismatch (20 mV rms).

    delta_t = C * delta_V / I_slope with I_slope ~ N*I_max at the crossing;
    compensable by bias re-tuning (section 4.1), modeled for completeness.
    """
    c_total = spec.c_total_f(n_inputs)
    dv = VTH_MISMATCH_RMS * jax.random.normal(key, shape)
    return c_total * dv / (n_inputs * spec.i_max)
