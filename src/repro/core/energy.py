"""Energy / latency / area cost model (paper section 4.2, Fig. 5).

The paper reports, for a conservative 6-bit digital-input/digital-output
four-quadrant N x N TD-VMM in 55 nm (C ~= 200*C_drain = 0.04 pF/input):

    N = 10   : 5.44 pJ per VMM window  => 38.6 TOps/J   (static ~65%)
    N = 100  : ~120 TOps/J
    N = 1000 : ~150 TOps/J  (dynamic, dominated by the external caps)
    N > 200  : ~7 fJ/Op including the digital<->time I/O conversion circuitry

Counting 2*N^2 Ops per window (N^2 MAC = N^2 mult + N^2 add), the model

    e_op(N, p=6) = alpha + (beta + gamma) / N           [J/Op]
      alpha  : dynamic energy per op (external caps + CG lines + neuron CMOS)
      beta/N : static leakage  (2N neuron blocks * P_leak * window) / (2N^2)
      gamma/N: I/O conversion  (N DAC + N ADC slices per window)    / (2N^2)

fits all four anchors with TWO free parameters:

    beta + gamma = 195.2 fJ,  alpha = 6.38 fJ
      -> e(10) = 25.9 fJ/Op (= 38.6 TOps/J, matches 5.44 pJ/window)
      -> e(100) = 8.33 fJ/Op (= 120 TOps/J)
      -> e(1000) = 6.58 fJ/Op (= 152 TOps/J vs ~150 reported)
      -> e(200) = 7.36 fJ/Op (~7 fJ/Op, matches the N > 200 claim)

beta is split from gamma via the "static ~= 65% at N=10" anchor:
    beta = 0.65 * e(10) * 10 = 168.3 fJ   =>   gamma = 26.9 fJ.

Precision scaling: static and counter-based I/O energies scale with the
window length 2T = 2*T0*2^p; the dynamic (charge) component does not.

Latency (section 4.2): 2T0 <= 1 ns per bit  =>  2T = 2T0 * 2^p  (~64-100 ns at
p=6); pipelined period 2T + tau_reset.

Area (Fig. 5b): external caps ~75% / memory array ~25% for N > 200; at N=10
one neuron block is ~1.5x the area of the whole 10x20 supercell array (Fig. 3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.constants import (
    A_SUPERCELL_UM2,
    DEFAULT_BITS,
    E_TOTAL_N10_J,
    STATIC_FRACTION_N10,
    T0_S,
    TAU_RESET_S,
    TOPS_PER_J_N10,
    TOPS_PER_J_N100,
    TOPS_PER_J_N1000,
    TDVMMSpec,
)

# --- fitted model constants (derivation in module docstring) -----------------
_E10 = 1.0 / (TOPS_PER_J_N10 * 1e12)          # 25.91 fJ/Op
_E100 = 1.0 / (TOPS_PER_J_N100 * 1e12)        # 8.33 fJ/Op
BETA_PLUS_GAMMA_J = (_E10 - _E100) / (1.0 / 10 - 1.0 / 100)   # 195.2 fJ
ALPHA_J = _E100 - BETA_PLUS_GAMMA_J / 100.0                   # 6.38 fJ
BETA_J = STATIC_FRACTION_N10 * _E10 * 10.0                    # 168.3 fJ (static)
GAMMA_J = BETA_PLUS_GAMMA_J - BETA_J                          # 26.9 fJ (I/O)
# alpha split: at N=1000 the paper says dynamic is dominated by the external
# caps; we attribute 85% of alpha to caps, the rest to CG lines + neuron CMOS.
ALPHA_CAP_FRACTION = 0.85

# --- area model constants ----------------------------------------------------
# One four-quadrant weight = 4 FG cells = 2 ESF3 supercells.
A_WEIGHT_UM2 = 2.0 * A_SUPERCELL_UM2
# [fitted] external-cap area per (input x output) cell such that the cap:memory
# split is 75:25 at large N (Fig. 5b):  a_cap = 3 * a_weight.
A_CAP_UM2 = 3.0 * A_WEIGHT_UM2
# [Fig. 3 / section 4.2] neuron block ~1.5x the 10x20 supercell array area.
A_NEURON_UM2 = 1.5 * 200.0 * A_SUPERCELL_UM2
# I/O converter slice (counter share + comparator latch + register), per line.
A_IO_UM2 = 60.0


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    n: int
    bits: int
    e_total_j: float
    e_dynamic_j: float
    e_static_j: float
    e_io_j: float
    e_per_op_j: float
    tops_per_j: float
    latency_s: float
    period_s: float
    throughput_ops: float
    area_um2: float
    area_mem_um2: float
    area_cap_um2: float
    area_neuron_um2: float
    area_io_um2: float


def ops_per_window(n: int) -> float:
    """2*N^2: the paper counts multiply and add separately."""
    return 2.0 * n * n


def _p_scale(bits: int) -> float:
    """Window-length scale factor vs the p=6 reference."""
    return 2.0 ** (bits - DEFAULT_BITS)


def energy_per_window(n: int, bits: int = DEFAULT_BITS) -> dict[str, float]:
    ops = ops_per_window(n)
    s = _p_scale(bits)
    e_dyn = ALPHA_J * ops                    # charge/discharge: per-op, p-independent
    e_static = BETA_J * 2.0 * n * s         # leakage * window, per 2N output lines
    e_io = GAMMA_J * 2.0 * n * s            # counter-based converters, ~2N slices
    return {
        "dynamic": e_dyn,
        "static": e_static,
        "io": e_io,
        "total": e_dyn + e_static + e_io,
    }


def cost(n: int, bits: int = DEFAULT_BITS, spec: TDVMMSpec | None = None) -> CostBreakdown:
    spec = spec or TDVMMSpec(bits=bits)
    e = energy_per_window(n, bits)
    ops = ops_per_window(n)
    t_window = T0_S * (2 ** bits)
    period = 2.0 * t_window + TAU_RESET_S
    a_mem = n * n * A_WEIGHT_UM2
    a_cap = n * n * A_CAP_UM2
    a_neuron = 2.0 * n * A_NEURON_UM2 / 20.0  # per differential line pair, scaled
    a_io = 2.0 * n * A_IO_UM2
    return CostBreakdown(
        n=n,
        bits=bits,
        e_total_j=e["total"],
        e_dynamic_j=e["dynamic"],
        e_static_j=e["static"],
        e_io_j=e["io"],
        e_per_op_j=e["total"] / ops,
        tops_per_j=1e-12 * ops / e["total"],
        latency_s=2.0 * t_window,
        period_s=period,
        throughput_ops=ops / period,
        area_um2=a_mem + a_cap + a_neuron + a_io,
        area_mem_um2=a_mem,
        area_cap_um2=a_cap,
        area_neuron_um2=a_neuron,
        area_io_um2=a_io,
    )


def validate_against_paper() -> dict[str, tuple[float, float]]:
    """(model, paper) pairs for every anchor number in section 4.2 / Fig. 5."""
    c10, c100, c1000, c200 = cost(10), cost(100), cost(1000), cost(200)
    return {
        "E_total_N10_pJ": (c10.e_total_j * 1e12, E_TOTAL_N10_J * 1e12),
        "TOpsJ_N10": (c10.tops_per_j, TOPS_PER_J_N10),
        "TOpsJ_N100": (c100.tops_per_j, TOPS_PER_J_N100),
        "TOpsJ_N1000": (c1000.tops_per_j, TOPS_PER_J_N1000),
        "fJ_per_op_N200": (c200.e_per_op_j * 1e15, 7.0),
        "static_fraction_N10": (c10.e_static_j / c10.e_total_j, STATIC_FRACTION_N10),
        "cap_area_fraction_largeN": (
            c1000.area_cap_um2 / (c1000.area_cap_um2 + c1000.area_mem_um2),
            0.75,
        ),
        "latency_6bit_ns": (c10.latency_s * 1e9, 64.0),  # 2T0*2^p, "~100 ns" class
    }


# --------------------------------------------------------------------------
# Serving-engine energy metering (runtime/engine.py)
# --------------------------------------------------------------------------
def serving_energy_model(cfg, tile_n: int = 256, n_devices: int = 1) -> dict:
    """Per-token analog Op/energy table for a model's **enabled** TD-VMM
    sites — the engine's fJ/Op currency.

    For every enabled site in the resolved plan, maps its per-token weight
    matrices (``configs.plan.site_linear_shapes``) onto ``tile_n x tile_n``
    tiles at the site's code width and prices one VMM window per tile from
    the paper's fitted model (``cost``).  Time-domain chains halve the I/O
    term on both ends of the pair: the upstream tile skips its ADC readout
    and the downstream tile skips its input DAC (Fig. 2 — the intermediate
    p-bit boundary disappears), so a ``chain=True`` plan shows up directly
    as fewer joules per token in ``benchmarks/bench_serving.py``.

    Ops are counted as 2 * d_in * d_out per matrix per token (the paper's
    MAC = mult + add convention); tile energy includes padding waste (a
    partially filled tile burns a full window), so fJ/Op degrades honestly
    when shapes don't divide ``tile_n``.

    ``ops_per_token`` / ``energy_per_token_j`` are AGGREGATE (whole-mesh)
    per-token columns — what a request is charged and what ``token_cost``
    reads — and are device-count independent.  ``n_devices > 1`` additionally
    reports the ``*_per_device`` share of that work: TP splits one token's
    tiles across devices, DP splits the token population, and either way the
    expected per-device rate per engine token is the aggregate over
    ``n_devices``.  ``fj_per_op`` is a ratio, identical at both scopes.
    """
    if n_devices < 1:
        raise ValueError(f"need >= 1 device, got {n_devices}")
    from repro.configs.plan import site_linear_shapes
    resolved = cfg.resolved_tdvmm_plan
    shapes = site_linear_shapes(cfg)
    chained_up = {u for u, _ in resolved.chains}
    chained_down = {d for _, d in resolved.chains}
    per_site: dict[str, dict] = {}
    tot_ops = tot_e = 0.0
    for site, sc in resolved.sites:
        info = shapes.get(site)
        if not sc.enabled or info is None:
            continue
        c = cost(tile_n, sc.bits)
        tiles = 0
        ops = 0.0
        for d_in, d_out in info["matrices"]:
            tiles += int(np.ceil(d_in / tile_n)) * int(np.ceil(d_out / tile_n))
            ops += 2.0 * d_in * d_out
        io_factor = 1.0 - 0.5 * (site in chained_up) \
            - 0.5 * (site in chained_down)
        e_tile = c.e_dynamic_j + c.e_static_j + io_factor * c.e_io_j
        layers = info["per_token"]
        site_ops = ops * layers
        site_e = tiles * e_tile * layers
        per_site[site] = {
            "ops_per_token": site_ops,
            "energy_per_token_j": site_e,
            "tiles_per_token": tiles * layers,
            "bits": sc.bits,
            "io_factor": io_factor,
            # I/O conversion energy the chain removed at this site (the
            # skipped ADC readout or DAC re-encode), made explicit so
            # per-site attribution can show where the chained joules went.
            "io_saved_per_token_j":
                (1.0 - io_factor) * c.e_io_j * tiles * layers,
        }
        tot_ops += site_ops
        tot_e += site_e
    return {
        "tile_n": tile_n,
        "n_devices": n_devices,
        "ops_per_token": tot_ops,
        "energy_per_token_j": tot_e,
        "ops_per_token_per_device": tot_ops / n_devices,
        "energy_per_token_j_per_device": tot_e / n_devices,
        "fj_per_op": (tot_e / tot_ops * 1e15) if tot_ops else 0.0,
        "per_site": per_site,
        "chains": [list(pair) for pair in resolved.chains],
    }


def token_cost(energy: dict, n_tokens: int = 1) -> tuple[float, float]:
    """Incremental (ops, joules) for ``n_tokens`` more tokens through the
    enabled sites — the per-token pricing quantum the engine accumulates
    into ``RequestRecord.analog_*`` and the SLA layer charges against
    ``joule_budget``.  ``energy`` is a ``serving_energy_model`` table."""
    return (energy["ops_per_token"] * n_tokens,
            energy["energy_per_token_j"] * n_tokens)


def site_attribution(energy: dict, tokens: int) -> dict:
    """Break ``tokens`` priced tokens down **by plan site** from a
    ``serving_energy_model`` table — the ``EngineReport.site_attribution``
    payload.

    The engine accumulates one exact integer — ``tokens_priced``, the
    number of tokens that went through ``token_cost`` — and this function
    expands it into the per-site table.  The aggregate row is the plain
    left-to-right float sum over ``per_site`` in table (resolved-plan)
    order, so summing the site table reproduces the aggregate
    **bit-exactly**: ``sum(per_site[*]["energy_j"])`` equals
    ``energy_j`` with zero float slack, and the same for ``ops`` (which
    are exact integers in f64 anyway: 2 * d_in * d_out * layers * tokens).
    ``io_saved_j`` makes the time-domain chain's removed I/O conversions
    explicit per chained site (0 everywhere on an unchained plan).
    """
    if tokens < 0:
        raise ValueError(f"tokens must be >= 0, got {tokens}")
    per_site: dict[str, dict] = {}
    tot_ops = tot_e = tot_io = 0.0
    for site, row in energy["per_site"].items():
        ops = row["ops_per_token"] * tokens
        e_j = row["energy_per_token_j"] * tokens
        io_saved = row.get("io_saved_per_token_j", 0.0) * tokens
        per_site[site] = {
            "ops": ops,
            "energy_j": e_j,
            "fj_per_op": (e_j / ops * 1e15) if ops else 0.0,
            "tiles": row["tiles_per_token"] * tokens,
            "bits": row["bits"],
            "io_factor": row["io_factor"],
            "io_saved_j": io_saved,
        }
        tot_ops += ops
        tot_e += e_j
        tot_io += io_saved
    return {
        "tokens": int(tokens),
        "ops": tot_ops,
        "energy_j": tot_e,
        "fj_per_op": (tot_e / tot_ops * 1e15) if tot_ops else 0.0,
        "io_saved_j": tot_io,
        "chains": [list(pair) for pair in energy.get("chains", [])],
        "per_site": per_site,
    }


def request_energy_bounds(energy: dict, prompt_len: int,
                          max_new_tokens: int) -> dict[str, float]:
    """Analog energy/Op bounds for one request under a
    ``serving_energy_model`` table.

    min_*:  the cheapest possible *served* outcome — the prompt prefilled
            plus a single generated token (a request cannot stream fewer
            than one token, so admission rejects any ``joule_budget`` below
            ``min_energy_j``: it could never deliver anything in budget).
    full_*: the full token budget (prompt + max_new_tokens), the worst case
            the deadline/energy planner prices against.
    """
    if prompt_len < 1 or max_new_tokens < 1:
        raise ValueError(f"need prompt_len/max_new_tokens >= 1, got "
                         f"{prompt_len}/{max_new_tokens}")
    min_tokens = prompt_len + 1
    full_tokens = prompt_len + max_new_tokens
    min_ops, min_e = token_cost(energy, min_tokens)
    full_ops, full_e = token_cost(energy, full_tokens)
    return {
        "min_tokens": float(min_tokens),
        "full_tokens": float(full_tokens),
        "min_ops": min_ops,
        "full_ops": full_ops,
        "min_energy_j": min_e,
        "full_energy_j": full_e,
    }


# --------------------------------------------------------------------------
# Mapping full LM architectures onto TD-VMM tiles (section 4.2's TDM reuse)
# --------------------------------------------------------------------------
def llm_mapping_cost(
    linear_shapes: list[tuple[int, int]],
    tile_n: int = 1024,
    bits: int = DEFAULT_BITS,
) -> dict[str, float]:
    """Cost of running all of a model's linear layers on tile_n x tile_n TD-VMM
    tiles with time-division multiplexing (weights stationary, section 4.2).

    linear_shapes: (d_in, d_out) of every weight matrix applied per token.
    Returns energy/token, TOps/J, tile count, and per-token latency assuming
    all tiles of one layer run in parallel and layers are pipelined.
    """
    c = cost(tile_n, bits)
    total_tiles = 0
    e_token = 0.0
    macs = 0.0
    chain_depth = 0
    for d_in, d_out in linear_shapes:
        tin = int(np.ceil(d_in / tile_n))
        tout = int(np.ceil(d_out / tile_n))
        total_tiles += tin * tout
        e_token += tin * tout * c.e_total_j
        macs += d_in * d_out
        chain_depth += tin  # column-tile partial sums chain in time domain
    return {
        "tiles": float(total_tiles),
        "energy_per_token_j": e_token,
        "macs_per_token": macs,
        "tops_per_j": 2.0 * macs / e_token / 1e12,
        "latency_per_token_s": c.period_s,  # pipelined: one period per token
        "area_mm2": total_tiles * c.area_um2 / (tile_n == tile_n) * 1e-6,
    }
