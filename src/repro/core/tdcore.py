"""Event-driven time-domain VMM core (paper sections 2.1-2.2, 3.1).

This module is the *behavioral oracle*: it simulates the physics of the
circuit — charge integration on the output capacitor and the latch threshold
crossing — exactly (piecewise-linear algebra), rather than assuming the
closed-form result.  Property tests assert that this simulation reproduces the
closed form  y = sum_i w_i x_i / (N w_max)  (Eq. 1), which is the paper's
central claim (the Eq. 6-7 current programming makes the crossing time an
exact, weight-scale-free encoding of the normalized dot product).

The closed-form *fast path* used inside large models lives in layers.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import currents as cur
from repro.core import encoding as enc
from repro.core.constants import TDVMMSpec, TAU_RESET_S, TAU_F_S


# --------------------------------------------------------------------------
# Exact threshold-crossing solver
# --------------------------------------------------------------------------
def crossing_time(t_on: jax.Array, i_src: jax.Array, k_charge: jax.Array) -> jax.Array:
    """Exact crossing time of  Q(t) = sum_i I_i * max(t - t_i, 0)  with Q(t*) = K.

    Q is non-decreasing piecewise-linear with breakpoints at the (sorted) turn-on
    times; between breakpoints the slope is the sum of all currents already on.
    We locate the segment containing K by sorting + cumulative sums — the JAX
    equivalent of the event-driven circuit simulation.

    Args:
      t_on:     (M,) turn-on times (absolute, >= 0).
      i_src:    (M,) source currents (>= 0).
      k_charge: scalar charge threshold K = C * V_TH.

    Returns:
      scalar crossing time t* (absolute).
    """
    order = jnp.argsort(t_on)
    ts = t_on[order]
    cs = i_src[order]
    slope = jnp.cumsum(cs)                      # A_k: slope after k-th event
    moment = jnp.cumsum(cs * ts)                # B_k: sum I_j t_j for j <= k
    q_at_break = slope * ts - moment            # charge accumulated at each event
    # last event with Q(t_event) <= K  (q_at_break is non-decreasing)
    idx = jnp.clip(jnp.searchsorted(q_at_break, k_charge, side="right") - 1, 0, ts.shape[0] - 1)
    a = jnp.maximum(slope[idx], 1e-30)
    return (k_charge + moment[idx]) / a


# vectorized: shared input times, per-column currents (N_in, N_out) + bias row
def _column_crossings(
    t_on: jax.Array, i_mat: jax.Array, i_bias: jax.Array, k_charge: jax.Array
) -> jax.Array:
    """Crossing times for every output column of a programmed array.

    t_on: (N_in,) input turn-on times; i_mat: (N_in, N_out); i_bias: (N_out,)
    (bias sources are always on from t=0, Eq. 7).  Returns (N_out,) times.
    """
    t_full = jnp.concatenate([t_on, jnp.zeros((1,), t_on.dtype)])
    i_full = jnp.concatenate([i_mat, i_bias[None, :]], axis=0)   # (N_in+1, N_out)
    return jax.vmap(lambda col: crossing_time(t_full, col, k_charge))(i_full.T)


# --------------------------------------------------------------------------
# Single-quadrant dot product / VMM (section 2.1)
# --------------------------------------------------------------------------
def td_vmm_single_quadrant(
    x: jax.Array, w: jax.Array, spec: TDVMMSpec
) -> jax.Array:
    """Simulate the single-quadrant VMM: x in [0,1]^(N_in), w in [0,w_max]^(N_in,N_out).

    Returns the decoded output  y = (w^T x) / (N_in * w_max)  as recovered from
    the simulated crossing times (Eq. 1-7 all exercised for real).
    """
    n_in = x.shape[0]
    t_window = spec.t_window_s
    i_mat, i_bias = cur.program_matrix(w, spec.i_max, spec.w_max)
    k_charge = spec.v_th_charge(n_in)           # K = N * I_max * T  (Eq. 5)
    t_on = enc.value_to_onset(x, t_window)
    t_cross = _column_crossings(t_on, i_mat, i_bias, k_charge)
    return enc.crossing_to_value(t_cross, t_window)


def ideal_single_quadrant(x: jax.Array, w: jax.Array, w_max: float) -> jax.Array:
    """Closed-form Eq. 1 for the single-quadrant VMM."""
    return (x @ w) / (x.shape[0] * w_max)


# --------------------------------------------------------------------------
# Four-quadrant VMM (section 2.2) and two-quadrant variant (section 3.1)
# --------------------------------------------------------------------------
def td_vmm_four_quadrant(
    x: jax.Array, w: jax.Array, spec: TDVMMSpec, return_times: bool = False
):
    """Simulate the differential four-quadrant VMM.

    x: (N_in,) signed, |x| <= 1.   w: (N_in, N_out) signed, |w| <= w_max.

    Each output wire of the +/- pair integrates 2*N_in current sources
    (W+ stacked over W- per section 2.2), so the decoded differential output is

        y = (w^T x) / (2 * N_in * w_max).

    Returns y (N_out,), and optionally the raw (t_plus, t_minus) crossing times
    (used for chaining / the ReLU AND-gate).
    """
    n_in = x.shape[0]
    t_window = spec.t_window_s
    x_p, x_m = enc.four_quadrant_split(x)
    prog = cur.four_quadrant_program(w, spec.i_max, spec.w_max)
    k_charge = spec.v_th_charge(2 * n_in)
    t_on = jnp.concatenate(
        [enc.value_to_onset(x_p, t_window), enc.value_to_onset(x_m, t_window)]
    )
    t_plus = _column_crossings(t_on, prog["pos"], prog["bias_pos"], k_charge)
    t_minus = _column_crossings(t_on, prog["neg"], prog["bias_neg"], k_charge)
    y = enc.crossing_to_value(t_plus, t_window) - enc.crossing_to_value(t_minus, t_window)
    if return_times:
        return y, (t_plus, t_minus)
    return y


def ideal_four_quadrant(x: jax.Array, w: jax.Array, w_max: float) -> jax.Array:
    return (x @ w) / (2.0 * x.shape[0] * w_max)


def td_vmm_two_quadrant(x: jax.Array, w: jax.Array, spec: TDVMMSpec, return_times: bool = False):
    """Two-quadrant VMM: non-negative inputs, signed weights (section 3.1 end).

    Obtained from the four-quadrant design by removing the negative input
    wires; each output wire integrates N_in sources, so

        y = (w^T x) / (N_in * w_max).
    """
    n_in = x.shape[0]
    t_window = spec.t_window_s
    w_p, w_m = cur.four_quadrant_weights(w)
    i_pos, b_pos = cur.program_matrix(w_p, spec.i_max, spec.w_max)
    i_neg, b_neg = cur.program_matrix(w_m, spec.i_max, spec.w_max)
    k_charge = spec.v_th_charge(n_in)
    t_on = enc.value_to_onset(jnp.clip(x, 0.0, 1.0), t_window)
    t_plus = _column_crossings(t_on, i_pos, b_pos, k_charge)
    t_minus = _column_crossings(t_on, i_neg, b_neg, k_charge)
    y = enc.crossing_to_value(t_plus, t_window) - enc.crossing_to_value(t_minus, t_window)
    if return_times:
        return y, (t_plus, t_minus)
    return y


def ideal_two_quadrant(x: jax.Array, w: jax.Array, w_max: float) -> jax.Array:
    return (x @ w) / (x.shape[0] * w_max)


# --------------------------------------------------------------------------
# Time-domain ReLU (the AND gate of Fig. 2c) and chaining
# --------------------------------------------------------------------------
def relu_duration(t_plus: jax.Array, t_minus: jax.Array) -> jax.Array:
    """The rectify-linear AND gate: a pulse of duration t_minus - t_plus when the
    + latch fires first (positive output), zero otherwise (Fig. 1d / 2c)."""
    return jnp.maximum(t_minus - t_plus, 0.0)


def td_mlp_forward(
    x: jax.Array, w1: jax.Array, w2: jax.Array, spec: TDVMMSpec
) -> jax.Array:
    """Two-layer perceptron computed fully in the time domain (Fig. 2).

    Layer 1: four-quadrant VMM -> differential crossing times.
    ReLU:    AND gate -> pulse-duration-encoded hidden activations (section 3.1).
    Layer 2: two-quadrant VMM (inputs are non-negative pulse durations).

    Returns the decoded output of layer 2.  The ideal reference is
        h = relu(x @ w1) / (2 N_in w_max);  y = (h @ w2) / (N_h w_max).
    """
    t_window = spec.t_window_s
    _, (t1p, t1m) = td_vmm_four_quadrant(x, w1, spec, return_times=True)
    # AND-gate pulse duration encodes h in [0, T]; as charge it is equivalent
    # to a rising-edge input of value h (section 3.1: equal total on-time).
    h = enc.duration_to_value(relu_duration(t1p, t1m), t_window)
    return td_vmm_two_quadrant(h, w2, spec)


def ideal_mlp(x: jax.Array, w1: jax.Array, w2: jax.Array, w_max: float) -> jax.Array:
    h = jax.nn.relu(ideal_four_quadrant(x, w1, w_max))
    return ideal_two_quadrant(h, w2, w_max)


# batched variants ----------------------------------------------------------
td_vmm_four_quadrant_batched = jax.vmap(
    lambda x, w, spec: td_vmm_four_quadrant(x, w, spec), in_axes=(0, None, None)
)
td_mlp_forward_batched = jax.vmap(td_mlp_forward, in_axes=(0, None, None, None))


# --------------------------------------------------------------------------
# Pipelined operation (Fig. 2d)
# --------------------------------------------------------------------------
def pipeline_schedule(
    n_stages: int, n_samples: int, spec: TDVMMSpec
) -> dict[str, float]:
    """Timing of the two-phase pipelined schedule (Fig. 2d).

    Each stage computes during phase I ([0,T]) and reads out during phase II
    ([T,2T]); phase II of stage l *is* phase I of stage l+1 (the SET/OR gating
    decouples adjacent VMMs).  New samples are admitted every 2T + tau_reset.
    """
    t = spec.t_window_s
    period = 2.0 * t + TAU_RESET_S
    first_out = (n_stages + 1) * t + n_stages * TAU_F_S
    total = (n_samples - 1) * period + first_out
    return {
        "period_s": period,
        "first_output_s": first_out,
        "total_s": total,
        "throughput_samples_per_s": 1.0 / period,
    }
