"""Weight -> current-source programming (paper Eq. 5-7) and four-quadrant split.

For an N-input column with weights w_i in [0, w_max], Eq. 6 programs

    I_i = I_max * w_i / (2*w_max - mean(w))

(derived from the paper's Eq. 6 after substituting Eq. 5,
 C*V_TH = N*I_max*T), and Eq. 7 adds a bias source, always on from t=0:

    I_0 = 1/2 * (N*I_max - sum_i I_i).

With these, the crossing time of the charge threshold K = C*V_TH = N*I_max*T
encodes exactly  y = sum_i w_i x_i / (N*w_max)  — weight-scale-free, which is
what allows chaining VMMs in the time domain (section 2.2).

Invariants (asserted in tests):
    0 <= I_i <= I_max      (currents are realizable, Eq. 6 denominator > 0)
    I_0 >= 0               (since sum I_i <= N*I_max)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def program_column(w: jax.Array, i_max: float, w_max: float) -> tuple[jax.Array, jax.Array]:
    """Program one column of N non-negative weights.

    Args:
      w: (N,) weights in [0, w_max].
      i_max: maximum source current.
      w_max: weight bound.

    Returns:
      (currents (N,), bias_current scalar)
    """
    n = w.shape[0]
    mean_w = jnp.mean(w)
    denom = 2.0 * w_max - mean_w          # in [w_max, 2*w_max] -> always > 0
    currents = i_max * w / denom
    bias = 0.5 * (n * i_max - jnp.sum(currents))
    return currents, bias


def program_matrix(w: jax.Array, i_max: float, w_max: float) -> tuple[jax.Array, jax.Array]:
    """Program a full (N_in, N_out) non-negative weight matrix column-wise.

    Returns (currents (N_in, N_out), bias (N_out,)).
    """
    n_in = w.shape[0]
    mean_w = jnp.mean(w, axis=0)          # (N_out,)
    denom = 2.0 * w_max - mean_w
    currents = i_max * w / denom[None, :]
    bias = 0.5 * (n_in * i_max - jnp.sum(currents, axis=0))
    return currents, bias


def four_quadrant_weights(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Signed weight matrix -> (W_plus, W_minus), both >= 0, W = W_plus - W_minus.

    In the circuit each weight owns four current sources: for w > 0,
    I^{++} = I^{--} = program(w), I^{+-} = I^{-+} = 0; mirrored for w < 0
    (section 2.2).  The rectified split realizes exactly that.
    """
    return jnp.maximum(w, 0.0), jnp.maximum(-w, 0.0)


def four_quadrant_program(
    w: jax.Array, i_max: float, w_max: float
) -> dict[str, jax.Array]:
    """Program the four current-source arrays for a signed (N_in, N_out) matrix.

    The positive output wire integrates  x+ @ W+  +  x- @ W-   (2*N_in sources),
    the negative output wire integrates  x+ @ W-  +  x- @ W+.

    Each output wire therefore sees a single-quadrant dot product with an
    effective input count of 2*N_in; the bias current is programmed for that
    stacked column.

    Returns dict with:
      'pos': (2*N_in, N_out) currents feeding the + wire  [W+ stacked over W-]
      'neg': (2*N_in, N_out) currents feeding the - wire  [W- stacked over W+]
      'bias_pos', 'bias_neg': (N_out,) bias currents.
    """
    w_plus, w_minus = four_quadrant_weights(w)
    stacked_pos = jnp.concatenate([w_plus, w_minus], axis=0)   # x+ rows, then x- rows
    stacked_neg = jnp.concatenate([w_minus, w_plus], axis=0)
    i_pos, b_pos = program_matrix(stacked_pos, i_max, w_max)
    i_neg, b_neg = program_matrix(stacked_neg, i_max, w_max)
    return {"pos": i_pos, "neg": i_neg, "bias_pos": b_pos, "bias_neg": b_neg}


def quantize_weights(w: jax.Array, weight_bits: int, w_max: float) -> jax.Array:
    """Model finite programming resolution of the FG current sources.

    The tuning procedure of ref. [15] reaches a target current within a
    relative tolerance; we model it as uniform quantization of the magnitude
    to 2^weight_bits levels over [0, w_max] (per quadrant).
    """
    levels = (1 << weight_bits) - 1
    mag = jnp.clip(jnp.abs(w) / w_max, 0.0, 1.0)
    mag_q = jnp.round(mag * levels) / levels
    return jnp.sign(w) * mag_q * w_max
