"""Model-wide TD-VMM calibration state.

The §3.1 output-window calibration ("slope ... controlled by appropriate
scaling of VMM weights") is **model state**, not a frozen config field: each
site's readout window is captured once on a representative batch and then
pinned for serving, where it (a) skips the per-call max|z| reduction and
(b) unlocks the Pallas fused-epilogue kernel (a fixed window is tile-local).

``CalibrationState`` is a pytree — per-site scalar windows, per-expert
``(E,)`` vector windows for expert-batched sites, and per-member ``(G,)``
vector windows for grouped sites (``attn.qkv``, ``ssm.in_proj``: the G
same-input projections of one shared-input launch each calibrate their own
tile window, captured in one record instead of G max-merged scalars) — so it
checkpoints through ``repro.checkpoint.checkpoint`` like any other state and
threads through ``models.model.prefill_step`` / ``decode_step``.

Capture protocol: ``collect()`` installs a process-wide collector;
``core.layers.td_matmul`` / ``td_expert_matmul`` then record each site's
latch-normalized max|z| via ``jax.debug.callback`` (values produced inside
``lax.scan``-ed layer stacks are tracers — the callback is the supported
escape hatch, and max-merging is order-independent).  The model-wide pass
lives in ``models.model.calibrate``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TDVMMPlan, tdvmm_rule


@dataclasses.dataclass
class CalibrationState:
    """Per-site calibrated readout windows.

    windows: site name -> f32 window; shape ``()`` for plain sites, ``(E,)``
    for expert-batched sites (one window per expert's analog tile).
    """
    windows: dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def sites(self) -> tuple[str, ...]:
        return tuple(sorted(self.windows))

    @classmethod
    def from_collected(cls, collected: dict[str, np.ndarray],
                       floor: float = 1e-9) -> "CalibrationState":
        return cls(windows={
            site: jnp.asarray(np.maximum(np.asarray(v, np.float32), floor))
            for site, v in sorted(collected.items())})


jax.tree_util.register_dataclass(
    CalibrationState, data_fields=["windows"], meta_fields=[])


# ---------------------------------------------------------------------------
# Collector (capture-time side channel)
# ---------------------------------------------------------------------------
class _Collector(threading.local):
    def __init__(self):
        self.store: Optional[dict[str, np.ndarray]] = None


_COLLECTOR = _Collector()


def active() -> bool:
    """True while a ``collect()`` context is installed (trace-time check —
    the serving fast path pays nothing when no calibration is running)."""
    return _COLLECTOR.store is not None


def record(site: str, z_max: jax.Array) -> None:
    """Max-merge one site's latch-normalized |z| maximum (scalar or (E,))
    into the active collector.  No-op without a collector."""
    store = _COLLECTOR.store
    if store is None or not site:
        return

    def _merge(value):
        # Closes over the dict itself: debug callbacks run on a runtime
        # thread where the installing thread's local slot is not visible.
        value = np.asarray(value, np.float32)
        prev = store.get(site)
        store[site] = value if prev is None else np.maximum(prev, value)

    jax.debug.callback(_merge, z_max)


@contextlib.contextmanager
def collect() -> Iterator[dict[str, np.ndarray]]:
    """Install a collector; yields the (mutating) site -> max|z| dict.

    The barrier on exit flushes outstanding debug callbacks so every
    recorded site is present before the caller reads the dict."""
    if _COLLECTOR.store is not None:
        raise RuntimeError("nested calibration collect() is not supported")
    _COLLECTOR.store = {}
    try:
        yield _COLLECTOR.store
        jax.effects_barrier()
    finally:
        _COLLECTOR.store = None


# ---------------------------------------------------------------------------
# Applying captured state to a model config
# ---------------------------------------------------------------------------
def _host_window(value) -> float | tuple[float, ...]:
    arr = np.asarray(value)
    if arr.ndim == 0:
        return float(arr)
    if arr.ndim == 1:
        return tuple(float(v) for v in arr)
    raise ValueError(f"calibration window must be scalar or (E,), "
                     f"got shape {arr.shape}")


def apply_calibration(cfg: ModelConfig,
                      calib: Optional[CalibrationState]) -> ModelConfig:
    """Bake a CalibrationState into the model's plan.

    Each captured window becomes an appended exact-site rule setting
    ``out_scale`` — later rules win, so calibration overrides any statically
    configured window while every other site setting is untouched.  Windows
    are converted to host floats here (out_scale is a jit-static kernel
    argument), which requires concrete values: apply before/at trace time,
    not on traced state.
    """
    if calib is None or not calib.windows:
        return cfg
    from repro.configs.plan import GROUPED_SITES
    plan = cfg.tdvmm_plan if cfg.tdvmm_plan is not None else TDVMMPlan()
    rules = []
    for site in sorted(calib.windows):
        window = _host_window(calib.windows[site])
        members = GROUPED_SITES.get(site)
        if members and isinstance(window, tuple) and len(window) != len(members):
            raise ValueError(
                f"grouped site {site!r}: calibration captured "
                f"{len(window)} windows for the {len(members)}-member "
                f"launch {members}")
        rules.append(tdvmm_rule(site, out_scale=window))
    return cfg.replace(tdvmm_plan=plan.with_rules(*rules))
