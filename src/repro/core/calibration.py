"""Model-wide TD-VMM calibration state.

The §3.1 output-window calibration ("slope ... controlled by appropriate
scaling of VMM weights") is **model state**, not a frozen config field: each
site's readout window is captured once on a representative batch and then
pinned for serving, where it (a) skips the per-call max|z| reduction and
(b) unlocks the Pallas fused-epilogue kernel (a fixed window is tile-local).

``CalibrationState`` is a pytree — per-site scalar windows, per-expert
``(E,)`` vector windows for expert-batched sites, and per-member ``(G,)``
vector windows for grouped sites (``attn.qkv``, ``ssm.in_proj``: the G
same-input projections of one shared-input launch each calibrate their own
tile window, captured in one record instead of G max-merged scalars) — so it
checkpoints through ``repro.checkpoint.checkpoint`` like any other state and
threads through ``models.model.prefill_step`` / ``decode_step``.

Capture protocol: ``collect()`` installs a process-wide collector;
``core.layers.td_matmul`` / ``td_expert_matmul`` then record each site's
latch-normalized max|z| via ``jax.debug.callback`` (values produced inside
``lax.scan``-ed layer stacks are tracers — the callback is the supported
escape hatch, and max-merging is order-independent).  The model-wide pass
lives in ``models.model.calibrate``.

Two serving-time mechanisms ride the same per-site channel:

  * **Runtime windows** (``runtime_windows`` / ``runtime_window``): a
    trace-time context mapping site -> f32 *array* window.  When a site
    resolves its readout window here, the window enters the compiled program
    as a runtime operand instead of a baked jit-static constant — so a
    restored or freshly recaptured ``CalibrationState`` can be hot-swapped
    between engine steps without recompiling (the two-compiled-step rule).
    Bitwise contract: the runtime-operand program evaluates the exact same
    barrier-pinned expression as the static path (``ops._epilogue``), so a
    window passed as an operand reproduces the baked-constant outputs bit
    for bit.
  * **Clip tracking** (``collect(pinned=...)``): a capture pass given the
    currently pinned windows additionally records, per site, how much of the
    latch-normalized |z| mass exceeds its pinned window — the readout
    *saturation/clip rate* that drift detection
    (``models.model.drift_probe`` -> ``runtime.engine.DriftConfig``)
    thresholds to decide when the §3.1 windows have gone stale.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TDVMMPlan, tdvmm_rule


@dataclasses.dataclass
class CalibrationState:
    """Per-site calibrated readout windows.

    windows: site name -> f32 window; shape ``()`` for plain sites, ``(E,)``
    for expert-batched sites (one window per expert's analog tile).
    """
    windows: dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def sites(self) -> tuple[str, ...]:
        return tuple(sorted(self.windows))

    @classmethod
    def from_collected(cls, collected: dict[str, np.ndarray],
                       floor: float = 1e-9) -> "CalibrationState":
        return cls(windows={
            site: jnp.asarray(np.maximum(np.asarray(v, np.float32), floor))
            for site, v in sorted(collected.items())})

    def as_arrays(self) -> dict[str, jax.Array]:
        """Site -> f32 window *array* (the runtime-operand form consumed by
        ``runtime_windows`` and the serving engine's hot-swap path)."""
        return {site: jnp.asarray(v, jnp.float32)
                for site, v in sorted(self.windows.items())}

    def drift_ratios(self, fresh: "CalibrationState") -> dict[str, float]:
        """Per-site max over the window elements of fresh/pinned — the drift
        magnitude a recalibration decision thresholds.  > 1 means the live
        max|z| outgrew the pinned window (readout clips); < 1 means the
        window is now oversized (resolution loss)."""
        out = {}
        for site, pinned in self.windows.items():
            if site not in fresh.windows:
                continue
            p = np.maximum(np.asarray(pinned, np.float64), 1e-12)
            f = np.asarray(fresh.windows[site], np.float64)
            if p.shape != f.shape:
                raise ValueError(
                    f"site {site!r}: pinned window shape {p.shape} vs "
                    f"recaptured {f.shape} — calibration structure changed")
            r = f / p
            # report the element that drifted FURTHEST from 1, either way
            out[site] = float(r.flat[np.argmax(np.abs(np.log(
                np.maximum(r, 1e-12))))])
        return out


jax.tree_util.register_dataclass(
    CalibrationState, data_fields=["windows"], meta_fields=[])


# ---------------------------------------------------------------------------
# Collector (capture-time side channel)
# ---------------------------------------------------------------------------
class _Collector(threading.local):
    def __init__(self):
        self.store: Optional[dict[str, np.ndarray]] = None
        self.pinned: Optional[dict[str, np.ndarray]] = None
        self.clips: Optional[dict[str, np.ndarray]] = None


_COLLECTOR = _Collector()


def active() -> bool:
    """True while a ``collect()`` context is installed (trace-time check —
    the serving fast path pays nothing when no calibration is running)."""
    return _COLLECTOR.store is not None


def clip_reference(site: str) -> Optional[np.ndarray]:
    """The pinned window the active collector tracks clip rates against for
    ``site`` (None when no clip tracking is requested) — concrete host
    values, so layers can fold the comparison into the capture pass."""
    pinned = _COLLECTOR.pinned
    if pinned is None or not site:
        return None
    return pinned.get(site)


def record(site: str, z_max: jax.Array) -> None:
    """Max-merge one site's latch-normalized |z| maximum (scalar or (E,))
    into the active collector.  No-op without a collector."""
    store = _COLLECTOR.store
    if store is None or not site:
        return

    def _merge(value):
        # Closes over the dict itself: debug callbacks run on a runtime
        # thread where the installing thread's local slot is not visible.
        value = np.asarray(value, np.float32)
        prev = store.get(site)
        store[site] = value if prev is None else np.maximum(prev, value)

    jax.debug.callback(_merge, z_max)


def record_clip(site: str, exceed: jax.Array, total: int) -> None:
    """Accumulate one site's (clipped-element count, element count) pair —
    the readout-saturation tally against the collector's pinned windows.
    No-op unless ``collect(pinned=...)`` installed clip tracking."""
    clips = _COLLECTOR.clips
    if clips is None or not site:
        return

    def _merge(exceed_v):
        delta = np.asarray([float(exceed_v), float(total)], np.float64)
        prev = clips.get(site)
        clips[site] = delta if prev is None else prev + delta

    jax.debug.callback(_merge, exceed)


def clip_rates(clips: dict[str, np.ndarray]) -> dict[str, float]:
    """(exceed, total) tallies -> per-site clip fraction in [0, 1]."""
    return {site: float(v[0] / max(v[1], 1.0)) for site, v in clips.items()}


def clip_rate_metrics(rates: dict[str, float]) -> dict[str, float]:
    """Per-site clip rates as ``MetricsSink`` series names
    (``clip_rate.<site>``), in sorted site order so the observation
    sequence is deterministic — the naming contract between the engine's
    live clip observation, ``AlertRule(metric="clip_rate.ffn.out", ...)``
    wiring, and ``launch/serve.py --alert-on``."""
    return {f"clip_rate.{site}": float(v)
            for site, v in sorted(rates.items())}


@contextlib.contextmanager
def collect(pinned: Optional[dict[str, np.ndarray]] = None,
            ) -> Iterator[dict[str, np.ndarray]]:
    """Install a collector; yields the (mutating) site -> max|z| dict.

    With ``pinned`` (site -> concrete window values), the pass additionally
    tallies per-site clip counts against those windows; read them from
    ``last_clips()`` after the context exits (or use
    ``models.model.drift_probe``, which packages both).

    The barrier on exit flushes outstanding debug callbacks so every
    recorded site is present before the caller reads the dict."""
    if _COLLECTOR.store is not None:
        raise RuntimeError("nested calibration collect() is not supported")
    _COLLECTOR.store = {}
    _COLLECTOR.clips = {} if pinned is not None else None
    _COLLECTOR.pinned = None if pinned is None else {
        site: np.asarray(v, np.float32) for site, v in pinned.items()}
    try:
        yield _COLLECTOR.store
        jax.effects_barrier()
    finally:
        _LAST_CLIPS[0] = _COLLECTOR.clips
        _COLLECTOR.store = None
        _COLLECTOR.pinned = None
        _COLLECTOR.clips = None


_LAST_CLIPS: list = [None]


def last_clips() -> Optional[dict[str, np.ndarray]]:
    """(exceed, total) tallies from the most recent ``collect(pinned=...)``
    pass (None when the last pass did not track clips)."""
    return _LAST_CLIPS[0]


# ---------------------------------------------------------------------------
# Runtime windows (hot-swappable serving calibration)
# ---------------------------------------------------------------------------
class _RuntimeWindows(threading.local):
    def __init__(self):
        self.map: Optional[dict[str, jax.Array]] = None


_RUNTIME = _RuntimeWindows()


@contextlib.contextmanager
def runtime_windows(windows: Optional[dict[str, jax.Array]]):
    """Install site -> f32 window *arrays* for the duration of a trace.

    Inside the context every TD-VMM site whose name appears in the map takes
    its readout window from the array (a runtime operand — typically a jit
    argument of the caller) instead of the plan's static ``out_scale``.
    This is what lets the serving engine swap a recaptured
    ``CalibrationState`` between steps without recompiling: same structure,
    same shapes, new values -> same compiled executable.

    Nesting installs the inner map (restored on exit); ``None``/empty maps
    are a no-op context.
    """
    prev = _RUNTIME.map
    _RUNTIME.map = dict(windows) if windows else prev
    try:
        yield
    finally:
        _RUNTIME.map = prev


def runtime_window_map() -> Optional[dict[str, jax.Array]]:
    """The full site -> window map currently installed (None outside a
    ``runtime_windows`` context).  Used by shard_map call sites that must
    re-install the map *inside* the per-shard body — closures over the
    outer-trace arrays would capture full ``(E,)`` windows where an
    expert-parallel shard only owns its ``(E_loc,)`` slice."""
    return _RUNTIME.map


def runtime_window(site: str) -> Optional[jax.Array]:
    """The runtime window array installed for ``site`` (trace-time lookup;
    None outside a ``runtime_windows`` context or for uncovered sites)."""
    m = _RUNTIME.map
    if m is None or not site:
        return None
    return m.get(site)


# ---------------------------------------------------------------------------
# Applying captured state to a model config
# ---------------------------------------------------------------------------
def _host_window(value) -> float | tuple[float, ...]:
    arr = np.asarray(value)
    if arr.ndim == 0:
        return float(arr)
    if arr.ndim == 1:
        return tuple(float(v) for v in arr)
    raise ValueError(f"calibration window must be scalar or (E,), "
                     f"got shape {arr.shape}")


def apply_calibration(cfg: ModelConfig,
                      calib: Optional[CalibrationState]) -> ModelConfig:
    """Bake a CalibrationState into the model's plan.

    Each captured window becomes an appended exact-site rule setting
    ``out_scale`` — later rules win, so calibration overrides any statically
    configured window while every other site setting is untouched.  Windows
    are converted to host floats here (out_scale is a jit-static kernel
    argument), which requires concrete values: apply before/at trace time,
    not on traced state.
    """
    if calib is None or not calib.windows:
        return cfg
    from repro.configs.plan import GROUPED_SITES
    plan = cfg.tdvmm_plan if cfg.tdvmm_plan is not None else TDVMMPlan()
    rules = []
    for site in sorted(calib.windows):
        window = _host_window(calib.windows[site])
        members = GROUPED_SITES.get(site)
        if members and isinstance(window, tuple) and len(window) != len(members):
            raise ValueError(
                f"grouped site {site!r}: calibration captured "
                f"{len(window)} windows for the {len(members)}-member "
                f"launch {members}")
        rules.append(tdvmm_rule(site, out_scale=window))
    return cfg.replace(tdvmm_plan=plan.with_rules(*rules))
