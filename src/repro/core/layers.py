"""TDVMMLinear: the paper's multiplier as a drop-in linear layer for models.

The fast path is the *closed form* of the four-quadrant TD-VMM (exact by
Eq. 1-7, property-tested against the event-driven simulator in tdcore.py):

    tile input   x -> x / s_x,   s_x = max|x|          (input range normalize)
    time-encode  x+ , x-  each fake-quantized to p bits (counter DAC, Eq. 2)
    program      W -> W+ - W-, each quantized to weight_bits levels (FG tuning)
    integrate    z = xq @ wq                            (charge accumulation)
    latch        y_norm = z / (2 N w_max)               (crossing time, Eq. 1)
    read out     y_norm fake-quantized to p bits when the tile boundary is
                 digital (shared-counter ADC); skipped when chained in time
    rescale      y = y_norm * 2 N w_max * s_x

Gradients: straight-through estimators on every quantizer (standard QAT), so
the layer is trainable inside any JAX model.  Optional stochastic DIBL /
tuning noise (core/nonideal.py) models deploy-time precision during training.

On TPU the integer core is the Pallas kernel in kernels/tdvmm (ops.py); the
jnp path below is numerically identical and is what the distributed dry-run
lowers (same FLOPs/bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import encoding as enc
from repro.core import nonideal
from repro.core.constants import TDVMMSpec


@dataclasses.dataclass(frozen=True)
class TDVMMLayerConfig:
    enabled: bool = False
    bits: int = 6                 # time-code (input/output) precision p
    weight_bits: int = 6          # FG programming precision
    io_quantize: bool = True      # digital tile boundary (False = time-chained)
    per_channel: bool = True      # per-output-column weight scale
    output_calibration: bool = True  # scale weights so outputs fill the [T,2T]
    # window (section 3.1: "slope ... controlled by appropriate scaling of VMM
    # weights"); modeled as a stop-grad per-tensor output gain.
    noise: bool = False           # stochastic DIBL + tuning noise (train-time)
    spec: TDVMMSpec = dataclasses.field(default_factory=TDVMMSpec)

    def replace(self, **kw) -> "TDVMMLayerConfig":
        return dataclasses.replace(self, **kw)


def _ste(x_quant: jax.Array, x: jax.Array) -> jax.Array:
    """Straight-through: forward x_quant, backward identity."""
    return x + jax.lax.stop_gradient(x_quant - x)


def _fake_quant_signed(x: jax.Array, bits: int) -> jax.Array:
    """Differential p-bit quantization: each wire of the (+,-) pair carries a
    p-bit time code; values assumed pre-normalized to [-1, 1]."""
    q = jnp.sign(x) * enc.fake_quant(jnp.abs(x), bits)
    return _ste(q, x)


def td_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: TDVMMLayerConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Four-quadrant TD-VMM fast path.  x: (..., N_in), w: (N_in, N_out)."""
    if not cfg.enabled:
        from repro.models import common as _c
        pet = _c.matmul_out_dtype()
        if pet is not None:
            return jnp.dot(x, w, preferred_element_type=pet)
        return x @ w

    n_in = w.shape[0]
    # ---- input range normalization (per example row; stop-grad scale) ----
    s_x = jax.lax.stop_gradient(
        jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-6)
    )
    xq = _fake_quant_signed(x / s_x, cfg.bits)

    # ---- weight programming ----
    axes = 0 if cfg.per_channel else None
    w_max = jax.lax.stop_gradient(
        jnp.maximum(jnp.max(jnp.abs(w), axis=axes, keepdims=True), 1e-6)
    )
    levels = (1 << cfg.weight_bits) - 1
    wq = jnp.round(jnp.clip(w / w_max, -1.0, 1.0) * levels) / levels
    wq = _ste(wq, w / w_max)  # normalized quantized weights in [-1, 1]

    if cfg.noise and key is not None:
        err = nonideal.relative_error(
            cfg.spec.i_max, jnp.asarray(cfg.spec.v_sg), jnp.asarray(cfg.spec.delta_vd)
        )
        k1, k2 = jax.random.split(key)
        u = jax.random.uniform(k1, wq.shape, minval=-1.0, maxval=1.0)
        wq = wq * (1.0 + err * u)
        wq = wq * jnp.exp(0.003 * jax.random.normal(k2, wq.shape))

    # ---- charge integration + latch (normalized output in [-1, 1]) ----
    z = (xq @ wq) / (2.0 * n_in)       # == y+ - y- of the differential pair
    if cfg.io_quantize:
        if cfg.output_calibration:
            # weight-scaling calibration: amplify so the dot product spans the
            # full output window before the p-bit readout (power is in s_y).
            s_y = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(z)), 1e-9))
        else:
            s_y = 0.5  # raw differential range [-1/2, 1/2] -> [-1, 1]
        z = _fake_quant_signed(z / s_y, cfg.bits) * s_y

    # ---- digital rescale back to model units (keep activation dtype) ----
    y = z * (2.0 * n_in) * w_max.reshape((w_max.shape[-1],)) * s_x
    return y.astype(x.dtype)


def init_linear(
    key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None
) -> jax.Array:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


class TDVMMLinear:
    """Functional linear layer: params = {'w': (d_in,d_out) [, 'b': (d_out,)]}"""

    @staticmethod
    def init(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
        p = {"w": init_linear(key, d_in, d_out, dtype)}
        if bias:
            p["b"] = jnp.zeros((d_out,), dtype)
        return p

    @staticmethod
    def apply(params, x, cfg: TDVMMLayerConfig, key=None):
        y = td_matmul(x, params["w"], cfg, key)
        if "b" in params:
            y = y + params["b"]
        return y
