"""TDVMMLinear: the paper's multiplier as a drop-in linear layer for models.

td_matmul is the *closed form* of the four-quadrant TD-VMM (exact by Eq. 1-7,
property-tested against the event-driven simulator in tdcore.py), structured
as the explicit code-and-scale pipeline of core/quant.py:

    plan         flatten (..., N_in) to 2-D, resolve the integrate backend
    encode       x -> p-bit signed time codes + per-row scale   (Eq. 2, DAC)
    program      W -> signed current codes + per-channel scale  (FG tuning)
    integrate    codes matmul — kernels/tdvmm (Pallas on TPU, interpret
                 elsewhere) or jnp.dot; identical integer arithmetic
    readout      latch normalization + p-bit ADC over the calibrated output
                 window when the tile boundary is digital      (Eq. 3, §4.2)
    rescale      digital per-row x per-channel rescale to model units

Gradients: straight-through estimators on every quantizer (standard QAT) and
a plain-matmul custom VJP on the integrate stage, so the layer is trainable
inside any JAX model on either backend.  Optional stochastic DIBL / tuning
noise (core/nonideal.py) models deploy-time precision during training.

Arbitrary leading batch dims and non-block-multiple shapes are supported:
codes are flattened to (M, K) and zero-padded to the kernel's block multiples
(a zero time code contributes zero charge, so padding is exact).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TDVMMLayerConfig  # re-export (historic home)
from repro.core import quant

__all__ = ["TDVMMLayerConfig", "td_matmul", "TDVMMLinear", "init_linear"]


class MatmulPlan(NamedTuple):
    """Static shape/backend bookkeeping for one td_matmul call."""
    batch_shape: tuple[int, ...]     # leading dims of x, flattened into M
    m: int
    k: int                           # N_in: sources per output column
    n: int
    backend: str                     # resolved: "jnp" | "pallas"


def plan_matmul(x_shape, w_shape, cfg: TDVMMLayerConfig) -> MatmulPlan:
    k, n = w_shape
    assert x_shape[-1] == k, (x_shape, w_shape)
    batch_shape = tuple(x_shape[:-1])
    m = 1
    for d in batch_shape:
        m *= d
    # f32 integer-exactness envelope: the backend-parity guarantee (and exact
    # charge accumulation) needs worst-case |acc| < 2^24.  6-bit codes are
    # safe to K = 4096; 8-bit only to K ~ 258.
    worst = ((1 << cfg.bits) - 1) * ((1 << cfg.weight_bits) - 1) * k
    if worst >= (1 << 24):
        warnings.warn(
            f"TD-VMM accumulator may exceed f32 integer range: "
            f"(2^{cfg.bits}-1)*(2^{cfg.weight_bits}-1)*K={worst} >= 2^24; "
            "charge sums can round and jnp/pallas backends may diverge",
            stacklevel=2)
    from repro.kernels.tdvmm import ops
    return MatmulPlan(batch_shape, m, k, n, ops.resolve_backend(cfg.backend))


def td_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: TDVMMLayerConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Four-quadrant TD-VMM fast path.  x: (..., N_in), w: (N_in, N_out)."""
    if not cfg.enabled:
        from repro.models import common as _c
        pet = _c.matmul_out_dtype()
        if pet is not None:
            return jnp.dot(x, w, preferred_element_type=pet)
        return x @ w

    # ---- plan: shapes + backend ----
    plan = plan_matmul(x.shape, w.shape, cfg)

    # ---- encode inputs / program weights (core/quant.py stages) ----
    qx = quant.encode_input(x, cfg.bits)
    qw = quant.program_weights(w, cfg.weight_bits, cfg.per_channel)
    if cfg.noise and key is not None:
        qw = quant.program_noise(qw, cfg.spec, key)

    # ---- integrate + readout + rescale (kernel epilogue) ----
    # Latch gain: codes -> normalized differential output z = y+ - y- in
    # [-1, 1]: divide out both code ranges and the 2*N_in charge headroom.
    from repro.kernels.tdvmm import ops
    gain = 1.0 / (float(qx.levels) * float(qw.levels) * 2.0 * plan.k)
    # Digital rescale: per-row input range and per-channel 2*N_in*w_max.
    w_scale = jnp.broadcast_to(
        qw.scale.reshape(-1) * (2.0 * plan.k), (plan.n,))
    y = ops.tdvmm_matmul(
        qx.codes.reshape(plan.m, plan.k),
        qw.codes,
        qx.scale.reshape(plan.m),
        w_scale,
        gain=gain,
        out_bits=cfg.bits if cfg.io_quantize else None,
        # None -> calibrate the ADC window to the data (section 3.1); a fixed
        # 0.5 window is the raw differential range of a normalized tile.
        out_scale=None if cfg.output_calibration else 0.5,
        backend=plan.backend,
    )
    return y.reshape(plan.batch_shape + (plan.n,)).astype(x.dtype)


def init_linear(
    key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None
) -> jax.Array:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


class TDVMMLinear:
    """Functional linear layer: params = {'w': (d_in,d_out) [, 'b': (d_out,)]}"""

    @staticmethod
    def init(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
        p = {"w": init_linear(key, d_in, d_out, dtype)}
        if bias:
            p["b"] = jnp.zeros((d_out,), dtype)
        return p

    @staticmethod
    def apply(params, x, cfg: TDVMMLayerConfig, key=None):
        y = td_matmul(x, params["w"], cfg, key)
        if "b" in params:
            y = y + params["b"]
        return y
