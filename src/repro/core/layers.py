"""TDVMMLinear: the paper's multiplier as a drop-in linear layer for models.

td_matmul is the *closed form* of the four-quadrant TD-VMM (exact by Eq. 1-7,
property-tested against the event-driven simulator in tdcore.py), structured
as the explicit code-and-scale pipeline of core/quant.py:

    plan         flatten (..., N_in) to 2-D, pick code storage (int8 when the
                 signed code range fits — exact int32 accumulation, no 2^24
                 envelope — else f32), resolve the integrate backend + block
                 sizes from the autotune table
    encode       x -> p-bit signed time codes + per-row scale   (Eq. 2, DAC)
    program      W -> signed current codes + per-channel scale  (FG tuning)
    integrate    codes matmul — kernels/tdvmm (Pallas on TPU, interpret
                 elsewhere) or jnp.dot; identical integer arithmetic
    readout      latch normalization + p-bit ADC over the calibrated output
                 window when the tile boundary is digital      (Eq. 3, §4.2)
    rescale      digital per-row x per-channel rescale to model units

With a *fixed* readout window (``cfg.out_scale``, captured once by
``calibrate_out_scale`` / ``TDVMMLinear.calibrate`` on the serving path) the
Pallas backend fuses readout + rescale into the kernel's final K step, so
each output tile is written to HBM exactly once.

``td_expert_matmul`` is the batched (E, C, K) x (E, K, N) form for MoE
expert banks: one analog tile per expert, per-expert scales, the expert dim
mapped onto the kernel's batched grid axis.  ``td_grouped_matmul`` is the
shared-input sibling: G same-input projection matrices (attention q/k/v, the
SSM in_proj fan-out) concatenate along N into one ragged 2-D launch — each
member rounded only to the 128 lane, not to the widest member — while the
input is encoded once and read by every column — the paper's shared-DAC
amortization at the model level, one kernel dispatch instead of G.

Gradients: straight-through estimators on every quantizer (standard QAT) and
a plain-matmul custom VJP on the integrate stage, so the layer is trainable
inside any JAX model on either backend.  Optional stochastic DIBL / tuning
noise (core/nonideal.py) models deploy-time precision during training (noisy
codes are non-integer and force the f32 code path).

Arbitrary leading batch dims and non-block-multiple shapes are supported:
codes are flattened to (M, K) and zero-padded to the kernel's block multiples
(a zero time code contributes zero charge, so padding is exact).
"""
from __future__ import annotations

import math
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TDVMMLayerConfig  # re-export (historic home)
from repro.core import quant

__all__ = ["TDVMMLayerConfig", "td_matmul", "td_expert_matmul",
           "td_grouped_matmul", "calibrate_out_scale", "TDVMMLinear",
           "init_linear"]


class MatmulPlan(NamedTuple):
    """Static shape/backend/storage bookkeeping for one td_matmul call."""
    batch_shape: tuple[int, ...]     # leading dims of x, flattened into M
    m: int
    k: int                           # N_in: sources per output column
    n: int
    backend: str                     # resolved: "jnp" | "pallas"
    code_dtype: str                  # "int4" | "int8" | "f32" code storage
    blocks: tuple[int, int, int]     # autotuned (bm, bk, bn)


def _plan_code_dtype(cfg: TDVMMLayerConfig, k: int, noisy: bool) -> str:
    """Pick the code storage for a K-deep accumulation, warning only on the
    f32 fallback (the int8/int32 path is exact, so it never warns)."""
    lx = (1 << cfg.bits) - 1
    lw = (1 << cfg.weight_bits) - 1
    worst = lx * lw * max(k, 1)
    # int8 storage: both code ranges fit int8 (quant.storage_dtype owns that
    # rule), codes stay on the integer grid (no analog noise), and the
    # worst-case |acc| fits int32 — then accumulation is exact for ANY K,
    # no envelope to warn about.
    fits_int8 = (quant.storage_dtype(cfg.bits) == jnp.int8
                 and quant.storage_dtype(cfg.weight_bits) == jnp.int8)
    if not noisy and fits_int8 and worst < (1 << 31):
        # p <= 3 on both operands fits a signed nibble: the Pallas stream
        # packs two codes per byte (half the int8 HBM bytes) and unpacks
        # in-kernel — still exact int32 accumulation, bit-for-bit vs int8.
        if cfg.bits <= quant.INT4_MAX_BITS and \
                cfg.weight_bits <= quant.INT4_MAX_BITS:
            return "int4"
        return "int8"
    # f32 integer-exactness envelope: the backend-parity guarantee (and exact
    # charge accumulation) needs worst-case |acc| < 2^24.  6-bit codes are
    # safe to K = 4096; 8-bit only to K ~ 258.
    if worst >= (1 << 24):
        warnings.warn(
            f"TD-VMM f32 accumulator may exceed f32 integer range: "
            f"(2^{cfg.bits}-1)*(2^{cfg.weight_bits}-1)*K={worst} >= 2^24; "
            "charge sums can round and jnp/pallas backends may diverge",
            stacklevel=3)
    return "f32"


def plan_matmul(x_shape, w_shape, cfg: TDVMMLayerConfig,
                noisy: bool = False) -> MatmulPlan:
    k, n = w_shape
    assert x_shape[-1] == k, (x_shape, w_shape)
    batch_shape = tuple(x_shape[:-1])
    m = 1
    for d in batch_shape:
        m *= d
    code_dtype = _plan_code_dtype(cfg, k, noisy)
    from repro.kernels.tdvmm import ops
    kp = ops.plan_kernel(cfg.backend, m, k, n, code_dtype)
    return MatmulPlan(batch_shape, m, k, n, kp.backend, code_dtype, kp.blocks)


def _readout_args(
    cfg: TDVMMLayerConfig, n_experts: Optional[int] = None
) -> tuple[Optional[int], Optional[float | tuple[float, ...]]]:
    """(out_bits, out_scale) for the kernel epilogue.  Priority: a cached
    calibration window (cfg.out_scale) > data calibration (None, §3.1) > the
    fixed 0.5 raw differential window of a normalized tile.

    ``cfg.out_scale`` may be an (E,)-tuple of per-expert windows on
    expert-batched sites; ``n_experts`` validates the pairing (None = a 2-D
    site, where only a scalar window is meaningful).
    """
    if not cfg.io_quantize:
        return None, None
    if cfg.out_scale is not None:
        s = cfg.out_scale
        if isinstance(s, tuple):
            if n_experts is None:
                if len(s) != 1:
                    raise ValueError(
                        f"site {cfg.site or '<unnamed>'}: per-expert "
                        f"out_scale tuple (len {len(s)}) on a non-batched "
                        "matmul; expected a scalar window")
                return cfg.bits, float(s[0])
            if len(s) != n_experts:
                raise ValueError(
                    f"site {cfg.site or '<unnamed>'}: out_scale has "
                    f"{len(s)} windows for {n_experts} experts")
            return cfg.bits, tuple(float(v) for v in s)
        return cfg.bits, float(s)
    return cfg.bits, (None if cfg.output_calibration else 0.5)


def _runtime_override(cfg: TDVMMLayerConfig, out_bits, out_scale):
    """Swap a site's static readout window for the runtime-operand array
    installed by ``calibration.runtime_windows`` (the serving engine's
    hot-swappable calibration channel).  Outside that context — or for
    sites without a digital readout — this is a no-op passthrough."""
    if out_bits is None:
        return out_scale, None
    from repro.core import calibration
    rw = calibration.runtime_window(cfg.site)
    if rw is None:
        return out_scale, None
    return None, rw


def _latch_gain(levels_x: int, levels_w: int, k: int) -> float:
    """Latch gain: codes -> normalized differential output z = y+ - y- in
    [-1, 1]: divide out both code ranges and the 2*N_in charge headroom."""
    return 1.0 / (float(levels_x) * float(levels_w) * 2.0 * max(k, 1))


def _record_window(cfg: TDVMMLayerConfig, x_view, w_view, backend: str,
                   code_dtype: str, gain: float, per_tile: bool,
                   group_widths: Optional[tuple[int, ...]] = None) -> None:
    """Calibration capture: when a ``core.calibration`` collector is active
    and the site has a digital readout boundary, record its latch-normalized
    max|z| — a scalar, the per-expert-tile ``(E,)`` vector when ``per_tile``,
    or the per-member ``(G,)`` vector over a ragged concat launch's column
    spans (``group_widths``) — exactly the window per-call data calibration
    would use.  Costs one extra codes matmul per site, paid only during the
    (one-time) calibration pass.

    Under ``collect(pinned=...)`` (a drift probe) the same pass also tallies
    the site's readout *clip count* — how many |z| elements exceed the
    currently pinned window — feeding the saturation-rate drift trigger."""
    from repro.core import calibration
    if not calibration.active() or not cfg.io_quantize:
        return
    from repro.kernels.tdvmm import ops
    acc = ops.codes_matmul(x_view, w_view, backend, code_dtype=code_dtype)
    z = jnp.abs(acc.astype(jnp.float32) * gain)
    ref = calibration.clip_reference(cfg.site)
    if ref is not None:
        if group_widths is not None:
            # Per-member windows expand to per-column thresholds; pad
            # columns threshold at +inf (zero charge, never a clip).
            cols = np.concatenate(
                [np.full(wd, float(v), np.float32) for v, wd in
                 zip(np.asarray(ref, np.float32).reshape(-1), group_widths)])
            tail = z.shape[-1] - cols.size
            if tail > 0:
                cols = np.concatenate(
                    [cols, np.full(tail, np.inf, np.float32)])
            thresh = jnp.asarray(cols)
        elif per_tile:
            thresh = jnp.asarray(ref, jnp.float32).reshape(-1, 1, 1)
        else:
            thresh = jnp.float32(np.float32(ref))
        calibration.record_clip(cfg.site, jnp.sum(z > thresh), int(z.size))
    if group_widths is not None:
        # Member g owns columns [off, off + width_g); pad columns are zero
        # charge, so the span max equals the member's standalone max.
        off, maxes = 0, []
        for wd in group_widths:
            maxes.append(jnp.max(z[..., off:off + wd], initial=0.0))
            off += wd
        calibration.record(cfg.site, jnp.stack(maxes))
        return
    calibration.record(
        cfg.site,
        jnp.max(z, axis=((-2, -1) if per_tile else None), initial=0.0))


def td_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: TDVMMLayerConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Four-quadrant TD-VMM fast path.  x: (..., N_in), w: (N_in, N_out)."""
    if not cfg.enabled:
        from repro.models import common as _c
        pet = _c.matmul_out_dtype()
        if pet is not None:
            return jnp.dot(x, w, preferred_element_type=pet)
        return x @ w

    noisy = cfg.noise and key is not None

    # ---- plan: shapes + code storage + backend/blocks ----
    plan = plan_matmul(x.shape, w.shape, cfg, noisy=noisy)

    # ---- encode inputs / program weights (core/quant.py stages) ----
    qx = quant.encode_input(x, cfg.bits)
    qw = quant.program_weights(w, cfg.weight_bits, cfg.per_channel)
    if noisy:
        qw = quant.program_noise(qw, cfg.spec, key)

    # ---- integrate + readout + rescale (kernel epilogue) ----
    from repro.kernels.tdvmm import ops
    gain = _latch_gain(qx.levels, qw.levels, plan.k)
    # Digital rescale: per-row input range and per-channel 2*N_in*w_max.
    w_scale = jnp.broadcast_to(
        qw.scale.reshape(-1) * (2.0 * plan.k), (plan.n,))
    out_bits, out_scale = _readout_args(cfg)
    out_scale, out_window = _runtime_override(cfg, out_bits, out_scale)
    _record_window(cfg, qx.view().reshape(plan.m, plan.k), qw.view(),
                   plan.backend, plan.code_dtype, gain, per_tile=False)
    y = ops.tdvmm_matmul(
        qx.view().reshape(plan.m, plan.k),
        qw.view(),
        qx.scale.reshape(plan.m),
        w_scale,
        gain=gain,
        out_bits=out_bits,
        out_scale=out_scale,
        backend=plan.backend,
        code_dtype=plan.code_dtype,
        block_sizes=plan.blocks,
        out_window=out_window,
    )
    return y.reshape(plan.batch_shape + (plan.n,)).astype(x.dtype)


def td_expert_matmul(
    x: jax.Array,            # (E, C, N_in) expert-batched activations
    w: jax.Array,            # (E, N_in, N_out) stacked expert weight bank
    cfg: TDVMMLayerConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Batched four-quadrant TD-VMM: one analog tile per expert.

    The MoE dispatch buffer multiplies against every expert's weight matrix
    in one kernel launch — the expert dim rides the kernel's batched grid
    axis, with per-expert-per-row input scales and per-expert-per-channel
    weight scales.  Zero-padded (ragged) expert rows carry zero codes and
    contribute zero charge, so capacity padding is exact.
    """
    if not cfg.enabled:
        from repro.models import common as _c
        pet = _c.matmul_out_dtype()
        kw = {"preferred_element_type": pet} if pet is not None else {}
        return jnp.einsum("eck,ekn->ecn", x, w, **kw)

    e, c, k = x.shape
    e2, k2, n = w.shape
    assert e == e2 and k == k2, (x.shape, w.shape)
    noisy = cfg.noise and key is not None
    code_dtype = _plan_code_dtype(cfg, k, noisy)
    from repro.kernels.tdvmm import ops
    kp = ops.plan_kernel(cfg.backend, c, k, n, code_dtype)

    qx = quant.encode_input(x, cfg.bits)                       # scale (E, C, 1)
    qw = quant.program_weights(w, cfg.weight_bits, cfg.per_channel)
    if noisy:
        qw = quant.program_noise(qw, cfg.spec, key)

    gain = _latch_gain(qx.levels, qw.levels, k)
    # qw.scale is (E, 1, N) per-channel or (E, 1, 1) per-tensor; the explicit
    # last dim (not -1) keeps E=0 expert stacks reshapeable.
    w_scale = jnp.broadcast_to(
        qw.scale.reshape(e, qw.scale.shape[-1]) * (2.0 * k), (e, n))
    out_bits, out_scale = _readout_args(cfg, n_experts=e)
    out_scale, out_window = _runtime_override(cfg, out_bits, out_scale)
    # Per-expert windows: each expert is its own analog tile, so the
    # recorded vector is the (E,) per-tile max the epilogue calibrates.
    _record_window(cfg, qx.view(), qw.view(), kp.backend, code_dtype, gain,
                   per_tile=True)
    y = ops.tdvmm_matmul(
        qx.view(),
        qw.view(),
        qx.scale.reshape(e, c),
        w_scale,
        gain=gain,
        out_bits=out_bits,
        out_scale=out_scale,
        backend=kp.backend,
        code_dtype=code_dtype,
        block_sizes=kp.blocks,
        out_window=out_window,
    )
    return y.astype(x.dtype)


def td_grouped_matmul(
    x: jax.Array,                       # (..., N_in) shared input
    ws: "tuple[jax.Array, ...]",        # G matrices (N_in, N_g), uneven N ok
    cfg: TDVMMLayerConfig,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, ...]:
    """Grouped four-quadrant TD-VMM: G same-input projections, one launch.

    The paper's NxN multiplier amortizes its I/O conversion circuitry across
    the whole tile — one DAC encode feeds every output column.  Call sites
    that project the *same* activation through several matrices (attention
    q/k/v, the SSM z/x/B/C/dt input projection) are the model-level analog:
    this encodes ``x`` once and runs the G weight matrices as a single
    **ragged concat** launch — the members concatenate along N into one 2-D
    ``(K, sum N_g)`` bank, each member rounded only to the 128 lane instead
    of padded to the widest member (the old batched-grid stacking cost
    attn.qkv with small KV heads a 2.3x padded-N overhead).

    Padding is exact — zero codes integrate zero charge; per-member
    per-channel weight scales concatenate into the epilogue's per-column
    scale row, and per-member readout windows resolve by column span
    (``group_widths``), so a grouped launch is bit-for-bit identical to the
    G sequential calls whenever the readout windows match (data calibration
    computes a per-member-span window, which *is* the per-call window).
    Returns a tuple of G arrays shaped ``(..., N_g)``.
    """
    ws = tuple(ws)
    if not ws:
        return ()
    if not cfg.enabled:
        from repro.models import common as _c
        pet = _c.matmul_out_dtype()
        kw = {"preferred_element_type": pet} if pet is not None else {}
        return tuple(jnp.dot(x, w, **kw) for w in ws)

    k = x.shape[-1]
    ns = tuple(w.shape[-1] for w in ws)
    for w in ws:
        assert w.ndim == 2 and w.shape[0] == k, (x.shape, w.shape)
    batch_shape = tuple(x.shape[:-1])
    m = 1
    for d in batch_shape:
        m *= d
    noisy = cfg.noise and key is not None
    code_dtype = _plan_code_dtype(cfg, k, noisy)
    from repro.kernels.tdvmm import ops, tdvmm
    # Per-member column spans: each member rounds to the 128 lane only.
    widths = tuple(
        tdvmm.padded_size(n, tdvmm.LANE, tdvmm.LANE) for n in ns)
    n_total = sum(widths)
    kp = ops.plan_kernel(cfg.backend, m, k, n_total, code_dtype)
    # No N block may span two members' readout windows: shrink block_n to
    # the gcd of the plan's choice and every member span (all multiples of
    # the 128 lane, so the gcd stays lane-aligned).
    bn_g = math.gcd(kp.bn, *widths)

    qx = quant.encode_input(x, cfg.bits)                       # encode ONCE
    qw = quant.concat_group(
        [quant.program_weights(w, cfg.weight_bits, cfg.per_channel)
         for w in ws], widths)
    if noisy:
        qw = quant.program_noise(qw, cfg.spec, key)

    gain = _latch_gain(qx.levels, qw.levels, k)
    w_scale = qw.scale.reshape(n_total) * (2.0 * k)
    out_bits, out_scale = _readout_args(cfg, n_experts=len(ws))
    out_scale, out_window = _runtime_override(cfg, out_bits, out_scale)
    # Per-member windows: each member's column span is its own analog tile,
    # so calibration records one (G,) vector for the site.
    _record_window(cfg, qx.view().reshape(m, k), qw.view(), kp.backend,
                   code_dtype, gain, per_tile=True, group_widths=widths)
    y = ops.tdvmm_matmul(
        qx.view().reshape(m, k),
        qw.view(),
        qx.scale.reshape(m),
        w_scale,
        gain=gain,
        out_bits=out_bits,
        out_scale=out_scale,
        backend=kp.backend,
        code_dtype=code_dtype,
        block_sizes=(kp.bm, kp.bk, bn_g),
        group_widths=widths,
        out_window=out_window,
    )                                                          # (M, n_total)
    outs, off = [], 0
    for n, wd in zip(ns, widths):
        outs.append(
            y[:, off:off + n].reshape(batch_shape + (n,)).astype(x.dtype))
        off += wd
    return tuple(outs)


def calibrate_out_scale(
    x: jax.Array, w: jax.Array, cfg: TDVMMLayerConfig,
    key: Optional[jax.Array] = None,
) -> float:
    """Serving-path readout calibration: capture the ADC window once.

    Runs encode -> program -> integrate on a representative batch and returns
    max|z| of the latch-normalized accumulation (the §3.1 output-window
    calibration) as a Python float.  Store it on the config
    (``cfg.replace(out_scale=...)``): per-call windows stop recomputing a
    global max, and the Pallas backend's fused-epilogue kernel becomes
    eligible (a fixed window is tile-local; a data-calibrated one is not).

    ``key`` matters when ``cfg.noise`` is set: the serving path perturbs the
    programmed currents, so the window must be captured over the *noisy*
    codes (``td_matmul`` with the same cfg/key) — a noise-free window would
    underestimate max|z| and clip the noisy deploy outputs.
    """
    if not cfg.enabled:
        raise ValueError("calibrate_out_scale needs an enabled TD-VMM config")
    noisy = cfg.noise and key is not None
    plan = plan_matmul(x.shape, w.shape, cfg, noisy=noisy)
    qx = quant.encode_input(x, cfg.bits)
    qw = quant.program_weights(w, cfg.weight_bits, cfg.per_channel)
    if noisy:
        qw = quant.program_noise(qw, cfg.spec, key)
    from repro.kernels.tdvmm import ops
    acc = ops.codes_matmul(
        qx.view().reshape(plan.m, plan.k), qw.view(), plan.backend,
        code_dtype=plan.code_dtype)
    gain = _latch_gain(qx.levels, qw.levels, plan.k)
    z_max = jnp.max(jnp.abs(acc.astype(jnp.float32) * gain), initial=0.0)
    return max(float(z_max), 1e-9)


def init_linear(
    key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None
) -> jax.Array:
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


class TDVMMLinear:
    """Functional linear layer: params = {'w': (d_in,d_out) [, 'b': (d_out,)]}"""

    @staticmethod
    def init(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
        p = {"w": init_linear(key, d_in, d_out, dtype)}
        if bias:
            p["b"] = jnp.zeros((d_out,), dtype)
        return p

    @staticmethod
    def apply(params, x, cfg: TDVMMLayerConfig, key=None):
        y = td_matmul(x, params["w"], cfg, key)
        if "b" in params:
            y = y + params["b"]
        return y

    @staticmethod
    def calibrate(params, x, cfg: TDVMMLayerConfig,
                  key=None) -> TDVMMLayerConfig:
        """Capture the readout window on a representative batch and return a
        config whose ``out_scale`` pins it (serving-path calibration cache).
        Pass ``key`` on noisy configs so the window covers the perturbed
        currents the serving path will actually integrate."""
        return cfg.replace(
            out_scale=calibrate_out_scale(x, params["w"], cfg, key))
