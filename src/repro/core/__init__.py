"""Core time-domain VMM library (the paper's contribution)."""
from repro.core.constants import TDVMMSpec
from repro.core.layers import TDVMMLayerConfig, TDVMMLinear, td_matmul

__all__ = ["TDVMMSpec", "TDVMMLayerConfig", "TDVMMLinear", "td_matmul"]
