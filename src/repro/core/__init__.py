"""Core time-domain VMM library (the paper's contribution).

Layer objects (``TDVMMLayerConfig``, ``TDVMMLinear``, ``td_matmul``) are
re-exported lazily (PEP 562): ``repro.core.layers`` imports
``repro.configs.base`` for the config types, and ``repro.configs.base`` in
turn imports ``repro.core.constants`` for ``TDVMMSpec`` — eager re-export
here would close that loop into a circular import.
"""
from repro.core.constants import TDVMMSpec

__all__ = ["TDVMMSpec", "TDVMMLayerConfig", "TDVMMLinear", "td_matmul"]

_LAZY = {
    "TDVMMLayerConfig": "repro.core.layers",
    "TDVMMLinear": "repro.core.layers",
    "td_matmul": "repro.core.layers",
}


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(target), name)
