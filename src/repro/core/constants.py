"""Physical and design constants from the paper (55 nm ESF3 NOR-flash process).

All values are taken from Bavandpour, Mahmoodi & Strukov, "Energy-Efficient
Time-Domain Vector-by-Matrix Multiplier for Neurocomputing and Beyond" (2017),
sections 3-4, unless marked [fitted] (behavioral-model constants fitted to the
paper's reported anchor numbers, see core/energy.py and core/nonideal.py).
"""
from __future__ import annotations

import dataclasses

# --- Timing (section 4.2) ---------------------------------------------------
T0_S = 0.5e-9           # per-bit half-window: 2*T0 <= 1 ns  => T0 = 0.5 ns
TAU_RESET_S = 2.0e-9    # output-capacitor pre-charge time (pipelining period 2T+tau)
TAU_F_S = 0.2e-9        # S-R latch + rectify-linear propagation delay (negligible vs T)

# --- Voltages (section 4.1) -------------------------------------------------
V_RESET = 0.7           # pre-charged drain-line voltage [V]
DELTA_VD = 0.2          # drain-line swing V_RESET - V_TH [V]
V_TH_LATCH = V_RESET - DELTA_VD   # S-R latch switching threshold [V]
V_CG = 1.2              # control-gate logic voltage [V]
V_SG_OPT = 0.8          # select-gate optimum (Fig. 4a) [V]
V_T_THERMAL = 0.0258    # thermal voltage at 300 K [V]
VTH_MISMATCH_RMS = 0.020  # S-R latch V_TH mismatch, Monte-Carlo (section 4.1) [V]

# --- Currents (section 4.1, Fig. 4) ------------------------------------------
I_MAX_OPT = 1.0e-6      # optimal max drain current ~1 uA (Fig. 4a)
DIBL_ERROR_AT_OPT = 0.02  # relative output error < 2% at optimum => >=5..6 bit

# --- Capacitances (sections 3.2, 4.2) ----------------------------------------
C_PER_INPUT = 0.04e-12  # conservative external cap per input: C ~= 200*C_drain [F]
C_DRAIN_PER_INPUT = C_PER_INPUT / 200.0

# --- Energy anchors from the paper (section 4.2 / Fig. 5) --------------------
# 6-bit digital-input/digital-output VMM, conservative design.
E_TOTAL_N10_J = 5.44e-12       # total energy for a 10x10 VMM window
TOPS_PER_J_N10 = 38.6e12 / 1e12   # 38.6 TOps/J
TOPS_PER_J_N100 = 120.0        # ~120 TOps/J
TOPS_PER_J_N1000 = 150.0       # ~150 TOps/J
STATIC_FRACTION_N10 = 0.65     # static energy ~65% of total at N=10

# --- Area anchors (section 4.2, Fig. 3/5b) ------------------------------------
AREA_CAP_FRACTION_LARGE_N = 0.75   # external caps ~75% of area for N > 200
AREA_MEM_FRACTION_LARGE_N = 0.25   # memory array ~25%
# [fitted] 55nm ESF3 supercell (2 FG cells sharing EG/SG): ~0.4 um^2 each;
# a four-quadrant weight needs 4 cells = 2 supercells.
A_SUPERCELL_UM2 = 0.40
# [fitted] MOSCAP density in 55 nm: ~6 fF/um^2 => 0.04 pF => ~6.7 um^2/input.
MOSCAP_F_PER_UM2 = 6.0e-15

# --- Default computing precision ---------------------------------------------
DEFAULT_BITS = 6        # DIBL-limited precision ceiling (abstract, section 4.1)

# --- TPU v5e roofline constants (task spec; used by launch/roofline.py) -------
TPU_PEAK_FLOPS_BF16 = 197e12     # per chip
TPU_HBM_BW = 819e9               # bytes/s per chip
TPU_ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass(frozen=True)
class TDVMMSpec:
    """Operating point of a time-domain VMM tile.

    The ideal math only needs (bits, w_max); the physical constants feed the
    non-ideality and energy models.
    """
    bits: int = DEFAULT_BITS           # input/output time-code precision p
    weight_bits: int = 6               # effective weight programming precision
    w_max: float = 1.0                 # weight magnitude bound
    i_max: float = I_MAX_OPT           # max current per source [A]
    v_sg: float = V_SG_OPT             # select-gate bias [V]
    delta_vd: float = DELTA_VD         # drain swing [V]
    t0_s: float = T0_S                 # half-window per bit
    c_per_input_f: float = C_PER_INPUT

    @property
    def t_window_s(self) -> float:
        """T: the input window length for p-bit precision."""
        return self.t0_s * (2 ** self.bits)

    @property
    def latency_s(self) -> float:
        """2T + tau_reset: pipelined VMM period (section 4.2)."""
        return 2.0 * self.t_window_s + TAU_RESET_S

    def c_total_f(self, n: int) -> float:
        """Total output-line capacitance for an N-input column."""
        return self.c_per_input_f * n

    def v_th_charge(self, n: int) -> float:
        """K = C*V_TH: the charge threshold for an N-input column [C].

        Defined via Eq. 5 so that I_max = C*V_TH / (N*T) exactly.
        """
        return n * self.i_max * self.t_window_s
