"""Process-global mesh context.

The model code is mesh-agnostic: it asks this module for the active mesh and
the (dp_axes, tp_axis) names.  Single-device tests run with no mesh — model
code then skips shard_map/collectives and uses the identical local math.
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None
_DP_AXES: tuple[str, ...] = ()
_TP_AXIS: Optional[str] = None


def set_mesh(mesh: Optional[Mesh], dp_axes: tuple[str, ...] = (), tp_axis: Optional[str] = None):
    global _MESH, _DP_AXES, _TP_AXIS
    _MESH = mesh
    _DP_AXES = dp_axes
    _TP_AXIS = tp_axis


def get_mesh() -> Optional[Mesh]:
    return _MESH


def dp_axes() -> tuple[str, ...]:
    return _DP_AXES


def tp_axis() -> Optional[str]:
    return _TP_AXIS


class use_mesh:
    """Context manager for tests."""

    def __init__(self, mesh, dp_axes=(), tp_axis=None):
        self.new = (mesh, dp_axes, tp_axis)

    def __enter__(self):
        self.old = (_MESH, _DP_AXES, _TP_AXIS)
        set_mesh(*self.new)

    def __exit__(self, *a):
        set_mesh(*self.old)
