"""Parameter / state / batch sharding rules (GSPMD logical-axis mapping).

Strategy (MaxText-style 2D/3D hybrid):
  * batch            -> all DP axes ('pod','data')
  * FSDP (ZeRO-3)    -> params' non-TP matrix dim sharded over the DP axes
  * TP               -> heads / ffn-hidden / vocab dim over 'model'
  * MoE expert banks -> impl 'ep': expert dim over DP axes; hidden over 'model'
                        impl 'local': replicated expert dim, FSDP d, TP hidden

Rules are written against the TRAILING dims of each weight; scanned stacks
(leading n_layers dim) get None padded on the left automatically, so the same
rule covers stacked and unstacked instances.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import axis_info


def _rules(fsdp, tp, ep):
    """(regex over '/'-joined path) -> trailing-dims PartitionSpec entries."""
    return [
        # MoE expert banks (3D: E, d_in, d_out)
        (r"moe/experts/w_(up|gate)$", (ep, None, tp)),
        (r"moe/experts/w_down$", (ep, tp, None)),
        (r"moe/shared/w_(up|gate)$", (None, fsdp, tp)),
        (r"moe/shared/w_down$", (None, tp, fsdp)),
        (r"moe/router/w$", (None, None)),
        # attention
        (r"attn/w[qkv]/w$", (fsdp, tp)),
        (r"attn/w[qkv]/b$", (tp,)),
        (r"attn/wo/w$", (tp, fsdp)),
        (r"attn/wo/b$", (None,)),
        # ffn
        (r"ffn/w_(up|gate)/w$", (fsdp, tp)),
        (r"ffn/w_down/w$", (tp, fsdp)),
        # ssm
        (r"ssm/w[zx]/w$", (fsdp, tp)),
        (r"ssm/w[BC]/w$", (fsdp, tp)),
        (r"ssm/wdt/w$", (fsdp, tp)),
        (r"ssm/wo/w$", (tp, fsdp)),
        (r"ssm/conv_w$", (None, None, tp)),
        (r"ssm/conv_b$", (tp,)),
        (r"ssm/(A_log|D|dt_bias)$", (None,)),
        # embeddings / head / fuse
        (r"embed/table$", (tp, fsdp)),
        (r"head/w$", (fsdp, tp)),
        (r"fuse/w$", (fsdp, tp)),
        # norms and everything 1D
        (r"(scale|b)$", (None,)),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh,
                dp_axes: tuple[str, ...] | None = None,
                layer_axis: str | None = None,
                ep_axes: tuple[str, ...] | None = None):
    """PartitionSpec pytree matching the params pytree.

    dp_axes: override the FSDP axes (pipeline parallelism uses 'pod' as the
    stage axis, so FSDP shrinks to ('data',); the serving engine passes ()
    to replicate weights over DP — no ZeRO-3 gathers in the step).
    layer_axis: if given, scanned-stack leaves (leading n_layers dim) get this
    mesh axis on dim 0 — the PP stage layout.
    ep_axes: override the expert-bank axes independently of FSDP (serving
    keeps dense weights DP-replicated but still shards expert tables over
    the DP axes under ``moe.impl='ep'``)."""
    info = axis_info(mesh)
    fsdp = (info["dp_axes"] if dp_axes is None else dp_axes) or None
    tp = info["tp_axis"]
    ep_base = fsdp if ep_axes is None else (ep_axes or None)
    ep = ep_base if (cfg.moe is not None and cfg.moe.impl == "ep") else None
    rules = _rules(fsdp, tp, ep)

    def spec_for(path, leaf):
        s = _path_str(path)
        for pat, trailing in rules:
            if re.search(pat, s):
                nd = len(leaf.shape)
                if len(trailing) > nd:   # unstacked smaller leaf (e.g. scalars)
                    trailing = trailing[-nd:] if nd else ()
                pad = list((None,) * (nd - len(trailing)))
                if layer_axis and pad and "/seg" in s:
                    pad[0] = layer_axis   # stage dim over 'pod' (PP layout)
                return P(*(tuple(pad) + tuple(trailing)))
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def opt_state_specs(opt_shape: Any, p_specs: Any):
    """Optimizer state shares its params' sharding; adafactor's factored
    moments drop the corresponding dim of the param spec."""
    import jax.tree_util as jtu

    p_leaves = {_path_str(p): s for p, s in
                jtu.tree_flatten_with_path(p_specs)[0]}

    def spec_for(path, leaf):
        s = _path_str(path)
        # step counter / scalars
        if not leaf.shape:
            return P()
        # path looks like 'inner/m/<param path>' or 'inner/<param path>/vr' etc.
        m = re.match(r"inner/(m|v)/(.*)$", s)
        if m and m.group(2) in p_leaves:
            return p_leaves[m.group(2)]
        m = re.match(r"inner/(.*)/(m|vr|vc|v)$", s)
        if m and m.group(1) in p_leaves:
            base = tuple(p_leaves[m.group(1)])
            kind = m.group(2)
            if kind in ("m", "v"):
                return P(*base)
            if kind == "vr":
                return P(*base[:-1])
            if kind == "vc":
                return P(*(base[:-2] + base[-1:]))
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec_for, opt_shape)


def _dp_size(mesh: Mesh, dp) -> int:
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str, global_batch: int):
    dp = axis_info(mesh)["dp_axes"]
    if global_batch % _dp_size(mesh, dp) != 0:
        dp = None   # e.g. long_500k's batch=1: replicate batch, shard the cache
    if cfg.input_mode == "tokens":
        inp = P(dp, None)
    else:
        inp = P(dp, None, None)
    if kind in ("decode", "prefill"):
        return {"inputs": inp}
    return {"inputs": inp, "targets": P(dp, None)}


def cache_specs(cache_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """KV caches: batch over DP and kv-heads over TP when divisible; falls back
    to sequence-sharding (SP) the cache / head_dim-sharding otherwise (e.g.
    long_500k's batch=1, or kv=8 on a 16-wide model axis)."""
    info = axis_info(mesh)
    dp, tp = info["dp_axes"], info["tp_axis"]
    dpn = _dp_size(mesh, dp)
    tpn = mesh.shape[tp] if tp else 1

    def spec_for(path, leaf):
        s = _path_str(path)
        nd = len(leaf.shape)
        if s.endswith("/pos") or nd <= 1:
            return P(*((None,) * nd))
        if re.search(r"/(k|v)$", s):          # (L, B, S, KV, HD)
            L, B, S, KV, HD = leaf.shape
            b_ax = dp if B % dpn == 0 else None
            s_ax = dp if (b_ax is None and S % dpn == 0) else None
            kv_ax = tp if KV % tpn == 0 else None
            hd_ax = tp if (kv_ax is None and HD % tpn == 0) else None
            return P(None, b_ax, s_ax, kv_ax, hd_ax)
        if re.search(r"/(k_scale|v_scale)$", s):   # (L, B, S, KV)
            L, B, S, KV = leaf.shape
            b_ax = dp if B % dpn == 0 else None
            s_ax = dp if (b_ax is None and S % dpn == 0) else None
            return P(None, b_ax, s_ax, tp if KV % tpn == 0 else None)
        if s.endswith("/conv"):               # (L, B, W, C)
            L, B, W, C = leaf.shape
            b_ax = dp if B % dpn == 0 else None
            return P(None, b_ax, None, tp if C % tpn == 0 else None)
        if s.endswith("/state"):              # (L, B, H, P, S)
            L, B, H, Pp, S = leaf.shape
            b_ax = dp if B % dpn == 0 else None
            return P(None, b_ax, tp if H % tpn == 0 else None, None, None)
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def paged_specs(cache_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """Paged KV pools: head dims over TP, the page pool itself replicated.

    Paged leaves are (L, pages, page_size, KV, HD) — the leading ``pages``
    dim is a global pool indexed through host-built block tables, so it must
    NOT be sharded (every device gathers arbitrary page ids; the DP slot-pool
    dimension lives in the *block tables*, not the pool).  kv-heads go over
    TP when divisible, else head_dim — same fallback as ``cache_specs``.
    Per-position int8 KV scales (L, pages, page_size, KV) follow their pool.
    """
    info = axis_info(mesh)
    tp = info["tp_axis"]
    tpn = mesh.shape[tp] if tp else 1

    def spec_for(path, leaf):
        s = _path_str(path)
        nd = len(leaf.shape)
        if re.search(r"/(k|v)$", s):          # (L, pages, ps, KV, HD)
            L, PG, PS, KV, HD = leaf.shape
            kv_ax = tp if KV % tpn == 0 else None
            hd_ax = tp if (kv_ax is None and HD % tpn == 0) else None
            return P(None, None, None, kv_ax, hd_ax)
        if re.search(r"/(k_scale|v_scale)$", s):   # (L, pages, ps, KV)
            L, PG, PS, KV = leaf.shape
            return P(None, None, None, tp if KV % tpn == 0 else None)
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def slot_specs(mesh: Mesh, kind: str):
    """Engine step-batch layouts for the DP slot-pool dimension.

    decode: batch rows ARE the slots, ordered (dp_rank, local_slot), so the
    leading dim shards over DP — inputs/block_tables (B, ·), pos/active (B,).
    prefill: one slot per step (batch 1) — fully replicated.
    """
    dp = axis_info(mesh)["dp_axes"] or None
    if kind == "prefill":
        return {"inputs": P(None, None), "block_row": P(None),
                "offset": P(), "valid": P()}
    if kind != "decode":
        raise ValueError(f"unknown engine step kind {kind!r}")
    return {"inputs": P(dp, None), "block_tables": P(dp, None),
            "pos": P(dp), "active": P(dp)}


def to_named(spec_tree: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sds_with_sharding(shape_tree: Any, sharding_tree: Any):
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        shape_tree, sharding_tree)
