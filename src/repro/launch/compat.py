"""Version-compat shims for JAX APIs that moved between releases.

``jax.shard_map`` (with ``check_vma`` / ``axis_names``) only exists in newer
releases; jax 0.4.x ships it as ``jax.experimental.shard_map.shard_map`` with
``check_rep`` and the complementary ``auto`` axis set.  All shard_map call
sites (models/common.py, models/moe.py, launch/pipeline.py) go through this
wrapper so the repo runs on both.
"""
from __future__ import annotations

import inspect
import logging

import jax

_logger = logging.getLogger(__name__)
_FALLBACK_WARNED: set = set()


def supports_partial_auto() -> bool:
    """True when this jax has stable partial-auto shard_map (``axis_names``).

    Probed from the signature rather than a version compare: the argument was
    renamed twice (``auto`` -> ``axis_names``) and only the keyword-stable
    form is safe to target.  Old jax's ``auto=`` variant is excluded on
    purpose — see the fallback note in :func:`shard_map`.
    """
    if not hasattr(jax, "shard_map"):
        return False
    try:
        params = inspect.signature(jax.shard_map).parameters
    except (TypeError, ValueError):  # pragma: no cover - C-accelerated stub
        return False
    return "axis_names" in params


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """New-style shard_map signature, lowered to whatever this jax has.

    ``axis_names`` is the set of *manual* mesh axes (None = all manual); on
    old jax ``check_vma`` maps to ``check_rep``.  Old jax's partial-auto mode
    (``auto=...``) is unreliable — XLA dies on a fatal IsManualSubgroup check
    when collectives mix with auto axes — so when ``axis_names`` asks for
    partial-manual we fall back to fully-manual there: numerically identical
    (unmentioned axes are replicated), it only forgoes GSPMD sharding of the
    per-shard body over the would-be-auto axes.
    """
    if supports_partial_auto():
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    if axis_names is not None:
        # One-time log (not warnings.warn: repeated trace-time hits would
        # spam or get deduped into silence), mirroring the autotune-miss
        # pattern in kernels/tdvmm/ops.py.
        key = tuple(sorted(str(a) for a in axis_names))
        if key not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(key)
            _logger.warning(
                "jax %s lacks stable partial-auto shard_map (axis_names=%s); "
                "falling back to fully-manual mode. Numerically identical, "
                "but GSPMD won't auto-shard the per-shard body over the "
                "unmentioned axes.", jax.__version__, sorted(key))

    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
