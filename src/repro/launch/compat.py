"""Version-compat shims for JAX APIs that moved between releases.

``jax.shard_map`` (with ``check_vma`` / ``axis_names``) only exists in newer
releases; jax 0.4.x ships it as ``jax.experimental.shard_map.shard_map`` with
``check_rep`` and the complementary ``auto`` axis set.  All shard_map call
sites (models/common.py, models/moe.py, launch/pipeline.py) go through this
wrapper so the repo runs on both.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    """New-style shard_map signature, lowered to whatever this jax has.

    ``axis_names`` is the set of *manual* mesh axes (None = all manual); on
    old jax ``check_vma`` maps to ``check_rep``.  Old jax's partial-auto mode
    (``auto=...``) is unreliable — XLA dies on a fatal IsManualSubgroup check
    when collectives mix with auto axes — so when ``axis_names`` asks for
    partial-manual we fall back to fully-manual there: numerically identical
    (unmentioned axes are replicated), it only forgoes GSPMD sharding of the
    per-shard body over the would-be-auto axes.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
