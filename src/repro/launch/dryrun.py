import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before any jax import: jax locks the device count
#   on first backend init.  (REPRO_XLA_FLAGS lets the perf loop add flags.)

# Multi-pod dry-run: prove the distribution config is coherent without TPUs.
#
# For every (architecture x input shape x mesh) cell this lowers + compiles the
# real train/prefill/decode step with sharded ShapeDtypeStructs (no
# allocation), prints memory_analysis() (proves it fits) and cost_analysis()
# (FLOPs/bytes for the roofline), parses per-device collective bytes out of
# the optimized HLO, and writes a JSON artifact consumed by EXPERIMENTS.md.
#
# Usage:
#   python -m repro.launch.dryrun --arch yi-34b --shape train_4k
#   python -m repro.launch.dryrun --arch all --shape all            # single-pod
#   python -m repro.launch.dryrun --arch all --shape all --multi-pod

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, OptimizerConfig, RunConfig, get_config
from repro.launch import meshctx, roofline, sharding, steps
from repro.launch.mesh import axis_info, make_production_mesh
from repro.models import model
from repro.optim.optimizer import make_optimizer


def input_specs(cfg, shape, mesh):
    """ShapeDtypeStruct stand-ins for every model input: weak-type-correct,
    shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    if kind == "decode":
        s_in = 1
    else:
        s_in = s
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((b, s_in), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((b, s_in, cfg.d_model), jnp.bfloat16)
    batch = {"inputs": inputs}
    if kind == "train":
        batch["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs = sharding.batch_specs(cfg, mesh, kind, b)
    shardings = sharding.to_named(specs, mesh)
    return sharding.sds_with_sharding(batch, shardings)


def optimizer_for(cfg) -> OptimizerConfig:
    """Adafactor + bf16 moments for ~T-param models (see DESIGN.md §6.4)."""
    if cfg.param_count() > 4e11:
        return OptimizerConfig(name="adafactor", moment_dtype="bfloat16")
    return OptimizerConfig()


def apply_opt_level(cfg, level: int):
    """Perf-iteration config ladder (EXPERIMENTS.md §Perf).

    0: baseline (GSPMD-placed f32 TP all-reduces, all-pairs flash, cf=1.25)
    1: + explicit bf16 TP reductions via shard_map for attn-out / ffn-down
       (preferred_element_type alone was REFUTED: XLA:CPU legalizes dots to
       f32 regardless — see §Perf it.1)
    2: + block-skipping flash attention (causal/SWA tile pairs only, shared
       constant masks)
    3: + MoE capacity_factor 1.0
    """
    import dataclasses as dc
    import jax.numpy as jnp
    from repro.models import common as mc
    mc.set_matmul_out_dtype(jnp.bfloat16 if level >= 1 else None)
    mc.set_tp_explicit(level >= 1)
    from repro.models import attention as at
    at.FLASH_BLOCK_SKIP = level >= 2
    if level >= 3 and cfg.moe is not None:
        cfg = cfg.replace(moe=dc.replace(cfg.moe, capacity_factor=1.0))
    return cfg


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatch: int | None = None, donate: bool = True,
               opt_level: int = 0, tdvmm: bool = False,
               tdvmm_chained: bool = False):
    cfg = get_config(arch)
    if tdvmm:
        from repro.core.layers import TDVMMLayerConfig
        cfg = cfg.replace(tdvmm=TDVMMLayerConfig(
            enabled=True, bits=6, weight_bits=6,
            io_quantize=not tdvmm_chained))
    cfg = apply_opt_level(cfg, opt_level)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skipped",
                "reason": "pure full-attention arch; 524k dense KV cache is "
                          "out of scope per DESIGN.md §5"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    info = axis_info(mesh)
    meshctx.set_mesh(mesh, info["dp_axes"], info["tp_axis"])
    try:
        return _lower_cell_inner(cfg, shape, mesh, info, microbatch, donate)
    finally:
        meshctx.set_mesh(None)


def _lower_cell_inner(cfg, shape, mesh, info, microbatch, donate):
    opt_cfg = optimizer_for(cfg)
    run = RunConfig(model=cfg, shape=shape, optimizer=opt_cfg)
    optimizer = make_optimizer(opt_cfg)
    t0 = time.time()

    params_shape = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = sharding.param_specs(params_shape, cfg, mesh)
    p_shardings = sharding.to_named(p_specs, mesh)

    batch_sds = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        dp_size = 1
        for a in info["dp_axes"]:
            dp_size *= mesh.shape[a]
        accum = microbatch if microbatch is not None else steps.grad_accum_steps(run, dp_size)
        opt_shape = jax.eval_shape(optimizer.init, params_shape)
        o_specs = sharding.opt_state_specs(opt_shape, p_specs)
        state_shardings = steps.TrainState(p_shardings, sharding.to_named(o_specs, mesh))
        state_sds = sharding.sds_with_sharding(
            steps.TrainState(params_shape, opt_shape), state_shardings)
        step_fn = steps.make_train_step(cfg, run, optimizer, accum)
        jitted = jax.jit(step_fn, donate_argnums=(0,) if donate else (),
                         out_shardings=(state_shardings, None))
        with mesh:
            lowered = jitted.lower(state_sds, batch_sds)
    else:
        caches_shape = jax.eval_shape(
            lambda: model.init_caches(cfg, shape.global_batch, shape.seq_len))
        c_specs = sharding.cache_specs(caches_shape, cfg, mesh)
        c_shardings = sharding.to_named(c_specs, mesh)
        caches_sds = sharding.sds_with_sharding(caches_shape, c_shardings)
        if shape.kind == "prefill":
            step_fn = steps.make_prefill_step(cfg)
        else:
            step_fn = steps.make_decode_step(cfg)
        jitted = jax.jit(step_fn, donate_argnums=(2,) if donate else (),
                         out_shardings=(None, c_shardings))
        with mesh:
            lowered = jitted.lower(sharding.sds_with_sharding(params_shape, p_shardings),
                                   batch_sds, caches_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    stats = roofline.analyze_hlo(hlo)   # loop-aware static profile of the HLO
    coll = dict(stats.coll)
    coll["total"] = stats.coll_total

    chips = mesh.size
    terms = roofline.RooflineTerms(
        chips=chips,
        flops_per_device=stats.flops,
        bytes_per_device=stats.hbm_bytes,
        coll_bytes_per_device=stats.coll_total,
        model_flops=roofline.model_flops(cfg, shape),
    )

    def _mem_dict(m):
        if m is None:
            return {}
        keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes"]
        return {k: getattr(m, k, None) for k in keys}

    result = {
        "status": "ok",
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": list(mesh.shape.values()),
        "mesh_axes": list(mesh.axis_names),
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory_analysis": _mem_dict(mem),
        # xla:cpu cost_analysis counts while bodies once — kept only as a
        # cross-check against the loop-aware static profile in `roofline`.
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed", "transcendentals")},
        "collective_bytes": coll,
        "roofline": terms.as_dict(),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt-level", type=int, default=0,
                    help="perf-iteration ladder (see apply_opt_level)")
    ap.add_argument("--tdvmm", action="store_true",
                    help="enable 6-bit TD-VMM linears (paper technique)")
    ap.add_argument("--tdvmm-chained", action="store_true",
                    help="paper section 2.2 chaining: skip per-layer output "
                         "requantization (no DAC/ADC between chained tiles)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="perf it.9: int8 KV cache (decode bandwidth)")
    args = ap.parse_args()
    if args.kv_int8:
        from repro.models import attention as _at
        _at.set_kv_cache_int8(True)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"
                path = outdir / f"{tag}.json"
                if path.exists() and not args.force:
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    result = lower_cell(arch, shape, multi_pod, args.microbatch,
                                        opt_level=args.opt_level,
                                        tdvmm=args.tdvmm,
                                        tdvmm_chained=args.tdvmm_chained)
                except Exception as e:  # noqa: BLE001 — record and continue
                    result = {"status": "error", "arch": arch, "shape": shape,
                              "multi_pod": multi_pod, "error": str(e),
                              "traceback": traceback.format_exc()}
                    failures += 1
                path.write_text(json.dumps(result, indent=2))
                status = result["status"]
                extra = ""
                if status == "ok":
                    r = result["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" t=({r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                             f"{r['t_collective_s']:.3e})s"
                             f" compile={result['compile_s']}s")
                elif status == "error":
                    extra = " " + result["error"][:200]
                print(f"[{status}] {tag}{extra}  ({time.time()-t0:.0f}s)", flush=True)
    print(f"done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
