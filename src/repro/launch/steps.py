"""jit-able train / prefill / decode step factories.

train_step supports gradient-accumulation microbatching (scan over G
microbatches, fp32 grad accumulators) — the memory/throughput lever for the
big configs — and donates the train state.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model
from repro.optim.optimizer import Optimizer, OptState


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(key, cfg: ModelConfig, optimizer: Optimizer) -> TrainState:
    params = model.init_params(key, cfg)
    return TrainState(params=params, opt=optimizer.init(params))


def grad_accum_steps(run: RunConfig, dp_size: int) -> int:
    """How many microbatches per step."""
    shape = run.shape
    if shape.kind != "train":
        return 1
    per_shard = max(shape.global_batch // max(dp_size, 1), 1)
    mb = shape.microbatch_per_shard or _auto_microbatch(run.model, shape.seq_len)
    mb = min(mb, per_shard)
    return max(per_shard // mb, 1)


def _auto_microbatch(cfg: ModelConfig, seq_len: int) -> int:
    """Target ~8k tokens per shard per microbatch."""
    return max(8192 // seq_len, 1)


def make_train_step(cfg: ModelConfig, run: RunConfig, optimizer: Optimizer,
                    accum: int = 1):
    def loss(params, batch):
        return model.loss_fn(params, batch, cfg)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        if accum <= 1:
            (_, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        else:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "lb_loss": jnp.zeros((), jnp.float32),
                  "z_loss": jnp.zeros((), jnp.float32),
                  "tokens": jnp.zeros((), jnp.float32)}

            def mb_step(carry, mb):
                gsum, msum = carry
                (_, m), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                msum = {k: msum[k] + m[k] for k in msum}
                return (gsum, msum), None

            (grads, msum), _ = jax.lax.scan(mb_step, (g0, m0), mb_batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = {k: v / accum for k, v in msum.items()}
            metrics["tokens"] = msum["tokens"]

        new_params, new_opt, opt_metrics = optimizer.update(grads, state.opt, params)
        metrics.update(opt_metrics)
        metrics["step"] = state.opt.step
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch, caches):
        return model.prefill_step(params, batch, caches, cfg)
    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, batch, caches):
        return model.decode_step(params, batch, caches, cfg)
    return decode
