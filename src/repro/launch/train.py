"""Training driver: config -> mesh -> sharded state -> fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --shape train_4k --steps 200 --smoke   # reduced config, CPU-runnable

Features exercised here (the production path in miniature):
  * sharded init + optimizer state (FSDP+TP specs from launch/sharding.py)
  * gradient-accumulation microbatching
  * deterministic resumable data pipeline
  * atomic checkpoint/restore with auto-resume, keep-k, async save
  * preemption guard (SIGTERM -> save + clean exit), step retry,
    straggler monitor, heartbeat
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import SHAPES, OptimizerConfig, RunConfig, get_config, smoke
from repro.data.pipeline import DataConfig, make_pipeline
from repro.launch import meshctx, sharding, steps
from repro.launch.mesh import axis_info
from repro.models import model
from repro.optim.optimizer import make_optimizer
from repro.runtime import fault


def build(run: RunConfig, mesh=None, accum: int | None = None):
    """Returns (train_step_jit, state, batch_fn)."""
    cfg = run.model
    optimizer = make_optimizer(run.optimizer)
    dp_size = 1
    if mesh is not None:
        info = axis_info(mesh)
        meshctx.set_mesh(mesh, info["dp_axes"], info["tp_axis"])
        for a in info["dp_axes"]:
            dp_size *= mesh.shape[a]
    if accum is None:
        accum = steps.grad_accum_steps(run, dp_size)
    step_fn = steps.make_train_step(cfg, run, optimizer, accum)

    key = jax.random.PRNGKey(run.seed)
    if mesh is not None:
        params_shape = jax.eval_shape(lambda: model.init_params(key, cfg))
        p_specs = sharding.param_specs(params_shape, cfg, mesh)
        p_shardings = sharding.to_named(p_specs, mesh)
        opt_shape = jax.eval_shape(
            lambda p: optimizer.init(p), params_shape)
        o_specs = sharding.opt_state_specs(opt_shape, p_specs)
        state_shardings = steps.TrainState(
            p_shardings, sharding.to_named(o_specs, mesh))
        with mesh:
            init_fn = jax.jit(
                lambda k: steps.init_train_state(k, cfg, optimizer),
                out_shardings=state_shardings)
            state = init_fn(key)
            step_jit = jax.jit(step_fn, donate_argnums=(0,),
                               out_shardings=(state_shardings, None))
    else:
        state = steps.init_train_state(key, cfg, optimizer)
        step_jit = jax.jit(step_fn, donate_argnums=(0,))
    return step_jit, state, accum


def train_loop(run: RunConfig, total_steps: int, mesh=None,
               accum: int | None = None, log_every: int = 10) -> dict:
    cfg = run.model
    step_jit, state, accum = build(run, mesh, accum)
    pipe = make_pipeline(cfg, run.shape, DataConfig(seed=run.seed))

    # --- auto-resume -------------------------------------------------------
    start_step = 0
    resumed = ckpt.latest_step(run.checkpoint_dir)
    if resumed is not None:
        state, start_step = ckpt.restore(state, run.checkpoint_dir)
        print(f"[resume] from step {start_step}")

    guard = fault.PreemptionGuard().install()
    monitor = fault.StragglerMonitor()
    hb = fault.Heartbeat(f"{run.checkpoint_dir}/heartbeat.json", every_s=10)
    history = []
    t_start = time.time()

    step = start_step
    while step < total_steps:
        batch = pipe.batch_at(step)
        t0 = time.time()
        state, metrics = fault.retry_step(step_jit, state, batch)
        dt = time.time() - t0
        monitor.record(step, dt)
        hb.beat(step)
        if step % log_every == 0 or step == total_steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m.update(step=step, dt=round(dt, 3))
            history.append(m)
            print(f"[train] step={step} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} dt={dt:.2f}s", flush=True)
        step += 1
        if guard.requested:
            print("[preempt] SIGTERM received — checkpointing and exiting")
            ckpt.save(state, run.checkpoint_dir, step, keep=run.keep_checkpoints)
            guard.uninstall()
            return {"history": history, "preempted": True, "step": step}
        if step % run.checkpoint_every == 0:
            ckpt.save(state, run.checkpoint_dir, step,
                      keep=run.keep_checkpoints, blocking=False)

    ckpt.save(state, run.checkpoint_dir, step, keep=run.keep_checkpoints)
    guard.uninstall()
    return {
        "history": history,
        "preempted": False,
        "step": step,
        "total_s": time.time() - t_start,
        "stragglers": monitor.stragglers,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tdvmm", action="store_true",
                    help="run all linears through the TD-VMM layer (QAT)")
    ap.add_argument("--tdvmm-bits", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    if args.tdvmm:
        from repro.core.layers import TDVMMLayerConfig
        cfg = cfg.replace(tdvmm=TDVMMLayerConfig(
            enabled=True, bits=args.tdvmm_bits, weight_bits=args.tdvmm_bits))
    shape = SHAPES[args.shape]
    if args.batch or args.seq:
        import dataclasses
        shape = dataclasses.replace(
            shape,
            global_batch=args.batch or shape.global_batch,
            seq_len=args.seq or shape.seq_len)
    run = RunConfig(model=cfg, shape=shape,
                    optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps),
                    checkpoint_dir=args.ckpt_dir,
                    checkpoint_every=args.ckpt_every)
    out = train_loop(run, args.steps)
    print(f"[done] steps={out['step']} loss "
          f"{out['history'][0]['loss']:.3f} -> {out['history'][-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
