"""Roofline-term extraction: a static profiler over post-SPMD optimized HLO.

Three terms (seconds) per (arch x shape x mesh), TPU v5e constants:

    compute    = FLOPs_per_device   / 197e12
    memory     = HBM_bytes_per_dev  / 819e9
    collective = wire_bytes_per_dev / (50e9 * links)

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies ONCE
(not x trip count), so it undercounts scanned-layer models ~n_layers-fold.
Instead we parse the optimized HLO text ourselves:

  * computations are split at column-0 '%name (...) -> ... {' blocks;
  * while-loop trip counts come from backend_config known_trip_count (with the
    loop-condition constant as fallback), multipliers propagate down the call
    graph (scan-over-layers x scan-over-microbatches nest correctly);
  * FLOPs: every `dot` op contributes 2 * prod(result_dims) * contract_size,
    with operand shapes resolved through a per-computation symbol table;
    `convolution` contributes 2 * prod(result) * window / groups;
  * HBM bytes: post-fusion, each top-level instruction is ~one kernel; we sum
    result + operand bytes for every real instruction (bitcast /
    get-tuple-element / tuple / parameter / constant are free);
  * collective wire bytes: result bytes x ring factor (all-reduce 2x, others
    1x) for all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute.

Known bias (documented in EXPERIMENTS.md): XLA:CPU upcasts bf16 dots/gathers
to f32, so byte counts are an upper bound (<= 2x) vs a real TPU lowering;
FLOP counts are dtype-independent.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core.constants import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OPNAME_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}/*\s]*?))\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_WHILE_PARTS_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")
_WINDOW_RE = re.compile(r"window=\{size=([\dx]+)")

_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
             "after-all", "add-dependency", "partition-id", "replica-id",
             "iota", "while", "conditional", "call"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Optional[tuple[str, list[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return m.group(1), dims


def _split_computations(hlo: str) -> dict[str, str]:
    comps: dict[str, str] = {}
    cur: Optional[str] = None
    lines: list[str] = []
    for line in hlo.splitlines():
        m = _COMP_START_RE.match(line)
        if m and cur is None:
            cur = m.group(2)
            if m.group(1):
                comps["__entry__"] = cur
            lines = [line]
            continue
        if cur is not None:
            lines.append(line)
            if line.rstrip() == "}":
                comps[cur] = "\n".join(lines)
                cur, lines = None, []
    if cur is not None:
        comps[cur] = "\n".join(lines)
    return comps


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _WIRE_FACTOR})

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def analyze_hlo(hlo: str) -> HLOStats:
    comps = _split_computations(hlo)
    entry = comps.pop("__entry__", None)

    # --- per-computation static facts -------------------------------------
    # symbol tables, per-computation local stats, call edges with trip counts.
    # Edge kinds: control-flow (while body/condition — instructions run and
    # touch HBM) vs inlined (fusion `calls=` / reduce `to_apply=` — their
    # instructions are fused into the caller's kernel: FLOPs are real, bytes
    # are NOT separate HBM traffic).
    local: dict[str, HLOStats] = {}
    edges: dict[str, list[tuple[str, int, bool]]] = {}

    for name, body in comps.items():
        syms: dict[str, str] = {}
        st = HLOStats()
        calls: list[tuple[str, int, bool]] = []
        for line in body.splitlines()[1:]:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            iname, rest = mi.group(1), mi.group(2)
            mo = _OPNAME_RE.match(rest)
            if not mo:
                continue
            type_str, op = mo.group(1), mo.group(2).lower()
            syms[iname] = type_str
            op_base = op.replace("-start", "").replace("-done", "")

            # call edges
            if op_base == "while":
                mp = _WHILE_PARTS_RE.search(rest)
                trip = 1
                mt = _TRIP_RE.search(rest)
                if mt:
                    trip = int(mt.group(1))
                elif mp and mp.group(1) in comps:
                    consts = [int(c) for c in _CONST_RE.findall(comps[mp.group(1)])]
                    trip = max(consts) if consts else 1
                if mp:
                    calls.append((mp.group(2), trip, False))      # body: control flow
                    calls.append((mp.group(1), trip + 1, False))  # condition
                continue
            for c in _CALLED_RE.findall(rest):
                if c in comps:
                    calls.append((c, 1, True))   # fusion/apply body: inlined

            if op_base in _FREE_OPS:
                continue

            result_bytes = _shape_bytes(type_str)
            # operand bytes via symbol table (dedup repeated uses per op)
            args = rest[rest.find("(") + 1:]
            operand_names = _OPERAND_RE.findall(args.split("metadata=")[0])
            operand_bytes = 0
            seen = set()
            for on in operand_names:
                if on in syms and on not in seen:
                    seen.add(on)
                    operand_bytes += _shape_bytes(syms[on])
            # in-place windowed ops: traffic is the slice, not the buffer
            if op_base == "dynamic-update-slice":
                upd = operand_names[1] if len(operand_names) > 1 else None
                ub = _shape_bytes(syms.get(upd, "")) if upd else 0
                st.hbm_bytes += 2.0 * ub
            elif op_base == "dynamic-slice":
                st.hbm_bytes += 2.0 * result_bytes
            elif op_base == "broadcast":
                st.hbm_bytes += result_bytes
            else:
                st.hbm_bytes += result_bytes + operand_bytes

            if op_base in _WIRE_FACTOR and "-done" not in op:
                st.coll[op_base] += result_bytes * _WIRE_FACTOR[op_base]
            elif op_base == "dot":
                fs = _first_shape_dims(type_str)
                mc = _CONTRACT_RE.search(rest)
                ops_list = _OPERAND_RE.findall(args.split("metadata=")[0])
                if fs and mc is not None and ops_list:
                    lhs = ops_list[0]
                    lhs_dims = []
                    if lhs in syms:
                        lf = _first_shape_dims(syms[lhs])
                        lhs_dims = lf[1] if lf else []
                    csize = 1
                    for ci in mc.group(1).split(","):
                        if ci.strip() and lhs_dims:
                            idx = int(ci)
                            if idx < len(lhs_dims):
                                csize *= lhs_dims[idx]
                    rprod = 1
                    for d in fs[1]:
                        rprod *= d
                    st.flops += 2.0 * rprod * csize
            elif op_base == "convolution":
                fs = _first_shape_dims(type_str)
                mw = _WINDOW_RE.search(rest)
                if fs and mw:
                    w = 1
                    for d in mw.group(1).split("x"):
                        w *= int(d)
                    rprod = 1
                    for d in fs[1]:
                        rprod *= d
                    st.flops += 2.0 * rprod * w
        local[name] = st
        edges[name] = calls

    # --- propagate multipliers from ENTRY down the call graph --------------
    # flops multiplier flows through every edge; the bytes multiplier is cut
    # at inlined (fusion/apply) edges — those instructions are part of the
    # caller's kernel and their HBM traffic is already counted at the call.
    mult_f: dict[str, float] = {}
    mult_b: dict[str, float] = {}

    def visit(name: str, mf: float, mb: float, depth: int = 0):
        if name not in local or depth > 64:
            return
        if mf <= mult_f.get(name, 0.0) and mb <= mult_b.get(name, 0.0):
            return
        mult_f[name] = max(mult_f.get(name, 0.0), mf)
        mult_b[name] = max(mult_b.get(name, 0.0), mb)
        for child, trip, inlined in edges.get(name, []):
            visit(child, mf * trip, 0.0 if inlined else mb * trip, depth + 1)

    if entry is None and comps:
        entry = next(iter(comps))
    if entry:
        visit(entry, 1.0, 1.0)

    total = HLOStats()
    for name, st in local.items():
        mf = mult_f.get(name, 0.0)
        mb = mult_b.get(name, 0.0)
        total.flops += mf * st.flops
        total.hbm_bytes += mb * st.hbm_bytes
        for k in total.coll:
            total.coll[k] += mf * st.coll[k]
    return total


def collective_bytes_per_device(hlo: str) -> dict[str, float]:
    st = analyze_hlo(hlo)
    out = dict(st.coll)
    out["total"] = st.coll_total
    return out


@dataclasses.dataclass
class RooflineTerms:
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float
    ici_links: int = 4          # v5e: 2D torus, 4 usable links/chip

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / TPU_PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / TPU_HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / (TPU_ICI_BW * self.ici_links)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips) / (t * TPU_PEAK_FLOPS_BF16)

    @property
    def flops_ratio(self) -> float:
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "step_time_lower_bound_s": self.step_time_lower_bound,
            "mfu_at_bound": self.mfu,
            "model_to_hlo_flops": self.flops_ratio,
        }


def model_flops(cfg, shape) -> float:
    """6*N_active*D (train) / 2*N_active*D per token (inference) — the
    standard decoder estimate used for the useful-FLOPs ratio."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch
