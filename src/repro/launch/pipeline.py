"""Pipeline parallelism over the pod axis (GPipe-style, inference pipeline).

The paper's section 3.1 chains VMM stages so that phase II of stage l IS
phase I of stage l+1, with a new sample admitted every period (Fig. 2d).  At
pod scale the same schedule maps onto the `pod` mesh axis: each pod owns a
contiguous half of the layer stack; microbatches stream through, and the
stage boundary is one collective_permute hop per microbatch — the only
cross-pod traffic (cheap on data-center interconnect vs FSDP gathers).

Implementation: `launch.compat.shard_map` with `axis_names={'pod'}` — the pod
axis is manual (explicit permutes), while `data`/`model` stay AUTO on new jax,
so the FSDP+TP sharding of each stage's layers is still GSPMD's job inside the
stage.  (jax 0.4.x runs the stage body fully manual instead — see compat.py.)

Layer stacks are (n_layers, ...) pytrees; we reshape to (n_stages,
layers_per_stage, ...) and shard dim 0 over `pod`.  Every pod executes the
same scanned-stage program on ITS slice; tokens enter at stage 0, exit at
stage n-1, and the GPipe schedule runs n_micro + n_stages - 1 ticks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import compat
from repro.models import common, transformer


def stage_split_params(block_params: dict, n_stages: int):
    """(L, ...) stacked seg params -> (n_stages, L/n_stages, ...)."""
    def split(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree.map(split, block_params)


def pp_forward(params, batch_tokens, cfg: ModelConfig, mesh, n_micro: int = 8):
    """Pipelined forward (logits) for a homogeneous dense stack.

    params: full model params (model.init_params layout, single 'seg0').
    batch_tokens: (B, S) int32, B % n_micro == 0.
    """
    n_stages = mesh.shape["pod"]
    staged = stage_split_params(params["blocks"]["seg0"], n_stages)

    def body(p_stage, x):
        """Run this pod's layers on a microbatch of hidden states."""
        def layer(h, lp):
            h2, _, _ = transformer.attn_ffn_block(
                lp, h, cfg, "train", None,
                jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32),
                                 h.shape[:2]))
            return h2, None
        x, _ = jax.lax.scan(layer, x, p_stage)
        return x

    def pipelined(staged_local, x_mb, stage_id):
        """staged_local: (1, L/stages, ...) this pod's layers;
        x_mb: (n_micro, mb, S, d) embedded microbatches (same on every pod —
        only stage 0's compute consumes them);
        stage_id: (1,) this pod's stage index, passed as pod-sharded data
        because lax.axis_index lowers to PartitionId, which GSPMD rejects
        inside a partially-auto shard_map on jax 0.4.x."""
        stage_params = jax.tree.map(lambda a: a[0], staged_local)
        idx = stage_id[0]
        n_ticks = n_micro + n_stages - 1

        def tick(carry, t):
            buf = carry                       # (mb, S, d) current stage input
            # stage 0 ingests microbatch t (older stages work on forwarded data)
            fresh = x_mb[jnp.minimum(t, n_micro - 1)]
            buf = jnp.where(idx == 0, jnp.where(t < n_micro, fresh, buf), buf)
            out = body(stage_params, buf)
            # forward to the next stage (last stage's permute wraps, ignored)
            nxt = jax.lax.ppermute(
                out, "pod", [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # emit: only the LAST stage's output at valid ticks is real
            emit = jnp.where(idx == n_stages - 1, out, jnp.zeros_like(out))
            return nxt, emit

        _, emitted = jax.lax.scan(tick, jnp.zeros_like(x_mb[0]), jnp.arange(n_ticks))
        # microbatch m exits the last stage at tick m + n_stages - 1
        outs = emitted[n_stages - 1:]
        # broadcast last stage's result to every pod so the head is replicated
        outs = jax.lax.psum(outs, "pod") / 1.0  # zeros elsewhere -> identity
        return outs

    # embed outside the pipeline (replicated over pod)
    x = params["embed"]["table"][batch_tokens]
    b, s, d = x.shape
    assert b % n_micro == 0
    x_mb = x.reshape(n_micro, b // n_micro, s, d)

    staged_specs = jax.tree.map(lambda _: P("pod"), staged)
    outs = compat.shard_map(
        pipelined, mesh=mesh,
        in_specs=(staged_specs, P(), P("pod")),
        out_specs=P(),
        axis_names={"pod"},
        check_vma=False,
    )(staged, x_mb, jnp.arange(n_stages, dtype=jnp.int32))

    h = outs.reshape(b, s, d)
    h = common.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ params["embed"]["table"].T
    return common.dense(params["head"], h, cfg.site_tdvmm("head"))
