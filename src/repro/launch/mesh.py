"""Production mesh definitions.

Kept as FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — required because tests run with 1 device while the
dry-run forces 512 host devices via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for unit tests (run under forced host device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str):
    """Build a (data, model) mesh from a CLI spec like ``"2x2"`` or ``"4x1"``.

    ``"none"`` / ``""`` return None (meshless engine).  The product must not
    exceed the visible device count — under CPU CI that count is raised via
    ``--xla_force_host_platform_device_count`` before jax is imported.
    """
    if not spec or spec.lower() == "none":
        return None
    try:
        data, model = (int(p) for p in spec.lower().split("x"))
    except ValueError as e:
        raise ValueError(f"mesh spec must look like 'DxT', got {spec!r}") from e
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    n = data * model
    if n > jax.device_count():
        raise ValueError(
            f"mesh {spec!r} needs {n} devices but only {jax.device_count()} "
            "are visible (set --xla_force_host_platform_device_count)")
    return make_test_mesh(data, model)


def axis_info(mesh) -> dict:
    """dp/tp axis naming convention for a mesh."""
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    return {"dp_axes": dp, "tp_axis": "model" if "model" in names else None}
