"""Production mesh definitions.

Kept as FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — required because tests run with 1 device while the
dry-run forces 512 host devices via XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for unit tests (run under forced host device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def axis_info(mesh) -> dict:
    """dp/tp axis naming convention for a mesh."""
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    return {"dp_axes": dp, "tp_axis": "model" if "model" in names else None}
