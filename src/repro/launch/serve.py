"""Serving driver: by default a thin CLI over the continuous-batching
engine (``runtime/engine.py`` — paged KV cache, slot scheduler, chunked
prefill, per-request energy accounting):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --slots 4 --chunk 16 --calibrate

``--static`` keeps the legacy uniform-batch fast path (``serve()`` below:
one fixed-shape prefill + a fixed number of decode steps for a uniform
batch, optionally mesh-sharded with cache donation) — still the right tool
for uniform offline batches and the only path for SSM/hybrid archs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke as smoke_cfg
from repro.launch import meshctx, sharding
from repro.launch.mesh import axis_info
from repro.models import model


def serve(cfg, batch: int, prompt_len: int, gen: int, mesh=None, seed: int = 0,
          calibrate: bool = False, calib=None, plan_report: bool = False):
    """Prefill + decode driver.

    ``calibrate=True`` runs the model-wide §3.1 readout-window pass
    (models.model.calibrate) on the prompt batch before jitting, then serves
    with every TD-VMM site's window pinned — no per-call max|z|, fused
    Pallas epilogue eligible.  Pass a restored ``CalibrationState`` as
    ``calib`` to skip the capture pass (e.g. from
    checkpoint.restore_calibration).  ``plan_report`` prints the resolved
    site table (which boundaries are digital vs time-chained).
    """
    key = jax.random.PRNGKey(seed)
    if plan_report:
        print("[serve] TD-VMM plan:")
        print(cfg.resolved_tdvmm_plan.describe())

    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
        step_in = {"inputs": prompts}
    else:
        step_in = {"inputs": jax.random.normal(
            key, (batch, prompt_len, cfg.d_model), jnp.float32)}

    if mesh is not None:
        info = axis_info(mesh)
        meshctx.set_mesh(mesh, info["dp_axes"], info["tp_axis"])
        params_shape = jax.eval_shape(lambda: model.init_params(key, cfg))
        p_specs = sharding.param_specs(params_shape, cfg, mesh)
        p_sh = sharding.to_named(p_specs, mesh)
        with mesh:
            params = jax.jit(lambda k: model.init_params(k, cfg),
                             out_shardings=p_sh)(key)
            caches_shape = jax.eval_shape(
                lambda: model.init_caches(cfg, batch, prompt_len + gen))
            c_specs = sharding.cache_specs(caches_shape, cfg, mesh)
            c_sh = sharding.to_named(c_specs, mesh)
            caches = jax.jit(lambda: model.init_caches(cfg, batch, prompt_len + gen),
                             out_shardings=c_sh)()
            if calibrate and calib is None:
                calib = model.calibrate(params, step_in, cfg,
                                        max_len=prompt_len + gen)
            prefill = jax.jit(
                lambda p, b, c: model.prefill_step(p, b, c, cfg, calib=calib),
                donate_argnums=(2,), out_shardings=(None, c_sh))
            decode = jax.jit(
                lambda p, b, c: model.decode_step(p, b, c, cfg, calib=calib),
                donate_argnums=(2,), out_shardings=(None, c_sh))
    else:
        params = model.init_params(key, cfg)
        caches = model.init_caches(cfg, batch, prompt_len + gen)
        if calibrate and calib is None:
            # One eager prefill with the collector installed; the captured
            # per-site windows are then closed over as jit-static settings.
            calib = model.calibrate(params, step_in, cfg,
                                    max_len=prompt_len + gen)
        prefill = jax.jit(
            lambda p, b, c: model.prefill_step(p, b, c, cfg, calib=calib),
            donate_argnums=(2,))
        decode = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c, cfg, calib=calib),
            donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, step_in, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        if cfg.input_mode == "tokens":
            nxt = {"inputs": tok}
        else:
            nxt = {"inputs": jax.random.normal(key, (batch, 1, cfg.d_model))}
        logits, caches = decode(params, nxt, caches)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out_tokens, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
        "calibration": calib,
    }


def _parse_alert_spec(spec: str):
    """One ``--alert-on`` value -> AlertRule.

    Format: ``metric:kind[:key=val[,key=val...]]``, e.g.
    ``step_latency_s:spike:k=6,abs_floor=0.05`` or
    ``fj_per_op:regression:baseline=57.1,tol=0.1``."""
    from repro.runtime.telemetry import AlertRule
    parts = spec.split(":", 2)
    if len(parts) < 2:
        raise SystemExit(f"--alert-on {spec!r}: want metric:kind[:k=v,...]")
    metric, kind = parts[0], parts[1]
    kwargs = {}
    if len(parts) == 3 and parts[2]:
        for kv in parts[2].split(","):
            k, _, v = kv.partition("=")
            if not _:
                raise SystemExit(f"--alert-on {spec!r}: bad param {kv!r}")
            kwargs[k] = int(v) if k in ("min_samples",) else float(v)
    try:
        return AlertRule(metric=metric, kind=kind, **kwargs)
    except (TypeError, ValueError) as e:
        raise SystemExit(f"--alert-on {spec!r}: {e}")


def _make_sink(args):
    """The telemetry MetricsSink for this run (None = telemetry off).

    Enabled by ``--metrics-jsonl`` and/or ``--alert-on``.  With no explicit
    rules a default step-latency spike detector is installed (median +
    6*MAD with a 50 ms absolute deadband — jit-compile steps on a cold
    engine will legitimately alert; warm traffic won't)."""
    from repro.runtime import telemetry as tele
    if not (args.metrics_jsonl or args.alert_on):
        return None
    rules = [_parse_alert_spec(s) for s in (args.alert_on or [])]
    if not rules:
        rules = [tele.AlertRule("step_latency_s", kind="spike", k=6.0,
                                abs_floor=0.05)]
    emitters = [tele.StdoutEmitter()]
    if args.metrics_jsonl:
        emitters.append(tele.JsonlEmitter(args.metrics_jsonl))
    return tele.MetricsSink(rules=rules, emitters=emitters)


def _fault_config(args, probe_batch=None, sink=None):
    """Assemble the engine FaultConfig from CLI flags (None = no wiring).

    A real PreemptionGuard with SIGTERM/SIGINT handlers is installed when a
    snapshot dir is given, so an actual eviction snapshots the in-flight
    state; ``--preempt-at``/``--fail-at``/``--drift-at``/``--slow-at``
    inject the same faults deterministically at a chosen engine step.  A
    telemetry ``sink`` threads into the straggler monitor and heartbeat so
    their events land in the metric series too."""
    from repro.runtime import fault
    from repro.runtime import faultinject as fi
    from repro.runtime.engine import DriftConfig, FaultConfig

    events = []
    if args.preempt_at is not None:
        events.append(fi.PreemptAt(args.preempt_at))
    if args.fail_at is not None:
        events.append(fi.FailStep(step=args.fail_at, kind=args.fail_kind,
                                  times=args.fail_times))
    if args.drift_at is not None:
        events.append(fi.DriftAt(args.drift_at, sigma=args.drift_sigma))
    if args.slow_at is not None:
        events.append(fi.SlowStep(args.slow_at, sleep_s=args.slow_sleep))
    drift = None
    if args.drift_check_every > 0 or args.clip_observe_every > 0:
        if probe_batch is None:
            raise SystemExit("--drift-check-every/--clip-observe-every "
                             "require --calibrate (the probe compares "
                             "against the pinned calibration windows)")
        drift = DriftConfig(probe_batch=probe_batch,
                            # observe-only wiring leaves the full check
                            # effectively off (clip alerts still stream)
                            check_every=args.drift_check_every or 10**9,
                            clip_threshold=args.drift_clip,
                            window_tol=args.drift_tol,
                            observe_every=args.clip_observe_every)
    hb = (fault.Heartbeat(args.heartbeat, args.heartbeat_every, sink=sink)
          if args.heartbeat else None)
    if not (events or drift or hb or args.snapshot_dir):
        return None
    guard = None
    if args.snapshot_dir:
        guard = fault.PreemptionGuard().install()
    return FaultConfig(
        guard=guard, snapshot_dir=args.snapshot_dir, retries=args.retries,
        injector=fi.FaultInjector(events) if events else None,
        drift=drift, heartbeat=hb,
        monitor=fault.StragglerMonitor(sink=sink))


def serve_engine(cfg, args, seed: int = 0):
    """Engine path: synthetic ragged trace -> continuous-batching run,
    optionally fault-wired (snapshot/resume, injection, drift probing)."""
    import numpy as np

    from repro.runtime.engine import Engine, EngineConfig, Request

    from repro.launch.mesh import parse_mesh

    mesh = parse_mesh(args.mesh)
    if mesh is not None:
        print(f"[serve] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}"
              f" over {mesh.size} devices")
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, cfg)
    calib = None
    calib_batch = None
    if args.calibrate:
        calib_batch = {"inputs": jax.random.randint(
            key, (min(args.slots, 4), args.prompt_len), 0, cfg.vocab_size)}
        calib = model.calibrate(params, calib_batch, cfg,
                                max_len=args.prompt_len + args.gen)
    if args.plan_report:
        print("[serve] TD-VMM plan:")
        print(cfg.resolved_tdvmm_plan.describe())

    sla = None
    if args.sla:
        from repro.runtime.sla import SlaConfig
        sla = SlaConfig(aging_steps=args.aging_steps)
    sink = _make_sink(args)
    tracer = None
    if args.trace_out:
        from repro.runtime.trace import Tracer
        tracer = Tracer()

    rng = np.random.default_rng(seed)
    lo, hi = max(1, args.prompt_len // 4), args.prompt_len + 1
    reqs = []
    arrival = 0
    for rid in range(args.requests):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in
                         rng.integers(0, cfg.vocab_size, rng.integers(lo, hi))),
            max_new_tokens=int(rng.integers(max(1, args.gen // 4), args.gen + 1)),
            arrival_step=arrival,
            # SLA fields are inert without --sla (defaults replay FIFO)
            priority=(rid % 3) if args.sla else 0,
            deadline_steps=args.deadline_steps,
            joule_budget=args.joule_budget))
        arrival += int(rng.integers(0, 3))
    # Block-table width (= per-slot attention span) sized to the workload,
    # not the pool: every decode step gathers max_pages_per_slot pages per
    # slot, so leaving it at num_pages would attend over mostly-trash keys.
    from repro.runtime.paged_cache import pages_for
    max_pages = min(args.num_pages,
                    pages_for(args.prompt_len + args.gen, args.page_size))
    ecfg = EngineConfig(slots=args.slots, page_size=args.page_size,
                        num_pages=args.num_pages, chunk=args.chunk,
                        max_pages_per_slot=max_pages)
    fc = _fault_config(args, probe_batch=calib_batch, sink=sink)
    if args.resume:
        # Resume a preempted run: the snapshot carries the full in-flight
        # state INCLUDING the pinned (possibly recalibrated) windows — build
        # the engine's calibration from them, then restore and continue.
        from repro.checkpoint import checkpoint
        from repro.core.calibration import CalibrationState

        if not args.snapshot_dir:
            raise SystemExit("--resume requires --snapshot-dir")
        flat, step = checkpoint.load_engine_snapshot(args.snapshot_dir)
        calib = CalibrationState(windows={
            k.split("/", 1)[1]: jnp.asarray(v) for k, v in flat.items()
            if k.startswith("windows/")})
        engine = Engine(cfg, params, ecfg, calib=calib, sla=sla, sink=sink,
                        mesh=mesh, tracer=tracer)
        engine.restore(flat)
        print(f"[serve] resumed from snapshot step {step} "
              f"({args.snapshot_dir})")
        rep = engine.resume(fc)
    else:
        engine = Engine(cfg, params, ecfg, calib=calib, sla=sla, sink=sink,
                        mesh=mesh, tracer=tracer)
        rep = engine.run(reqs, fc)
    if rep.preempted:
        print(f"[serve] PREEMPTED at step {rep.steps}; snapshot: "
              f"{rep.snapshot_path} (resume with --resume)")
    if rep.step_retries or rep.failed:
        print(f"[serve] faults: {rep.step_retries} step retries, "
              f"{rep.failed} requests failed")
    if rep.recalibrations or rep.drift_events:
        print(f"[serve] drift: {len(rep.drift_events)} events, "
              f"{rep.recalibrations} online recalibrations "
              f"(compiled steps still {rep.compiled_steps})")
    print(f"[serve] engine: {len(reqs)} requests, "
          f"{rep.generated_tokens} tokens in {rep.steps} steps "
          f"({rep.prefill_steps} chunk + {rep.decode_steps} decode, "
          f"{rep.generated_tokens / max(rep.wall_s, 1e-9):.1f} tok/s), "
          f"utilization {rep.utilization:.2f}, "
          f"KV high-water {rep.kv_high_water_bytes / 1024:.1f} KiB, "
          f"compiled steps = {rep.compiled_steps}")
    if rep.analog_ops:
        print(f"[serve] analog: {rep.analog_ops:.3g} Ops, "
              f"{rep.fj_per_op:.2f} fJ/Op, "
              f"{rep.tokens_per_joule:.3g} tok/J")
    if sla is not None:
        print(f"[serve] sla: {rep.rejected} rejected at admission, "
              f"{rep.over_budget} over budget, deadlines "
              f"{rep.deadline_hits} hit / {rep.deadline_misses} missed")
    if sink is not None:
        tel = rep.telemetry or {}
        print(f"[serve] telemetry: {tel.get('observations', 0)} samples, "
              f"{rep.alerts} alerts "
              f"({', '.join(f'{k}={v}' for k, v in sorted(tel.get('alerts_by_rule', {}).items())) or 'none'})")
        if args.metrics_jsonl:
            print(f"[serve] metrics streamed to {args.metrics_jsonl}")
        for em in sink.emitters:
            em.close()
    if tracer is not None:
        import json
        from pathlib import Path
        doc = tracer.chrome_trace()
        Path(args.trace_out).write_text(json.dumps(doc))
        summ = rep.trace_summary or {}
        pct = (summ.get("percentiles") or {}).get("total_us", {})
        print(f"[serve] trace: {len(doc['traceEvents'])} events over "
              f"{summ.get('ticks', 0)} ticks -> {args.trace_out} "
              f"(request total p50 {pct.get('p50', 0.0):.0f} us / "
              f"p95 {pct.get('p95', 0.0):.0f} us; open in Perfetto)")
    for r in rep.requests[:4]:
        print(f"[serve]   req {r['rid']}: {r['finish_reason']} "
              f"tokens={r['tokens'][:8]}")
    if args.report_json:
        import json
        from pathlib import Path
        Path(args.report_json).write_text(json.dumps(rep.to_json(), indent=1))
        print(f"[serve] report written to {args.report_json}")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--static", action="store_true",
                    help="legacy uniform-batch path (serve(); required for "
                         "SSM/hybrid archs)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="model-wide TD-VMM readout-window calibration pass "
                         "before serving (pins every site's ADC window)")
    ap.add_argument("--plan-report", action="store_true",
                    help="print the resolved TD-VMM site table")
    # engine knobs
    ap.add_argument("--requests", type=int, default=8,
                    help="engine path: synthetic ragged trace size")
    ap.add_argument("--mesh", default=None, metavar="DxT",
                    help="engine path: serve over a (data, model) mesh, e.g. "
                         "2x2 (needs D*T visible devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count). "
                         "DP multiplies the slot pool: total slots = D * "
                         "--slots")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=64)
    # fault tolerance & drift (engine path)
    ap.add_argument("--snapshot-dir", default=None,
                    help="preemption snapshots go here; also installs real "
                         "SIGTERM/SIGINT handlers")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest engine snapshot from "
                         "--snapshot-dir and continue the trace")
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="inject a preemption at this engine step")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a compiled-step failure at this step")
    ap.add_argument("--fail-kind", default="any",
                    choices=["prefill", "decode", "any"])
    ap.add_argument("--fail-times", type=int, default=1,
                    help="how many raises (<= --retries: transient; "
                         "--retries+1: persistent, one request fails)")
    ap.add_argument("--drift-at", type=int, default=None,
                    help="perturb device currents (FG tuning drift) at "
                         "this step")
    ap.add_argument("--drift-sigma", type=float, default=0.5)
    ap.add_argument("--slow-at", type=int, default=None,
                    help="inject a one-step straggler (inflated wall time) "
                         "at this engine step")
    ap.add_argument("--slow-sleep", type=float, default=0.25,
                    help="seconds the injected straggler step sleeps")
    # SLA scheduling & telemetry (engine path)
    ap.add_argument("--sla", action="store_true",
                    help="SLA admission/dispatch: priority-with-aging "
                         "(trace priorities cycle rid %% 3), deadline/joule "
                         "admission control, over-budget enforcement")
    ap.add_argument("--aging-steps", type=int, default=16,
                    help="queue-wait steps per priority level of aging")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request deadline (engine steps after arrival) "
                         "stamped on every trace request")
    ap.add_argument("--joule-budget", type=float, default=None,
                    help="per-request analog energy budget in joules "
                         "stamped on every trace request")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="stream per-tick metrics + alerts to this JSONL "
                         "file (enables the telemetry sink)")
    ap.add_argument("--alert-on", action="append", default=None,
                    metavar="METRIC:KIND[:K=V,...]",
                    help="telemetry alert rule, e.g. "
                         "step_latency_s:spike:k=6,abs_floor=0.05 or "
                         "fj_per_op:regression:baseline=57.1,tol=0.1 "
                         "(repeatable; enables the telemetry sink)")
    ap.add_argument("--retries", type=int, default=2,
                    help="retry budget per compiled step")
    ap.add_argument("--heartbeat", default=None,
                    help="liveness marker file path")
    ap.add_argument("--heartbeat-every", type=float, default=30.0)
    ap.add_argument("--drift-check-every", type=int, default=0,
                    help="probe for window drift every N engine steps "
                         "(0 = off; requires --calibrate)")
    ap.add_argument("--drift-tol", type=float, default=0.25,
                    help="max |log window ratio| before recalibrating")
    ap.add_argument("--drift-clip", type=float, default=0.01,
                    help="max readout clip rate before recalibrating")
    ap.add_argument("--clip-observe-every", type=int, default=0,
                    help="stream per-site readout clip rates into the "
                         "telemetry sink every N engine steps as "
                         "clip_rate.<site> series (0 = off; requires "
                         "--calibrate and analog sites, e.g. "
                         "--tdvmm 'ffn.*'; pair with --alert-on "
                         "'clip_rate.ffn.out:threshold:limit=0.01')")
    ap.add_argument("--tdvmm", default=None, metavar="PATTERN",
                    help="enable analog TD-VMM at the plan sites matching "
                         "PATTERN (e.g. 'ffn.*'); stock arch configs ship "
                         "all-digital, so clip_rate series and per-site "
                         "attribution need this (jnp backend: bit-exact "
                         "with pallas, no interpret-mode slowdown on CPU)")
    ap.add_argument("--trace-out", default=None,
                    help="engine path: write a Chrome-trace/Perfetto JSON "
                         "of the whole request lifecycle here (spans ride "
                         "engine snapshots, so a --resume run continues "
                         "the same trace)")
    ap.add_argument("--report-json", default=None,
                    help="engine path: write the full EngineReport here")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    if args.tdvmm:
        from repro.configs import TDVMMPlan, tdvmm_rule
        cfg = cfg.replace(tdvmm_plan=TDVMMPlan(rules=(
            tdvmm_rule(args.tdvmm, enabled=True, backend="jnp"),)))
    if args.kv_int8:
        from repro.models import attention
        attention.set_kv_cache_int8(True)
    if not args.static:
        serve_engine(cfg, args)
        return
    out = serve(cfg, args.batch, args.prompt_len, args.gen,
                calibrate=args.calibrate, plan_report=args.plan_report)
    print(f"[serve] {args.arch} batch={args.batch} prefill={out['prefill_s']:.2f}s "
          f"decode={out['decode_s']:.2f}s ({out['decode_tok_per_s']:.1f} tok/s)")
    if out["calibration"] is not None:
        print(f"[serve] calibrated sites: {out['calibration'].sites()}")
    print("[serve] sample:", out["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
