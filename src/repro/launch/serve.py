"""Sharded serving driver: mesh -> sharded params/caches -> prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 16 --gen 16

The production path in miniature: params and KV caches placed with the same
FSDP+TP/SP specs the dry-run proves out, steps jitted with cache donation,
tokens/s reported.  (The continuous-batching slot manager lives in
examples/serve_lm.py; this driver is the uniform-batch fast path.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke as smoke_cfg
from repro.launch import meshctx, sharding
from repro.launch.mesh import axis_info
from repro.models import model


def serve(cfg, batch: int, prompt_len: int, gen: int, mesh=None, seed: int = 0,
          calibrate: bool = False, calib=None, plan_report: bool = False):
    """Prefill + decode driver.

    ``calibrate=True`` runs the model-wide §3.1 readout-window pass
    (models.model.calibrate) on the prompt batch before jitting, then serves
    with every TD-VMM site's window pinned — no per-call max|z|, fused
    Pallas epilogue eligible.  Pass a restored ``CalibrationState`` as
    ``calib`` to skip the capture pass (e.g. from
    checkpoint.restore_calibration).  ``plan_report`` prints the resolved
    site table (which boundaries are digital vs time-chained).
    """
    key = jax.random.PRNGKey(seed)
    if plan_report:
        print("[serve] TD-VMM plan:")
        print(cfg.resolved_tdvmm_plan.describe())

    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
        step_in = {"inputs": prompts}
    else:
        step_in = {"inputs": jax.random.normal(
            key, (batch, prompt_len, cfg.d_model), jnp.float32)}

    if mesh is not None:
        info = axis_info(mesh)
        meshctx.set_mesh(mesh, info["dp_axes"], info["tp_axis"])
        params_shape = jax.eval_shape(lambda: model.init_params(key, cfg))
        p_specs = sharding.param_specs(params_shape, cfg, mesh)
        p_sh = sharding.to_named(p_specs, mesh)
        with mesh:
            params = jax.jit(lambda k: model.init_params(k, cfg),
                             out_shardings=p_sh)(key)
            caches_shape = jax.eval_shape(
                lambda: model.init_caches(cfg, batch, prompt_len + gen))
            c_specs = sharding.cache_specs(caches_shape, cfg, mesh)
            c_sh = sharding.to_named(c_specs, mesh)
            caches = jax.jit(lambda: model.init_caches(cfg, batch, prompt_len + gen),
                             out_shardings=c_sh)()
            if calibrate and calib is None:
                calib = model.calibrate(params, step_in, cfg,
                                        max_len=prompt_len + gen)
            prefill = jax.jit(
                lambda p, b, c: model.prefill_step(p, b, c, cfg, calib=calib),
                donate_argnums=(2,), out_shardings=(None, c_sh))
            decode = jax.jit(
                lambda p, b, c: model.decode_step(p, b, c, cfg, calib=calib),
                donate_argnums=(2,), out_shardings=(None, c_sh))
    else:
        params = model.init_params(key, cfg)
        caches = model.init_caches(cfg, batch, prompt_len + gen)
        if calibrate and calib is None:
            # One eager prefill with the collector installed; the captured
            # per-site windows are then closed over as jit-static settings.
            calib = model.calibrate(params, step_in, cfg,
                                    max_len=prompt_len + gen)
        prefill = jax.jit(
            lambda p, b, c: model.prefill_step(p, b, c, cfg, calib=calib),
            donate_argnums=(2,))
        decode = jax.jit(
            lambda p, b, c: model.decode_step(p, b, c, cfg, calib=calib),
            donate_argnums=(2,))

    t0 = time.time()
    logits, caches = prefill(params, step_in, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        if cfg.input_mode == "tokens":
            nxt = {"inputs": tok}
        else:
            nxt = {"inputs": jax.random.normal(key, (batch, 1, cfg.d_model))}
        logits, caches = decode(params, nxt, caches)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out_tokens, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
        "calibration": calib,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="model-wide TD-VMM readout-window calibration pass "
                         "before serving (pins every site's ADC window)")
    ap.add_argument("--plan-report", action="store_true",
                    help="print the resolved TD-VMM site table")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    if args.kv_int8:
        from repro.models import attention
        attention.set_kv_cache_int8(True)
    out = serve(cfg, args.batch, args.prompt_len, args.gen,
                calibrate=args.calibrate, plan_report=args.plan_report)
    print(f"[serve] {args.arch} batch={args.batch} prefill={out['prefill_s']:.2f}s "
          f"decode={out['decode_s']:.2f}s ({out['decode_tok_per_s']:.1f} tok/s)")
    if out["calibration"] is not None:
        print(f"[serve] calibrated sites: {out['calibration'].sites()}")
    print("[serve] sample:", out["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
