"""Quickstart: the time-domain VMM in six steps.

    PYTHONPATH=src python examples/quickstart.py

1. encode a vector as turn-on times,
2. program a weight matrix into current sources (Eq. 5-7),
3. integrate charge + fire latches (the event-driven simulation),
4. decode crossing times -> exact normalized dot products (Eq. 1),
5. drop the same multiplier into a JAX model as a quantized linear layer,
6. address a whole LM's analog matmuls with a site plan + calibration.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import (
    ModelConfig, TDVMMLayerConfig, TDVMMPlan, tdvmm_rule)
from repro.core import currents, encoding, tdcore
from repro.core.constants import TDVMMSpec
from repro.core.layers import td_matmul
from repro.models import model

spec = TDVMMSpec(bits=6)
print(f"operating point: p={spec.bits} bits, T={spec.t_window_s*1e9:.0f} ns, "
      f"I_max={spec.i_max*1e6:.1f} uA, period={spec.latency_s*1e9:.0f} ns")

# -- 1. time-encode an input vector ------------------------------------------
x = jnp.array([0.8, -0.3, 0.5, 0.0, -1.0, 0.25, 0.9, -0.6])
x_pos, x_neg = encoding.four_quadrant_split(x)
t_on = encoding.value_to_onset(x_pos, spec.t_window_s)
print("\ninputs       :", x)
print("onset times + wire (ns):", (t_on * 1e9).round(1))

# -- 2. program a signed weight matrix into four current-source arrays -------
key = jax.random.PRNGKey(0)
w = jax.random.uniform(key, (8, 4), minval=-1.0, maxval=1.0)
prog = currents.four_quadrant_program(w, spec.i_max, spec.w_max)
print("\ncurrents (uA), + wire, col 0:", (prog["pos"][:, 0] * 1e6).round(3))
print("bias current (uA), + wire   :", (prog["bias_pos"] * 1e6).round(3))

# -- 3+4. event-driven crossing simulation vs the closed form ----------------
y_sim, (t_plus, t_minus) = tdcore.td_vmm_four_quadrant(x, w, spec, return_times=True)
y_ref = tdcore.ideal_four_quadrant(x, w, spec.w_max)
print("\nlatch fire times + wire (ns):", (t_plus * 1e9).round(2))
print("decoded outputs :", y_sim)
print("closed form Eq.1:", y_ref)
print("max |err|       :", float(jnp.max(jnp.abs(y_sim - y_ref))))

# -- 5. the same multiplier as a model layer (fast path + QAT gradients) -----
cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6)
xb = jax.random.normal(key, (4, 8))
y_layer = td_matmul(xb, w, cfg)
print("\nTD-VMM layer out (6-bit):", y_layer[0])
print("exact matmul            :", (xb @ w)[0])

# chaining: a 2-layer MLP entirely in the time domain (Fig. 2)
w2 = jax.random.uniform(jax.random.PRNGKey(1), (4, 3), minval=-1, maxval=1)
y_mlp = tdcore.td_mlp_forward(x, w, w2, spec)
print("\n2-layer time-domain MLP out:", y_mlp,
      "\n(ideal:", tdcore.ideal_mlp(x, w, w2, spec.w_max), ")")

# -- 6. site plans: per-site configs + model-wide calibration -----------------
# Every analog matmul in a model has a canonical site name (attn.qkv, ffn.in,
# head, ...).  A TDVMMPlan maps ordered glob rules onto per-site overrides;
# chain=True declares the paper's time-domain chaining (Fig. 2) — the ffn.in
# tile's latch output feeds ffn.out directly, skipping one p-bit readout.
lm = ModelConfig(
    name="quickstart-lm", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256, vocab_pad_multiple=16,
    dtype="float32", remat_policy="none",
    tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True, backend="jnp"),   # default: 6-bit tiles
        tdvmm_rule("attn.qkv", bits=5),                 # cheaper projections
        tdvmm_rule("ffn.in", chain=True),               # analog ffn boundary
        tdvmm_rule("head", bits=7),                     # precise logits
    )))
print("\nresolved TD-VMM site plan:")
print(lm.resolved_tdvmm_plan.describe())

params = model.init_params(jax.random.PRNGKey(2), lm)
batch = {"inputs": jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                      lm.vocab_size)}
# one model-wide calibration pass pins every site's readout window (§3.1);
# serving then skips per-call max|z| and unlocks the fused Pallas epilogue.
calib = model.calibrate(params, batch, lm)
print("calibrated windows:",
      {site: round(float(jnp.max(w)), 4) for site, w in calib.windows.items()})
caches = model.init_caches(lm, 2, 24)
logits, caches = model.prefill_step(params, batch, caches, lm, calib=calib)
print("calibrated prefill logits:", logits.shape)
