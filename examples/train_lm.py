"""End-to-end LM training driver (example application).

Default --quick profile trains a ~9M-param qwen-family model for 300 steps on
CPU (~5 min) with TD-VMM quantized linears, exercising the full production
path: sharding-aware state init, microbatched train step, deterministic data,
atomic checkpoints + auto-resume, preemption guard, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py                # quick profile
    PYTHONPATH=src python examples/train_lm.py --profile 100m # ~100M params
"""
import argparse
import dataclasses

from repro.configs import OptimizerConfig, RunConfig, get_config
from repro.configs.base import ShapeConfig
from repro.core.layers import TDVMMLayerConfig
from repro.launch.train import train_loop

PROFILES = {
    # (d_model, n_layers, n_heads, kv, d_ff, seq, batch, steps)
    "quick": (256, 4, 4, 2, 1024, 256, 8, 300),
    "20m": (384, 6, 6, 2, 1536, 512, 8, 300),
    "100m": (768, 12, 12, 4, 3072, 1024, 16, 300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="quick", choices=sorted(PROFILES))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--tdvmm", action="store_true", default=True)
    ap.add_argument("--no-tdvmm", dest="tdvmm", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    d, L, h, kv, ff, seq, batch, steps = PROFILES[args.profile]
    steps = args.steps or steps
    cfg = get_config("qwen1.5-0.5b").replace(
        d_model=d, n_layers=L, n_heads=h, n_kv_heads=kv, head_dim=d // h,
        d_ff=ff, vocab_size=8192, vocab_pad_multiple=16, dtype="float32",
        remat_policy="none",
        tdvmm=TDVMMLayerConfig(enabled=args.tdvmm, bits=6, weight_bits=6))
    print(f"[config] {cfg.param_count()/1e6:.1f}M params, "
          f"tdvmm={'6-bit' if args.tdvmm else 'off'}")
    shape = ShapeConfig("example", seq_len=seq, global_batch=batch, kind="train",
                        microbatch_per_shard=batch)
    run = RunConfig(model=cfg, shape=shape,
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=30,
                                              total_steps=steps),
                    checkpoint_dir=args.ckpt_dir, checkpoint_every=100)
    out = train_loop(run, steps, log_every=20)
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"[done] loss {first:.3f} -> {last:.3f} over {out['step']} steps "
          f"({out.get('total_s', 0):.0f}s, stragglers={out.get('stragglers')})")
    assert last < first, "loss should decrease"


if __name__ == "__main__":
    main()
