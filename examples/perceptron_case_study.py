"""Section-3 case study, end to end: train the paper's 10x10x10 perceptron
with TD-VMM quantization-aware training, then DEPLOY it on the simulated
analog circuit (event-driven crossing times + DIBL/tuning non-idealities) and
measure accuracy — digital twin vs time-domain hardware.

    PYTHONPATH=src python examples/perceptron_case_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nonideal, tdcore
from repro.core.constants import TDVMMSpec
from repro.core.currents import quantize_weights
from repro.core.layers import TDVMMLayerConfig, td_matmul

# ---- a 10-class toy task: 10-dim gaussian blobs -----------------------------
key = jax.random.PRNGKey(0)
n_per, n_cls = 100, 10
centers = jax.random.uniform(key, (n_cls, 10), minval=-0.8, maxval=0.8)
ks = jax.random.split(jax.random.PRNGKey(1), n_cls)
xs = jnp.concatenate([
    centers[i] + 0.25 * jax.random.normal(ks[i], (n_per, 10))
    for i in range(n_cls)])
ys = jnp.repeat(jnp.arange(n_cls), n_per)
perm = jax.random.permutation(jax.random.PRNGKey(2), xs.shape[0])
xs, ys = jnp.clip(xs[perm], -1, 1), ys[perm]
x_tr, y_tr, x_te, y_te = xs[:800], ys[:800], xs[800:], ys[800:]

# ---- QAT training through the TD-VMM fast path (STE gradients) -------------
cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6)
params = {
    "w1": 0.5 * jax.random.normal(jax.random.PRNGKey(3), (10, 10)),
    "w2": 0.5 * jax.random.normal(jax.random.PRNGKey(4), (10, 10)),
}


def forward_qat(p, x):
    h = jax.nn.relu(td_matmul(x, p["w1"], cfg))
    return td_matmul(h, p["w2"], cfg)


def loss_fn(p, x, y):
    logits = forward_qat(p, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y])


@jax.jit
def step(p, x, y, lr):
    l, g = jax.value_and_grad(loss_fn)(p, x, y)
    return jax.tree.map(lambda a, b: a - lr * b, p, g), l


for epoch in range(300):
    params, l = step(params, x_tr, y_tr, 0.5)
acc_digital = float(jnp.mean(jnp.argmax(forward_qat(params, x_te), -1) == y_te))
print(f"QAT digital-twin test accuracy: {acc_digital:.3f}")

# ---- deploy on the simulated circuit (Fig. 2): crossing times + DIBL --------
spec = TDVMMSpec(bits=6)
wmax1 = float(jnp.max(jnp.abs(params["w1"])))
wmax2 = float(jnp.max(jnp.abs(params["w2"])))
w1n = quantize_weights(params["w1"] / wmax1, 6, 1.0)
w2n = quantize_weights(params["w2"] / wmax2, 6, 1.0)

err = float(nonideal.relative_error(spec.i_max, jnp.asarray(spec.v_sg),
                                    jnp.asarray(spec.delta_vd)))
kd = jax.random.PRNGKey(7)
w1d = w1n * (1 + err * jax.random.uniform(kd, w1n.shape, minval=-1, maxval=1))
w2d = w2n * (1 + err * jax.random.uniform(
    jax.random.split(kd)[0], w2n.shape, minval=-1, maxval=1))

td_fwd = jax.jit(jax.vmap(lambda x: tdcore.td_mlp_forward(x, w1d, w2d, spec),
                          in_axes=0))
logits_td = td_fwd(x_te)
acc_td = float(jnp.mean(jnp.argmax(logits_td, -1) == y_te))
print(f"time-domain circuit (event-driven + DIBL {err*100:.1f}%) accuracy: "
      f"{acc_td:.3f}")

# equivalence of the two compute paths on the same weights
ideal = jax.vmap(lambda x: tdcore.ideal_mlp(x, w1d, w2d, 1.0))(x_te)
print(f"crossing-sim vs closed-form max err: "
      f"{float(jnp.max(jnp.abs(logits_td - ideal))):.2e}")
print(f"accuracy drop from analog deployment: {acc_digital - acc_td:+.3f}")
assert acc_td > 0.8, "time-domain deployment should preserve accuracy"
