"""Batched serving example: prefill + decode with KV caches and a
continuous-batching-style slot manager (requests of different lengths enter
and leave the fixed-size decode batch).

    PYTHONPATH=src python examples/serve_lm.py

The FFN matmuls run as calibrated TD-VMM tiles via the site-plan API:
``ffn.*`` sites are addressed with one glob rule, ``ffn.in`` chains into
``ffn.out`` in the time domain (Fig. 2 — the intermediate p-bit readout
disappears), and a model-wide calibration pass pins each remaining digital
site's readout window before the steps are jitted.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.models import model

ARCH = "qwen1.5-0.5b"
BATCH_SLOTS = 4
MAX_LEN = 64


def main():
    cfg = smoke(get_config(ARCH)).replace(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("ffn.*", enabled=True, backend="auto"),
        tdvmm_rule("ffn.in", chain=True),
    )))
    print("TD-VMM plan:")
    print(cfg.resolved_tdvmm_plan.describe())
    params = model.init_params(jax.random.PRNGKey(0), cfg)

    # model-wide §3.1 window calibration on a representative prompt, pinned
    # into the jitted steps (fixed windows -> fused readout epilogue).
    calib_batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (BATCH_SLOTS, 16), 0, cfg.vocab_size)}
    calib = model.calibrate(params, calib_batch, cfg, max_len=MAX_LEN)
    print("calibrated sites:", calib.sites())

    prefill = jax.jit(lambda p, b, c: model.prefill_step(p, b, c, cfg,
                                                         calib=calib))
    decode = jax.jit(lambda p, b, c: model.decode_step(p, b, c, cfg,
                                                       calib=calib))

    # a queue of incoming "requests": (prompt tokens, #tokens to generate)
    rng = np.random.default_rng(0)
    requests = [(rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
                 int(rng.integers(8, 20))) for _ in range(10)]

    # slot state: per-slot caches (batch = BATCH_SLOTS)
    caches = model.init_caches(cfg, BATCH_SLOTS, MAX_LEN)
    slot_remaining = [0] * BATCH_SLOTS
    slot_request = [None] * BATCH_SLOTS
    cur_tok = jnp.zeros((BATCH_SLOTS, 1), jnp.int32)
    outputs = {i: [] for i in range(len(requests))}
    pending = list(enumerate(requests))
    done = 0
    t0 = time.time()
    steps = 0

    def admit(slot):
        """Prefill one pending request into `slot` (single-request prefill,
        then merged into the batch caches)."""
        nonlocal cur_tok
        rid, (prompt, gen) = pending.pop(0)
        c1 = model.init_caches(cfg, 1, MAX_LEN)
        logits, c1 = prefill(params,
                             {"inputs": jnp.asarray(prompt)[None, :]}, c1)
        tok = jnp.argmax(logits[0, -1, :cfg.vocab_size])[None, None]
        # merge single-request cache into the batch cache at `slot`
        def merge(batch_leaf, one_leaf):
            if batch_leaf.ndim == 0 or one_leaf.shape == batch_leaf.shape:
                return one_leaf if batch_leaf.ndim == 0 else batch_leaf
            # leaf shapes: (L, B, ...) vs (L, 1, ...)
            return batch_leaf.at[:, slot].set(one_leaf[:, 0])
        nonlocal caches
        caches = jax.tree.map(merge, caches, c1)
        cur_tok = cur_tok.at[slot].set(tok[0])
        slot_remaining[slot] = gen
        slot_request[slot] = rid
        outputs[rid].append(int(tok[0, 0]))

    while done < len(requests):
        for s in range(BATCH_SLOTS):
            if slot_remaining[s] == 0 and pending:
                admit(s)
        logits, caches = decode(params, {"inputs": cur_tok}, caches)
        steps += 1
        nxt = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1)
        cur_tok = nxt[:, None].astype(jnp.int32)
        for s in range(BATCH_SLOTS):
            if slot_remaining[s] > 0:
                outputs[slot_request[s]].append(int(nxt[s]))
                slot_remaining[s] -= 1
                if slot_remaining[s] == 0:
                    done += 1

    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(f"served {len(requests)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({steps} decode steps, batch={BATCH_SLOTS})")
    for rid in sorted(outputs)[:3]:
        print(f"  req {rid}: {outputs[rid][:10]}...")
    assert all(len(v) > 0 for v in outputs.values())


if __name__ == "__main__":
    main()
