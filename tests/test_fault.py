"""Fault tolerance: preemption-safe snapshot/restore, step retry, fault
injection, and drift-aware online recalibration.

Hard contracts under test:

  * an engine killed at ANY step of a ragged trace and restored from its
    snapshot resumes the remaining trace **bit-identically** to the
    uninterrupted run (streams, finish reasons, finish steps);
  * a transiently failing compiled step is retried invisibly (streams
    unchanged); a persistently failing one degrades to exactly one
    ``failed`` request with every neighbor's stream bit-equal;
  * injected device-current drift is detected by the eager probe and fixed
    by hot-swapping the pinned windows between steps — ``compiled_steps``
    stays exactly 2 (runtime-operand windows, no recompilation).
"""
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.models import model
from repro.runtime import fault
from repro.runtime import faultinject as fi
from repro.runtime.engine import (DriftConfig, Engine, EngineConfig,
                                  FaultConfig, Request)
from repro.runtime.sla import SlaConfig
from repro.runtime.telemetry import MemoryEmitter, MetricsSink


# ==========================================================================
# fault.py unit tests (no model)
# ==========================================================================
def test_guard_install_uninstall_restores_handlers():
    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    g = fault.PreemptionGuard().install()
    assert signal.getsignal(signal.SIGTERM) == g._handler
    g._handler(signal.SIGTERM, None)
    assert g.requested
    g.uninstall()
    assert signal.getsignal(signal.SIGTERM) == prev_term
    assert signal.getsignal(signal.SIGINT) == prev_int
    assert not g._installed and g._prev == {}
    # re-install after uninstall works (idempotent cycle)
    g2 = fault.PreemptionGuard().install().install()
    g2.uninstall()
    assert signal.getsignal(signal.SIGTERM) == prev_term


def test_retry_exhaustion_reraises_with_attempt_count():
    calls = []

    def boom():
        calls.append(1)
        raise RuntimeError("persistent")

    with pytest.raises(RuntimeError, match="persistent") as ei:
        fault.retry_step(boom, retries=2, backoff_s=0.0, jitter=0.0)
    assert len(calls) == 3                      # 1 try + 2 retries
    assert ei.value.retry_attempts == 3


def test_retry_does_not_swallow_non_runtime_errors():
    def boom():
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        fault.retry_step(boom, retries=5, backoff_s=0.0)


def test_retry_backoff_doubles_caps_and_jitters(monkeypatch):
    # fake clock: injected sleep advances it, so the <=100ms slice loop in
    # retry_step terminates deterministically without real waiting
    clock = {"t": 0.0}
    monkeypatch.setattr(fault.time, "monotonic", lambda: clock["t"])
    events = []

    def fake_sleep(s):
        events.append(s)
        clock["t"] += s

    def boom():
        raise RuntimeError("x")

    with pytest.raises(RuntimeError):
        fault.retry_step(
            boom, retries=6, backoff_s=1.0, backoff_cap_s=4.0, jitter=0.25,
            on_retry=lambda a, e: events.append(("attempt", a)),
            sleep=fake_sleep,
            rng=np.random.default_rng(0))  # has .random() like random.Random
    # slices arrive in <=0.1s pieces between "attempt" markers; reassemble
    # each attempt's total backoff
    attempts, totals, cur = [], [], None
    for ev in events:
        if isinstance(ev, tuple):
            if cur is not None:
                totals.append(cur)
            attempts.append(ev[1])
            cur = 0.0
        else:
            assert ev <= 0.1 + 1e-9
            cur += ev
    totals.append(cur)
    assert attempts == [1, 2, 3, 4, 5, 6]
    assert len(totals) == 6
    for i, t in enumerate(totals):
        nominal = min(1.0 * 2 ** i, 4.0)        # doubling, capped at 4s
        assert nominal * 0.75 - 1e-6 <= t <= nominal * 1.25 + 1e-6, (i, t)
    assert max(totals) <= 4.0 * 1.25 + 1e-6     # cap held under jitter


def test_retry_polls_guard_and_raises_preempted_fast():
    g = fault.PreemptionGuard()

    def boom():
        raise RuntimeError("x")

    def preempt_soon():
        time.sleep(0.05)
        g.requested = True

    t = threading.Thread(target=preempt_soon)
    t0 = time.time()
    t.start()
    # 30s nominal backoff: without slice-polling this would sleep it out
    with pytest.raises(fault.Preempted):
        fault.retry_step(boom, retries=3, backoff_s=30.0, jitter=0.0,
                         guard=g)
    t.join()
    assert time.time() - t0 < 5.0               # seen within ~100ms slices
    # already-requested guard preempts before the first attempt
    calls = []
    with pytest.raises(fault.Preempted):
        fault.retry_step(lambda: calls.append(1), guard=g)
    assert calls == []


def test_preempted_is_not_a_runtime_error():
    # retry_step retries RuntimeErrors; a preemption must never be one.
    assert not issubclass(fault.Preempted, RuntimeError)


def test_straggler_monitor_warmup_and_ewma():
    m = fault.StragglerMonitor(threshold=2.0, ewma_alpha=0.5)
    # warm-up: a huge step among the first 6 records is NOT flagged
    for dt in (0.1, 0.1, 5.0, 0.1, 0.1, 0.1):
        assert not m.record(0, dt)
    assert m.stragglers == 0 and m.n == 6
    assert m.ewma > 0.0                          # exposed for the report
    ewma_before = m.ewma
    assert m.record(7, 100 * ewma_before)        # post-warm-up outlier flags
    assert m.stragglers == 1
    assert m.log[0]["step"] == 7
    assert not m.record(8, ewma_before)          # normal step doesn't


def test_heartbeat_throttles(tmp_path):
    hb = fault.Heartbeat(tmp_path / "hb.json", every_s=3600.0)
    assert hb.beat(1) is True                    # first beat writes
    assert hb.beat(2) is False                   # throttled
    assert hb.beats == 1
    assert (tmp_path / "hb.json").exists()
    hb2 = fault.Heartbeat(tmp_path / "hb.json", every_s=0.0)
    assert hb2.beat(3) and hb2.beat(4)           # zero period never throttles
    assert hb2.beats == 2


# ==========================================================================
# Engine-level fault tolerance (shared tiny model + trace)
# ==========================================================================
def _cfg():
    return smoke(get_config("qwen1.5-0.5b")).replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("ffn.*", enabled=True, backend="jnp"),)))


ECFG = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    calib = model.calibrate(params, batch, cfg, max_len=48)
    return cfg, params, calib, batch


def _trace(vocab, n=4, seed=0):
    rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for rid in range(n):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(
                0, vocab, rng.integers(3, 11))),
            max_new_tokens=int(rng.integers(2, 6)),
            arrival_step=arrival))
        arrival += int(rng.integers(0, 2))
    return reqs


@pytest.fixture(scope="module")
def baseline(served):
    """Uninterrupted reference run + the trace it served."""
    cfg, params, calib, _ = served
    reqs = _trace(cfg.vocab_size)
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs)
    assert rep.compiled_steps == 2
    return reqs, rep


def _same_streams(a, b):
    for ra, rb in zip(a.requests, b.requests):
        assert ra["tokens"] == rb["tokens"], (ra, rb)
        assert ra["finish_reason"] == rb["finish_reason"], (ra, rb)
        assert ra["finished_step"] == rb["finished_step"], (ra, rb)
    assert a.steps == b.steps


# --------------------------------------------------------------------------
# THE tentpole property: kill at EVERY step k, restore, resume bit-identical
# --------------------------------------------------------------------------
def test_kill_at_every_step_resumes_bit_identically(served, baseline,
                                                    tmp_path):
    cfg, params, calib, _ = served
    reqs, base = baseline
    # Two engines reused across every k: each holds its own jit caches, so
    # the loop pays compilation once, and the victim engine also proves that
    # run() state fully re-initializes after a preempted run.
    victim = Engine(cfg, params, ECFG, calib=calib)
    survivor = Engine(cfg, params, ECFG, calib=calib)
    for k in range(base.steps):
        rep = victim.run(reqs, FaultConfig(
            injector=fi.FaultInjector([fi.PreemptAt(k)]),
            snapshot_dir=tmp_path, snapshot_keep=1))
        assert rep.preempted and rep.steps == k, (k, rep.steps)
        assert rep.snapshot_path is not None
        flat, step = checkpoint.load_engine_snapshot(tmp_path, step=k)
        assert step == k
        survivor.restore(flat)
        resumed = survivor.resume()
        assert not resumed.preempted
        _same_streams(base, resumed)
        assert survivor.compiled_steps() <= 2
    # the victim engine still serves clean traces afterwards
    _same_streams(base, victim.run(reqs))


def test_in_memory_snapshot_round_trip(served, baseline):
    cfg, params, calib, _ = served
    reqs, base = baseline
    e1 = Engine(cfg, params, ECFG, calib=calib)
    r1 = e1.run(reqs, FaultConfig(
        injector=fi.FaultInjector([fi.PreemptAt(2)])))
    assert r1.preempted
    e2 = Engine(cfg, params, ECFG, calib=calib)
    e2.restore(e1.snapshot())
    _same_streams(base, e2.resume())


def test_snapshot_ecfg_mismatch_raises(served, baseline):
    cfg, params, calib, _ = served
    reqs, _ = baseline
    e1 = Engine(cfg, params, ECFG, calib=calib)
    e1.run(reqs, FaultConfig(injector=fi.FaultInjector([fi.PreemptAt(2)])))
    snap = e1.snapshot()
    other = Engine(cfg, params,
                   EngineConfig(slots=2, page_size=4, num_pages=32, chunk=4),
                   calib=calib)
    with pytest.raises(ValueError, match="EngineConfig"):
        other.restore(snap)


# --------------------------------------------------------------------------
# Injected step failures through the retry wrapper
# --------------------------------------------------------------------------
def test_transient_failure_retried_streams_unchanged(served, baseline):
    cfg, params, calib, _ = served
    reqs, base = baseline
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs, FaultConfig(
        injector=fi.FaultInjector(
            [fi.FailStep(step=2, kind="any", times=1)]),
        retries=2, backoff_s=0.001))
    assert rep.step_retries == 1
    assert rep.failed == 0
    _same_streams(base, rep)


def test_persistent_failure_fails_one_request_neighbors_bit_equal(
        served, baseline):
    cfg, params, calib, _ = served
    reqs, base = baseline
    # times == retries + 1: the step's whole retry budget burns once — a
    # persistent failure.  The engine blames one request and keeps serving.
    fail_step = base.steps - 2
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs, FaultConfig(
        injector=fi.FaultInjector(
            [fi.FailStep(step=fail_step, kind="any", times=2)]),
        retries=1, backoff_s=0.001))
    failed = [r for r in rep.requests if r["finish_reason"] == "failed"]
    assert len(failed) == 1 and rep.failed == 1
    assert rep.step_retries == 1
    base_by = {r["rid"]: r for r in base.requests}
    for r in rep.requests:
        if r["finish_reason"] != "failed":
            assert r["tokens"] == base_by[r["rid"]]["tokens"], r["rid"]
            assert r["finish_reason"] == base_by[r["rid"]]["finish_reason"]
    # the failed request's already-streamed prefix is a baseline prefix
    fr = failed[0]
    assert fr["tokens"] == base_by[fr["rid"]]["tokens"][:len(fr["tokens"])]


def test_rid_attributed_failure_blames_that_request(served, baseline):
    cfg, params, calib, _ = served
    reqs, base = baseline
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs, FaultConfig(
        injector=fi.FaultInjector(
            [fi.FailStep(step=base.steps - 2, kind="decode", times=2,
                         rid=reqs[1].rid)]),
        retries=1, backoff_s=0.001))
    failed = [r for r in rep.requests if r["finish_reason"] == "failed"]
    assert [r["rid"] for r in failed] == [reqs[1].rid]


# --------------------------------------------------------------------------
# Drift detection + online recalibration (compiled_steps stays 2)
# --------------------------------------------------------------------------
def test_drift_triggers_recalibration_without_recompiling(served):
    cfg, params, calib, batch = served
    reqs = _trace(cfg.vocab_size, n=6, seed=5)
    eng = Engine(cfg, params, ECFG, calib=calib)
    rep = eng.run(reqs, FaultConfig(
        injector=fi.FaultInjector(
            [fi.DriftAt(step=4, sigma=0.5, repeats=3)]),
        drift=DriftConfig(probe_batch=batch, check_every=4,
                          clip_threshold=0.005, window_tol=0.05)))
    assert rep.recalibrations >= 1, rep.drift_events
    assert rep.drift_events[0]["recalibrated"]
    assert rep.drift_events[0]["max_log_ratio"] > 0.05 or \
        rep.drift_events[0]["max_clip_rate"] > 0.005
    assert rep.compiled_steps == 2              # hot-swap, no third program
    # the engine's pinned windows really moved
    moved = eng.pinned_calibration().drift_ratios(calib)
    assert any(abs(np.log(max(r, 1e-12))) > 1e-6 for r in moved.values())


def test_no_drift_no_false_positive(served):
    cfg, params, calib, batch = served
    reqs = _trace(cfg.vocab_size, n=6, seed=5)
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs, FaultConfig(
        drift=DriftConfig(probe_batch=batch, check_every=4,
                          clip_threshold=0.005, window_tol=0.05)))
    assert rep.recalibrations == 0 and rep.drift_events == []
    assert rep.compiled_steps == 2


def test_snapshot_carries_recalibrated_windows(served, baseline):
    """Preempt AFTER a drift recalibration: the snapshot must carry the
    hot-swapped windows (restoring the stale originals would break
    bit-identity of the remaining trace)."""
    cfg, params, calib, batch = served
    reqs = _trace(cfg.vocab_size, n=6, seed=5)
    drifted = fi.drift_params(
        params, jax.random.PRNGKey(0), fi._model_spec(cfg),
        __import__("repro.core.nonideal", fromlist=["NonIdealityConfig"])
        .NonIdealityConfig(dibl=False, weight_noise=True, sigma_tune=0.5),
        repeats=3)
    # reference: drifted params served end-to-end with fresh calibration
    fresh = model.calibrate(drifted, batch, cfg, max_len=48)
    base = Engine(cfg, drifted, ECFG, calib=fresh).run(reqs)
    # victim: same drifted params + fresh calib, preempted mid-trace
    e1 = Engine(cfg, drifted, ECFG, calib=fresh)
    e1.run(reqs, FaultConfig(
        injector=fi.FaultInjector([fi.PreemptAt(base.steps // 2)])))
    snap = e1.snapshot()
    # survivor constructed with the STALE calib; restore swaps in the
    # snapshot's (fresh) windows
    e2 = Engine(cfg, drifted, ECFG, calib=calib)
    e2.restore(snap)
    got = e2.pinned_calibration().as_arrays()
    want = fresh.as_arrays()
    for site in want:
        np.testing.assert_array_equal(np.asarray(got[site]),
                                      np.asarray(want[site]))
    _same_streams(base, e2.resume())


# --------------------------------------------------------------------------
# Fault telemetry reaches the report
# --------------------------------------------------------------------------
def test_monitor_and_heartbeat_feed_report(served, baseline, tmp_path):
    cfg, params, calib, _ = served
    reqs, base = baseline
    hb = fault.Heartbeat(tmp_path / "hb.json", every_s=0.0)
    mon = fault.StragglerMonitor()
    rep = Engine(cfg, params, ECFG, calib=calib).run(
        reqs, FaultConfig(heartbeat=hb, monitor=mon))
    _same_streams(base, rep)
    # every tick beat (0s period); ticks >= steps (the final drained tick
    # and evict-only re-plan ticks don't advance the step counter)
    assert rep.heartbeats >= rep.steps
    assert rep.straggler_ewma_s > 0.0
    assert rep.stragglers == mon.stragglers


def test_monitor_and_heartbeat_emit_into_sink(tmp_path):
    """Straggler and heartbeat events land in the metric series too (PR 8:
    one stream for everything the engine observes)."""
    sink = MetricsSink()
    mon = fault.StragglerMonitor(threshold=2.0, sink=sink)
    for step, dt in enumerate((0.1,) * 6):       # warm-up, no flags
        mon.record(step, dt)
    assert mon.record(7, 100.0)
    assert sink.series["straggler_dt_s"].count == 1
    assert sink.series["straggler_dt_s"].last == 100.0
    hb = fault.Heartbeat(tmp_path / "hb.json", every_s=0.0, sink=sink)
    hb.beat(3), hb.beat(4)
    assert sink.series["heartbeat"].count == 2
    assert sink.series["heartbeat"].last == 2.0  # cumulative beat counter


# --------------------------------------------------------------------------
# SlowStep injection (the telemetry straggler)
# --------------------------------------------------------------------------
def test_slowstep_fires_once_and_keeps_streams(served, baseline):
    cfg, params, calib, _ = served
    reqs, base = baseline
    ev = fi.SlowStep(step=2, sleep_s=0.05, kind="any")
    t0 = time.time()
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs, FaultConfig(
        injector=fi.FaultInjector([ev])))
    assert time.time() - t0 >= 0.05
    assert ev.fired and not ev.matches("decode", 2)   # one-shot
    _same_streams(base, rep)                     # wall time only, no values
    # kind filter: a prefill-only event never matches decode steps
    assert not fi.SlowStep(step=0, kind="prefill").matches("decode", 0)


# --------------------------------------------------------------------------
# PR 8 acceptance: the kill-at-any-step contract survives SLA + telemetry
# --------------------------------------------------------------------------
def _sla_trace(vocab, e_tok):
    """Mixed-priority trace + one deadline-doomed and one joule-capped
    request, so snapshots are taken with rejected/over_budget state and a
    live SLA queue in flight."""
    reqs = [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens,
                    arrival_step=r.arrival_step, priority=r.rid % 3)
            for r in _trace(vocab)]
    reqs.append(Request(rid=900, prompt=tuple(range(1, 9)),
                        max_new_tokens=20, deadline_steps=1,
                        arrival_step=1))
    reqs.append(Request(rid=901, prompt=tuple(range(9, 15)),
                        max_new_tokens=6, arrival_step=2,
                        joule_budget=(6 + 2.5) * e_tok))
    return reqs


def test_kill_at_any_step_with_sla_and_telemetry(served):
    cfg, params, calib, _ = served
    sla = SlaConfig(aging_steps=8)
    ref = Engine(cfg, params, ECFG, calib=calib, sla=sla,
                 sink=MetricsSink())
    reqs = _sla_trace(cfg.vocab_size, ref.energy["energy_per_token_j"])
    base = ref.run(reqs)
    # the trace really exercises the SLA paths the snapshot must carry
    assert base.rejected == 1 and base.over_budget == 1
    victim = Engine(cfg, params, ECFG, calib=calib, sla=sla,
                    sink=MetricsSink())
    survivor = Engine(cfg, params, ECFG, calib=calib, sla=sla,
                      sink=MetricsSink())
    for k in range(base.steps):
        rep = victim.run(reqs, FaultConfig(
            injector=fi.FaultInjector([fi.PreemptAt(k)])))
        assert rep.preempted and rep.steps == k
        snap = victim.snapshot()
        survivor.restore(snap)
        # the sink rode the snapshot: restored series/alerts are dict-equal
        assert survivor.sink.snapshot() == victim.sink.snapshot()
        resumed = survivor.resume()
        assert not resumed.preempted
        _same_streams(base, resumed)
        assert resumed.rejected == base.rejected
        assert resumed.over_budget == base.over_budget
        by_rid = {r["rid"]: r for r in resumed.requests}
        assert by_rid[900]["finish_reason"] == "rejected"
        assert by_rid[901]["finish_reason"] == "over_budget"
        assert survivor.compiled_steps() <= 2


def test_restore_sla_policy_mismatch_raises(served, baseline):
    cfg, params, calib, _ = served
    reqs, _ = baseline
    e1 = Engine(cfg, params, ECFG, calib=calib, sla=SlaConfig(aging_steps=8))
    e1.run(reqs, FaultConfig(injector=fi.FaultInjector([fi.PreemptAt(2)])))
    snap = e1.snapshot()
    # different aging policy -> different admission order -> refuse
    other = Engine(cfg, params, ECFG, calib=calib,
                   sla=SlaConfig(aging_steps=16))
    with pytest.raises(ValueError, match="SLA policy"):
        other.restore(snap)
    # no policy at all is also a mismatch
    with pytest.raises(ValueError, match="SLA policy"):
        Engine(cfg, params, ECFG, calib=calib).restore(snap)


def test_restore_telemetry_without_sink_raises(served, baseline):
    cfg, params, calib, _ = served
    reqs, _ = baseline
    e1 = Engine(cfg, params, ECFG, calib=calib, sink=MetricsSink())
    e1.run(reqs, FaultConfig(injector=fi.FaultInjector([fi.PreemptAt(2)])))
    snap = e1.snapshot()
    with pytest.raises(ValueError, match="no sink"):
        Engine(cfg, params, ECFG, calib=calib).restore(snap)
    # with a sink (any emitters — they are config, not state) it restores
    e2 = Engine(cfg, params, ECFG, calib=calib,
                sink=MetricsSink(emitters=[MemoryEmitter()]))
    e2.restore(snap)
    assert e2.sink.snapshot() == e1.sink.snapshot()


# --------------------------------------------------------------------------
# PR 9 acceptance: the kill+restore contract survives mesh sharding.
# Runs in a subprocess with 4 forced host devices (the main test process
# keeps its single-device jax runtime).
# --------------------------------------------------------------------------
_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import numpy as np

from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.launch.mesh import make_test_mesh
from repro.models import model
from repro.runtime import faultinject as fi
from repro.runtime.engine import Engine, EngineConfig, FaultConfig, Request
from repro.runtime.sla import SlaConfig
from repro.runtime.telemetry import MetricsSink

cfg = smoke(get_config("qwen1.5-0.5b")).replace(tdvmm_plan=TDVMMPlan(
    rules=(tdvmm_rule("ffn.*", enabled=True, backend="jnp"),)))
params = model.init_params(jax.random.PRNGKey(0), cfg)
batch = {"inputs": jax.random.randint(
    jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
calib = model.calibrate(params, batch, cfg, max_len=48)

# slots >= max concurrency of the trace: the DP pool's extra slots then never
# change admission, so solo and meshed runs schedule identically and every
# deterministic telemetry series must be bit-equal.
ecfg = EngineConfig(slots=6, page_size=4, num_pages=32, chunk=4)
sla = SlaConfig(aging_steps=8)

rng = np.random.default_rng(0)
reqs, arrival = [], 0
for rid in range(4):
    reqs.append(Request(
        rid=rid,
        prompt=tuple(int(t) for t in rng.integers(
            0, cfg.vocab_size, rng.integers(3, 11))),
        max_new_tokens=int(rng.integers(2, 6)),
        arrival_step=arrival, priority=rid % 3))
    arrival += int(rng.integers(0, 2))
e_tok = Engine(cfg, params, ecfg, calib=calib).energy["energy_per_token_j"]
reqs.append(Request(rid=900, prompt=tuple(range(1, 9)), max_new_tokens=20,
                    deadline_steps=1, arrival_step=1))
reqs.append(Request(rid=901, prompt=tuple(range(9, 15)), max_new_tokens=6,
                    arrival_step=2, joule_budget=(6 + 2.5) * e_tok))


def strip_latency(snap):
    # step_latency_s is wall clock — the only nondeterministic series
    snap = dict(snap)
    snap["series"] = {k: v for k, v in snap["series"].items()
                     if k != "step_latency_s"}
    return snap


def kill_restore(mesh, k):
    base = Engine(cfg, params, ecfg, calib=calib, sla=sla,
                  sink=MetricsSink(), mesh=mesh).run(reqs)
    victim = Engine(cfg, params, ecfg, calib=calib, sla=sla,
                    sink=MetricsSink(), mesh=mesh)
    rep = victim.run(reqs, FaultConfig(
        injector=fi.FaultInjector([fi.PreemptAt(k)])))
    assert rep.preempted and rep.steps == k
    survivor = Engine(cfg, params, ecfg, calib=calib, sla=sla,
                      sink=MetricsSink(), mesh=mesh)
    survivor.restore(victim.snapshot())
    sink_at_restore = strip_latency(survivor.sink.snapshot())
    resumed = survivor.resume()

    def streams(r):
        return [{"rid": q["rid"], "tokens": q["tokens"],
                 "finish_reason": q["finish_reason"],
                 "finished_step": q["finished_step"]} for q in r.requests]
    return {
        "base": streams(base), "resumed": streams(resumed),
        "base_steps": base.steps, "resumed_steps": resumed.steps,
        "rejected": resumed.rejected, "over_budget": resumed.over_budget,
        "sink_at_restore": sink_at_restore,
        "compiled": survivor.compiled_steps(),
        "devices": resumed.devices, "total_slots": resumed.total_slots,
    }


probe = Engine(cfg, params, ecfg, calib=calib, sla=sla,
               sink=MetricsSink()).run(reqs)
k = probe.steps // 2
out = {"solo": kill_restore(None, k),
       "mesh": kill_restore(make_test_mesh(2, 2), k)}
print("RESULTS::" + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_2x2_kill_restore_matches_unsharded_restore():
    """An engine killed mid-trace on a (2,2) mesh and restored from its
    snapshot resumes bit-identically — and its streams, SLA queue outcomes,
    and deterministic telemetry series are bit-equal to the *unsharded*
    kill+restore of the same trace."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULTS::")][0]
    res = __import__("json").loads(line.split("::", 1)[1])
    solo, mesh = res["solo"], res["mesh"]
    # restore contract holds on each layout independently
    for r in (solo, mesh):
        assert r["resumed"] == r["base"]
        assert r["resumed_steps"] == r["base_steps"]
        assert r["compiled"] == 2
        assert r["rejected"] == 1 and r["over_budget"] == 1
        by_rid = {q["rid"]: q for q in r["resumed"]}
        assert by_rid[900]["finish_reason"] == "rejected"
        assert by_rid[901]["finish_reason"] == "over_budget"
    # ... and the meshed restore is bit-equal to the unsharded restore:
    # streams, step count, SLA outcomes, telemetry series at restore point
    assert mesh["resumed"] == solo["resumed"]
    assert mesh["resumed_steps"] == solo["resumed_steps"]
    assert mesh["sink_at_restore"] == solo["sink_at_restore"]
    assert mesh["devices"] == 4 and mesh["total_slots"] == 12
    assert solo["devices"] == 1 and solo["total_slots"] == 6
