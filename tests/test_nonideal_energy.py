"""Tests for the DIBL precision model (Fig. 4) and the energy/area/latency
model (Fig. 5, section 4.2) — every anchor number the paper reports."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, nonideal
from repro.core.constants import DELTA_VD, I_MAX_OPT, V_SG_OPT
from repro.core.layers import TDVMMLayerConfig, td_matmul


# --- Fig. 4: DIBL error surface -------------------------------------------
def test_vsg_optimum_at_0p8():
    vsgs = np.linspace(0.5, 1.1, 25)
    errs = [float(nonideal.relative_error(I_MAX_OPT, v, DELTA_VD)) for v in vsgs]
    assert vsgs[int(np.argmin(errs))] == pytest.approx(V_SG_OPT, abs=0.05)


def test_error_below_2pct_at_optimum():
    e = float(nonideal.relative_error(I_MAX_OPT, V_SG_OPT, DELTA_VD))
    assert e < 0.02


def test_error_decreasing_with_current_then_bounded():
    """Fig. 4a/b: error falls with I_max up to ~1-2 uA, then rises at the
    subthreshold conduction edge."""
    lo = float(nonideal.relative_error(1e-8, V_SG_OPT, DELTA_VD))
    mid = float(nonideal.relative_error(1e-6, V_SG_OPT, DELTA_VD))
    hi = float(nonideal.relative_error(5e-6, V_SG_OPT, DELTA_VD))
    assert lo > mid and hi > mid


def test_effective_bits_at_least_5():
    e = nonideal.relative_error(I_MAX_OPT, V_SG_OPT, DELTA_VD)
    assert int(nonideal.effective_bits(e)) >= 5


def test_end_to_end_6bit_precision():
    """~6-bit TD-VMM layer error should sit near the paper's 2% band."""
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.1
    y6 = td_matmul(x, w, TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6))
    rel = float(jnp.max(jnp.abs(y6 - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.05, rel


# --- Fig. 5 / section 4.2: energy, latency, area ----------------------------
def test_energy_anchors():
    for key, (model, paper) in energy.validate_against_paper().items():
        assert model == pytest.approx(paper, rel=0.12), (key, model, paper)


def test_energy_efficiency_increases_with_n():
    t10 = energy.cost(10).tops_per_j
    t100 = energy.cost(100).tops_per_j
    t1000 = energy.cost(1000).tops_per_j
    assert t10 < t100 < t1000
    assert t1000 > 145.0            # "potentially reaching 150 TOps/J"


def test_io_overhead_amortizes():
    """Fig. 5: I/O conversion share drops and becomes negligible for N>200."""
    frac10 = energy.cost(10).e_io_j / energy.cost(10).e_total_j
    frac500 = energy.cost(500).e_io_j / energy.cost(500).e_total_j
    assert frac500 < frac10 and frac500 < 0.03


def test_latency_scales_with_precision():
    """2T = 2*T0*2^p (section 4.2)."""
    assert energy.cost(100, bits=6).latency_s == pytest.approx(64e-9)
    assert energy.cost(100, bits=8).latency_s == pytest.approx(256e-9)


def test_area_split_large_n():
    c = energy.cost(1000)
    frac_cap = c.area_cap_um2 / (c.area_cap_um2 + c.area_mem_um2)
    assert frac_cap == pytest.approx(0.75, abs=0.02)


def test_peripheral_dominates_small_n():
    """Fig. 3: at N=10 the neuron blocks dwarf the supercell array."""
    c = energy.cost(10)
    assert c.area_neuron_um2 > c.area_mem_um2


def test_llm_mapping_reports():
    shapes = [(4096, 4096)] * 4 + [(4096, 14336)] * 3
    out = energy.llm_mapping_cost(shapes, tile_n=1024, bits=6)
    assert out["tops_per_j"] > 100.0      # large-N regime of Fig. 5
    assert out["tiles"] > 0
