"""Pipeline parallelism over the pod axis (launch/pipeline.py): GPipe-style
schedule must be numerically identical to the plain forward, and must
lower+compile on the production multi-pod mesh (2 stages x 256 chips)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.launch import pipeline
from repro.models import model

cfg = smoke(get_config("yi-34b")).replace(n_layers=4)
params = model.init_params(jax.random.PRNGKey(0), cfg)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
ref, _ = model.forward(params, {"inputs": tokens, "targets": tokens}, cfg)
with mesh:
    out = jax.jit(lambda p, t: pipeline.pp_forward(p, t, cfg, mesh, n_micro=4))(
        params, tokens)
err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
assert err < 2e-2, err
print("PP_OK", err)
"""


@pytest.mark.slow
def test_pp_equals_plain_forward():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PP_OK" in out.stdout
