"""Continuous-batching serving engine: paged KV cache, slot scheduler,
two-compiled-step invariant, evict-before-poison, energy accounting.

The load-bearing contract (acceptance criterion, jnp backend): per-request
token streams from the batched paged engine are bit-identical to running
each request alone at the same calibrated windows — slots never couple.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.core import energy
from repro.models import attention, model
from repro.runtime.engine import (Engine, EngineConfig, Request,
                                  static_baseline)
from repro.runtime.paged_cache import PagePool, pages_for


def _cfg():
    return smoke(get_config("qwen1.5-0.5b")).replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("ffn.*", enabled=True, backend="jnp"),)))


@pytest.fixture(scope="module")
def served():
    """Shared (cfg, params, calib): one calibration pass for the module."""
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    calib = model.calibrate(params, batch, cfg, max_len=48)
    return cfg, params, calib


def _trace(vocab, n=4, seed=0, prompt=(3, 11), gen=(2, 6), max_gap=0):
    rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for rid in range(n):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(
                0, vocab, rng.integers(*prompt))),
            max_new_tokens=int(rng.integers(*gen)),
            arrival_step=arrival))
        arrival += int(rng.integers(0, max_gap + 1))
    return reqs


def _solo_dense_greedy(cfg, params, calib, req):
    """Reference: the request alone through the dense-cache
    prefill_step/decode_step path at the same calibrated windows."""
    caches = model.init_caches(cfg, 1, len(req.prompt) + req.max_new_tokens)
    logits, caches = model.prefill_step(
        params, {"inputs": jnp.asarray([req.prompt], jnp.int32)}, caches,
        cfg, calib=calib)
    toks = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
    while len(toks) < req.max_new_tokens:
        logits, caches = model.decode_step(
            params, {"inputs": jnp.asarray([[toks[-1]]], jnp.int32)}, caches,
            cfg, calib=calib)
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
    return toks


# --------------------------------------------------------------------------
# Acceptance: batched engine == each request alone (dense path), jnp backend
# --------------------------------------------------------------------------
def test_engine_bit_identical_to_solo_dense(served):
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size, n=4)
    # chunk covers every prompt: the whole prompt is one prefill chunk, the
    # exact computation prefill_step runs (masked page tail == exact zeros).
    eng = Engine(cfg, params,
                 EngineConfig(slots=3, page_size=4, num_pages=32, chunk=16),
                 calib=calib)
    rep = eng.run(reqs)
    assert rep.compiled_steps == 2
    assert rep.nan_logit_steps == 0
    for req, rec in zip(reqs, rep.requests):
        assert rec["finish_reason"] == "max_tokens"
        assert rec["tokens"] == _solo_dense_greedy(cfg, params, calib, req), \
            f"slot coupling: request {req.rid} diverged from its solo run"


def test_engine_chunked_prefill_matches_solo_engine(served):
    """Chunked prefill (chunk < prompt) stays request-isolated: batched run
    == B=1 run with the same chunking."""
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size, n=4, seed=3, prompt=(6, 14))
    ecfg = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4)
    rep = Engine(cfg, params, ecfg, calib=calib).run(reqs)
    solo_cfg = EngineConfig(slots=1, page_size=4, num_pages=32, chunk=4)
    for req, rec in zip(reqs, rep.requests):
        solo = Engine(cfg, params, solo_cfg, calib=calib).run(
            [Request(req.rid, req.prompt, req.max_new_tokens, 0)])
        assert rec["tokens"] == solo.requests[0]["tokens"]


def test_engine_requires_pinned_windows(served):
    cfg, params, _ = served
    with pytest.raises(ValueError, match="pinned readout window"):
        Engine(cfg, params, EngineConfig())


# --------------------------------------------------------------------------
# Satellite: evict-before-poison (page budget hit => clean "evicted" finish)
# --------------------------------------------------------------------------
def test_eviction_finishes_cleanly_without_poisoning_neighbors(served):
    cfg, params, calib = served
    # rid 0 wants far more tokens than its page budget; rid 1/2 are small.
    reqs = [Request(0, tuple(range(1, 9)), max_new_tokens=40),
            Request(1, tuple(range(9, 14)), max_new_tokens=4),
            Request(2, tuple(range(14, 20)), max_new_tokens=5)]
    ecfg = EngineConfig(slots=3, page_size=4, num_pages=16,
                        max_pages_per_slot=3, chunk=16)
    rep = Engine(cfg, params, ecfg, calib=calib).run(reqs)
    by_rid = {r["rid"]: r for r in rep.requests}
    # budget = 3 pages * 4 = 12 positions, prompt 8 -> 4 decode writes; the
    # token sampled after the last write needs no page, so 5 tokens stream.
    assert by_rid[0]["finish_reason"] == "evicted"
    assert len(by_rid[0]["tokens"]) == 5
    # the would-be NaN-poisoning write never happened: no NaN logit row was
    # observed on ANY active slot in the whole run,
    assert rep.nan_logit_steps == 0
    # and the neighbors' streams are exactly their solo runs.
    for rid in (1, 2):
        assert by_rid[rid]["finish_reason"] == "max_tokens"
        assert by_rid[rid]["tokens"] == _solo_dense_greedy(
            cfg, params, calib, reqs[rid])
    # the evicted prefix itself is still correct (truncated solo stream)
    solo0 = _solo_dense_greedy(cfg, params, calib, reqs[0].__class__(
        0, reqs[0].prompt, 5))
    assert by_rid[0]["tokens"] == solo0


def test_oversized_prompt_rejected_as_evicted(served):
    cfg, params, calib = served
    reqs = [Request(0, tuple(range(1, 30)), max_new_tokens=4),
            Request(1, tuple(range(1, 6)), max_new_tokens=3)]
    ecfg = EngineConfig(slots=2, page_size=4, num_pages=16,
                        max_pages_per_slot=4, chunk=8)
    rep = Engine(cfg, params, ecfg, calib=calib).run(reqs)
    assert rep.requests[0]["finish_reason"] == "evicted"
    assert rep.requests[0]["tokens"] == []
    assert rep.requests[1]["finish_reason"] == "max_tokens"
    assert rep.requests[1]["tokens"] == _solo_dense_greedy(
        cfg, params, calib, reqs[1])


# --------------------------------------------------------------------------
# Satellite: int8 KV quantization under page reuse (write -> free -> realloc)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("int8", [False, True])
def test_page_reuse_no_stale_scale_bleed(served, int8):
    """A new request reallocating a finished request's pages must see no
    trace of the old codes/scales (stale positions are masked to exact
    zeros; every written position carries its own fresh scale)."""
    cfg, params, calib = served
    # pool = exactly one request's worth of pages: B MUST reuse A's pages.
    reqs = [Request(0, tuple(range(1, 11)), max_new_tokens=5,
                    arrival_step=0),
            Request(1, tuple(range(40, 49)), max_new_tokens=5,
                    arrival_step=1)]
    ecfg = EngineConfig(slots=2, page_size=4, num_pages=4, chunk=8)
    assert pages_for(15, 4) == 4          # A fills the whole pool
    attention.set_kv_cache_int8(int8)
    try:
        rep = Engine(cfg, params, ecfg, calib=calib).run(reqs)
        solo_cfg = EngineConfig(slots=1, page_size=4, num_pages=8, chunk=8)
        for req, rec in zip(reqs, rep.requests):
            assert rec["finish_reason"] == "max_tokens"
            solo = Engine(cfg, params, solo_cfg, calib=calib).run(
                [Request(req.rid, req.prompt, req.max_new_tokens, 0)])
            assert rec["tokens"] == solo.requests[0]["tokens"], \
                f"int8={int8}: stale page state bled into request {req.rid}"
        assert rep.nan_logit_steps == 0
    finally:
        attention.set_kv_cache_int8(False)


# --------------------------------------------------------------------------
# Satellite: scheduler determinism across slot assignment order
# --------------------------------------------------------------------------
def test_slot_assignment_order_does_not_change_streams(served):
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size, n=6, seed=7, prompt=(3, 12), gen=(2, 7),
                  max_gap=2)
    kw = dict(page_size=4, num_pages=32, chunk=8)
    rep_f = Engine(cfg, params, EngineConfig(slots=3, slot_order="fifo", **kw),
                   calib=calib).run(reqs)
    rep_l = Engine(cfg, params, EngineConfig(slots=3, slot_order="lifo", **kw),
                   calib=calib).run(reqs)
    for a, b in zip(rep_f.requests, rep_l.requests):
        assert a["tokens"] == b["tokens"]
        assert a["finish_reason"] == b["finish_reason"]
        assert a["finished_step"] == b["finished_step"]
    assert rep_f.steps == rep_l.steps


# --------------------------------------------------------------------------
# Acceptance: engine beats the static batch on the ragged trace (steps,
# KV memory high-water, utilization)
# --------------------------------------------------------------------------
def test_engine_beats_static_batch_on_ragged_trace(served):
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size, n=10, seed=0, prompt=(4, 14), gen=(2, 25),
                  max_gap=1)
    ecfg = EngineConfig(slots=4, page_size=4, num_pages=64, chunk=8)
    rep = Engine(cfg, params, ecfg, calib=calib).run(reqs)
    static = static_baseline(reqs, ecfg.slots, ecfg.chunk)
    assert rep.steps < static["wall_steps"]
    assert rep.utilization > static["utilization"]
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    dense = jax.eval_shape(lambda: model.init_caches(cfg, ecfg.slots, max_len))
    dense_bytes = sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                      for leaf in jax.tree.leaves(dense))
    assert rep.kv_high_water_bytes < dense_bytes
    assert rep.nan_logit_steps == 0
    assert rep.compiled_steps == 2


# --------------------------------------------------------------------------
# Energy accounting
# --------------------------------------------------------------------------
def test_serving_energy_model_chain_saves_io(served):
    cfg, _, _ = served
    unchained = energy.serving_energy_model(cfg, tile_n=64)
    chained_cfg = cfg.replace(tdvmm_plan=cfg.tdvmm_plan.with_rules(
        tdvmm_rule("ffn.in", chain=True)))
    chained = energy.serving_energy_model(chained_cfg, tile_n=64)
    assert unchained["ops_per_token"] > 0
    # chaining drops one readout + one DAC: same ops, strictly less energy
    assert chained["ops_per_token"] == unchained["ops_per_token"]
    assert chained["energy_per_token_j"] < unchained["energy_per_token_j"]
    assert chained["per_site"]["ffn.in"]["io_factor"] == 0.5
    assert chained["per_site"]["ffn.out"]["io_factor"] == 0.5
    # disabled sites don't meter
    off = energy.serving_energy_model(smoke(get_config("qwen1.5-0.5b")))
    assert off["ops_per_token"] == 0


def test_serving_energy_model_chain_saves_io_moe_sites(served):
    """Chain-aware I/O halving on the MoE chainable pairs: both
    ``moe.expert.in -> .out`` and ``moe.shared.in -> .out`` drop the
    intermediate p-bit boundary (io_factor 0.5 on each end), with ops
    unchanged and strictly less energy per token."""
    # kimi-k2 smoke: the only arch with shared experts (n_shared_experts=1)
    base = smoke(get_config("kimi-k2-1t-a32b"))
    assert base.moe.n_shared_experts >= 1
    on = base.replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("moe.*", enabled=True, backend="jnp"),)))
    unchained = energy.serving_energy_model(on, tile_n=64)
    chained_cfg = on.replace(tdvmm_plan=on.tdvmm_plan.with_rules(
        tdvmm_rule("moe.expert.in", chain=True),
        tdvmm_rule("moe.shared.in", chain=True)))
    chained = energy.serving_energy_model(chained_cfg, tile_n=64)
    assert unchained["ops_per_token"] > 0
    assert chained["ops_per_token"] == unchained["ops_per_token"]
    assert chained["energy_per_token_j"] < unchained["energy_per_token_j"]
    for site in ("moe.expert.in", "moe.expert.out",
                 "moe.shared.in", "moe.shared.out"):
        assert unchained["per_site"][site]["io_factor"] == 1.0, site
        assert chained["per_site"][site]["io_factor"] == 0.5, site
    # each chained pair saves exactly half its I/O energy; the expert pair
    # (top_k matrices) saves more joules than the single shared pair
    def pair_saving(up, down):
        return sum(unchained["per_site"][s]["energy_per_token_j"]
                   - chained["per_site"][s]["energy_per_token_j"]
                   for s in (up, down))
    assert pair_saving("moe.expert.in", "moe.expert.out") > \
        pair_saving("moe.shared.in", "moe.shared.out") > 0
    # chaining only the expert pair leaves the shared boundary digital
    expert_only = energy.serving_energy_model(on.replace(
        tdvmm_plan=on.tdvmm_plan.with_rules(
            tdvmm_rule("moe.expert.in", chain=True))), tile_n=64)
    assert expert_only["per_site"]["moe.shared.in"]["io_factor"] == 1.0
    assert expert_only["per_site"]["moe.expert.in"]["io_factor"] == 0.5


def test_token_cost_and_request_energy_bounds(served):
    cfg, _, _ = served
    table = energy.serving_energy_model(cfg, tile_n=64)
    ops1, e1 = energy.token_cost(table)
    assert (ops1, e1) == (table["ops_per_token"],
                          table["energy_per_token_j"])
    ops5, e5 = energy.token_cost(table, 5)
    assert ops5 == pytest.approx(5 * ops1) and e5 == pytest.approx(5 * e1)
    b = energy.request_energy_bounds(table, prompt_len=7, max_new_tokens=4)
    # min = prompt + 1 token (the cheapest *served* outcome), full = budget
    assert b["min_tokens"] == 8 and b["full_tokens"] == 11
    assert b["min_energy_j"] == pytest.approx(8 * e1)
    assert b["full_energy_j"] == pytest.approx(11 * e1)
    assert b["min_ops"] == pytest.approx(8 * ops1)
    assert b["min_energy_j"] < b["full_energy_j"]
    with pytest.raises(ValueError, match=">= 1"):
        energy.request_energy_bounds(table, 0, 4)
    with pytest.raises(ValueError, match=">= 1"):
        energy.request_energy_bounds(table, 7, 0)


def test_engine_per_request_energy_accounting(served):
    cfg, params, calib = served
    reqs = [Request(0, tuple(range(1, 7)), max_new_tokens=3)]
    ecfg = EngineConfig(slots=1, page_size=4, num_pages=8, chunk=8, tile_n=64)
    eng = Engine(cfg, params, ecfg, calib=calib)
    rep = eng.run(reqs)
    tokens = len(reqs[0].prompt) + 3
    assert rep.requests[0]["analog_ops"] == pytest.approx(
        tokens * eng.energy["ops_per_token"])
    assert rep.requests[0]["analog_energy_j"] == pytest.approx(
        tokens * eng.energy["energy_per_token_j"])
    assert rep.fj_per_op == pytest.approx(eng.energy["fj_per_op"])
    assert rep.tokens_per_joule > 0


# --------------------------------------------------------------------------
# Page pool mechanics
# --------------------------------------------------------------------------
def test_page_pool_deterministic_alloc_free():
    pool = PagePool(num_pages=6, page_size=4)
    a = pool.alloc(3)
    assert a == [0, 1, 2] and pool.in_use == 3
    b = pool.alloc(2)
    assert b == [3, 4]
    assert pool.alloc(2) is None and pool.in_use == 5   # nothing taken
    pool.free(a)
    assert pool.alloc(4) == [0, 1, 2, 5]
    assert pool.high_water == 6
    with pytest.raises(ValueError):
        pool.free([3, 3])
    assert pool.trash_page == 6


def test_page_pool_dp_ranks_partition_and_snapshot():
    """ranks=dp partitions the pool: rank r owns ids offset by r*(P+1),
    rank 0 is bit-identical to the ranks=1 pool, and only the single global
    trash page exists (last device row)."""
    pool = PagePool(num_pages=6, page_size=4, ranks=2)
    assert pool.total_pages == 12 and pool.trash_page == 13
    # rank 0 mirrors the single-rank layout exactly
    assert pool.alloc(3, rank=0) == [0, 1, 2]
    # rank 1's region starts beyond rank 0's trash row (id 6)
    b = pool.alloc(2, rank=1)
    assert b == [7, 8] and pool.in_use == 5
    # per-rank exhaustion: rank 1 has 4 pages left, rank 0 has 3
    assert pool.alloc(4, rank=0) is None
    assert pool.alloc(4, rank=1) == [9, 10, 11, 12]
    # free() infers the rank from the id; cross-region ids are rejected
    pool.free(b)
    assert pool.alloc(2, rank=1) == [7, 8]
    with pytest.raises(ValueError, match="out-of-range"):
        pool.free([6])                      # rank 0's trash row: not a page
    with pytest.raises(ValueError):
        pool.alloc(1, rank=2)
    # free-list snapshot round-trips (what Engine.snapshot carries)
    lists = pool.free_lists()
    pool2 = PagePool(num_pages=6, page_size=4, ranks=2)
    pool2.restore_free(lists)
    assert pool2.free_lists() == lists
    assert pool2.in_use == pool.in_use
    with pytest.raises(ValueError, match="rank free-lists"):
        PagePool(num_pages=6, page_size=4).restore_free(lists)


# --------------------------------------------------------------------------
# Acceptance (PR 9): mesh-sharded engine, (1,1) mesh == no mesh exactly
# --------------------------------------------------------------------------
def test_mesh_1x1_engine_bit_identical_to_meshless(served):
    """The sharded engine on a trivial (1,1) mesh replays the fixed-seed
    ragged trace bit-identically to the meshless engine — streams, finish
    reasons, finish steps — with compiled_steps == 2 through the sharded
    path (shard_map over size-1 axes, device_put'ed params/pools/batches)."""
    from repro.launch.mesh import make_test_mesh

    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size, n=6, seed=5, prompt=(3, 12), gen=(2, 7),
                  max_gap=1)
    ecfg = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4)
    base = Engine(cfg, params, ecfg, calib=calib).run(reqs)
    meshed_eng = Engine(cfg, params, ecfg, calib=calib,
                        mesh=make_test_mesh(1, 1))
    meshed = meshed_eng.run(reqs)
    assert meshed.compiled_steps == 2
    assert meshed.devices == 1 and meshed.total_slots == ecfg.slots
    assert meshed.steps == base.steps
    assert meshed.page_high_water == base.page_high_water
    for a, b in zip(base.requests, meshed.requests):
        assert a["tokens"] == b["tokens"], (a, b)
        assert a["finish_reason"] == b["finish_reason"]
        assert a["finished_step"] == b["finished_step"]
    # snapshot layout is the meshless v3 layout (dp=1, one free list)
    snap = meshed_eng.snapshot()
    import json as _json
    meta = _json.loads(np.asarray(snap["meta"], np.uint8).tobytes())
    assert meta["dp"] == 1 and len(meta["pool"]["free"]) == 1
