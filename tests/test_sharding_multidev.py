"""Multi-device numerical equivalence: the sharded execution paths (GSPMD
FSDP+TP, shard_map MoE local/EP, explicit-TP reductions) must produce the
same numbers as single-device execution.

Runs in a subprocess with 4 forced host devices so the main test process
keeps its single-device jax runtime.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, smoke
from repro.launch import meshctx, sharding
from repro.launch.mesh import make_test_mesh, axis_info
from repro.models import model, common

results = {}
import dataclasses
for arch in ["mixtral-8x7b", "kimi-k2-1t-a32b", "yi-34b"]:
    cfg = smoke(get_config(arch)).replace(vocab_pad_multiple=32)
    if cfg.moe is not None:
        # no-drop capacity: capacity-dropping is per-shard-local by design
        # (GShard semantics), so drop patterns legitimately differ across
        # mesh layouts; equivalence is only defined without drops.
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    key = jax.random.PRNGKey(0)
    b, s = 4, 16
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"inputs": tokens, "targets": tokens}

    # single-device reference
    meshctx.set_mesh(None)
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    ref, _ = model.forward(params, batch, cfg)

    # sharded: 2x2 mesh, FSDP+TP specs, same params
    mesh = make_test_mesh(2, 2)
    info = axis_info(mesh)
    meshctx.set_mesh(mesh, info["dp_axes"], info["tp_axis"])
    p_specs = sharding.param_specs(jax.eval_shape(lambda: params), cfg, mesh)
    p_sh = sharding.to_named(p_specs, mesh)
    params_sharded = jax.tree.map(jax.device_put, params, p_sh)
    with mesh:
        fwd = jax.jit(lambda p, bt: model.forward(p, bt, cfg)[0])
        out = fwd(params_sharded, batch)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    results[arch] = err

    # explicit-TP path (it.1b) for the dense arch
    if arch == "yi-34b":
        common.set_tp_explicit(True)
        with mesh:
            out2 = jax.jit(lambda p, bt: model.forward(p, bt, cfg)[0])(
                params_sharded, batch)
        common.set_tp_explicit(False)
        results["yi-34b_tp_explicit"] = float(
            jnp.max(jnp.abs(out2.astype(jnp.float32) - ref.astype(jnp.float32))))
    meshctx.set_mesh(None)

# ---- elastic restore: checkpoint under mesh A, restore under mesh B --------
import tempfile
from repro.checkpoint import checkpoint as ckpt

cfg = smoke(get_config("yi-34b")).replace(vocab_pad_multiple=32)
params = model.init_params(jax.random.PRNGKey(5), cfg)
mesh_a = make_test_mesh(2, 2)
info_a = axis_info(mesh_a)
spec_a = sharding.param_specs(jax.eval_shape(lambda: params), cfg, mesh_a)
sharded_a = jax.tree.map(jax.device_put, params, sharding.to_named(spec_a, mesh_a))
with tempfile.TemporaryDirectory() as d:
    ckpt.save(sharded_a, d, step=3)
    # restore onto a DIFFERENT mesh layout (4-way data, no model axis use)
    mesh_b = make_test_mesh(4, 1)
    spec_b = sharding.param_specs(jax.eval_shape(lambda: params), cfg, mesh_b)
    restored, step = ckpt.restore(params, d, shardings=sharding.to_named(spec_b, mesh_b))
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)))
    results["elastic_restore"] = err

print("RESULTS::" + json.dumps(results))
"""


@pytest.mark.slow
def test_sharded_equals_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS::")][0]
    results = json.loads(line.split("::", 1)[1])
    for name, err in results.items():
        assert err < 5e-2, f"{name}: sharded-vs-single max err {err}"
