"""Unified quantized-code subsystem (core/quant.py) + backend dispatch.

The contract under test: one QuantizedTensor path from encoding to the Pallas
TD-VMM kernel, with (a) the jnp and Pallas-interpret integrate backends
bit-for-bit identical at model shapes, (b) exact padding round-trips for
non-block-multiple shapes, and (c) STE gradients flowing through every stage
so QAT works on either backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.layers import TDVMMLayerConfig, td_matmul
from repro.kernels.tdvmm.ops import tdvmm_matmul
from repro.kernels.tdvmm.ref import tdvmm_matmul_ref
from repro.kernels.tdvmm.tdvmm import pad_to_blocks, padded_size


# --------------------------------------------------------------------------
# QuantizedTensor stages
# --------------------------------------------------------------------------
def test_encode_input_codes_and_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 33)) * 3.0
    qt = quant.encode_input(x, bits=6)
    codes = np.asarray(qt.codes)
    assert qt.bits == 6 and qt.levels == 63
    assert codes.shape == x.shape and qt.scale.shape == (4, 7, 1)
    # codes are exact integers on the signed p-bit grid
    assert np.all(codes == np.round(codes))
    assert np.max(np.abs(codes)) <= 63
    # round-trip error bounded by half an LSB of the per-row range
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(x))
    bound = np.asarray(qt.scale) / (2 * 63) + 1e-6
    assert np.all(err <= bound)


def test_program_weights_per_channel_vs_per_tensor():
    w = jax.random.normal(jax.random.PRNGKey(1), (40, 9))
    q_pc = quant.program_weights(w, bits=6, per_channel=True)
    q_pt = quant.program_weights(w, bits=6, per_channel=False)
    assert q_pc.scale.shape == (1, 9) and q_pt.scale.shape == (1, 1)
    np.testing.assert_allclose(
        np.asarray(q_pc.scale[0]), np.abs(np.asarray(w)).max(axis=0))
    for q in (q_pc, q_pt):
        codes = np.asarray(q.codes)
        assert np.all(codes == np.round(codes)) and np.max(np.abs(codes)) <= 63


def test_readout_matches_inline_formula():
    y = jax.random.normal(jax.random.PRNGKey(2), (13, 21)) * 4.0
    for bits in (4, 6, 8):
        levels = (1 << bits) - 1
        s = float(jnp.max(jnp.abs(y)))
        expect = jnp.round(y / s * levels) / levels * s
        np.testing.assert_allclose(
            np.asarray(quant.readout(y, bits)), np.asarray(expect),
            rtol=1e-6, atol=1e-6)


def test_quantized_tensor_is_a_pytree():
    qt = quant.encode_input(jnp.ones((3, 5)), bits=6)
    out = jax.jit(lambda t: t.dequantize())(qt)
    assert out.shape == (3, 5)
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 3  # codes + scale + ste; bits is static metadata
    # a serving-path tensor (no STE companion) drops to 2 leaves
    bare = quant.QuantizedTensor(
        codes=qt.codes, scale=qt.scale, bits=qt.bits, ste=None)
    assert len(jax.tree.leaves(bare)) == 2
    np.testing.assert_array_equal(
        np.asarray(bare.dequantize()), np.asarray(qt.dequantize()))


def test_int8_storage_and_f32_view():
    """p <= 7 codes store as int8; view() is the f32 STE companion."""
    x = jax.random.normal(jax.random.PRNGKey(20), (5, 40)) * 2.0
    qt = quant.encode_input(x, bits=6)
    assert qt.codes.dtype == jnp.int8 and qt.ste is not None
    view = qt.view()
    assert view.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(view), np.asarray(qt.codes).astype(np.float32))
    # p = 8 codes span [-255, 255]: int8 can't hold them -> f32 storage
    qt8 = quant.encode_input(x, bits=8)
    assert qt8.codes.dtype == jnp.float32 and qt8.ste is None
    assert float(jnp.max(jnp.abs(qt8.codes))) <= 255
    # gradients flow through the int8 storage's view (QAT identity)
    g = jax.grad(lambda x: jnp.sum(quant.encode_input(x, 6).dequantize()))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), rtol=1e-5)


def test_program_noise_forces_f32_codes():
    """Noise perturbs codes off the integer grid -> f32 storage, grads kept."""
    from repro.core.constants import TDVMMSpec
    w = jax.random.normal(jax.random.PRNGKey(21), (32, 8))
    qw = quant.program_weights(w, bits=6)
    assert qw.codes.dtype == jnp.int8
    qn = quant.program_noise(qw, TDVMMSpec(), jax.random.PRNGKey(0))
    assert qn.codes.dtype == jnp.float32
    g = jax.grad(lambda w: jnp.sum(quant.program_noise(
        quant.program_weights(w, 6), TDVMMSpec(),
        jax.random.PRNGKey(0)).dequantize()))(w)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.linalg.norm(g)) > 0


# --------------------------------------------------------------------------
# (a) jnp path == Pallas-interpret path, bit for bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [
    ((2, 9, 200), (200, 120)),     # non-block-multiple model shape
    ((8, 128), (128, 64)),         # the perceptron case-study shape
    ((3, 256), (256, 512)),        # block-aligned K/N, tiny M
])
def test_backend_parity_bit_for_bit(shape):
    x_shape, w_shape = shape
    x = jax.random.normal(jax.random.PRNGKey(3), x_shape)
    w = jax.random.normal(jax.random.PRNGKey(4), w_shape) * 0.2
    cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6, backend="jnp")
    y_jnp = td_matmul(x, w, cfg)
    y_pal = td_matmul(x, w, cfg.replace(backend="pallas"))
    assert y_jnp.shape == x_shape[:-1] + (w_shape[1],)
    assert np.array_equal(np.asarray(y_jnp), np.asarray(y_pal))


def test_backend_parity_without_io_quantize():
    """Time-chained tiles (no digital boundary) must agree too."""
    x = jax.random.normal(jax.random.PRNGKey(5), (5, 100))
    w = jax.random.normal(jax.random.PRNGKey(6), (100, 30))
    cfg = TDVMMLayerConfig(enabled=True, io_quantize=False, backend="jnp")
    y_jnp = td_matmul(x, w, cfg)
    y_pal = td_matmul(x, w, cfg.replace(backend="pallas"))
    assert np.array_equal(np.asarray(y_jnp), np.asarray(y_pal))


def test_ops_matches_ref_oracle():
    """ops.tdvmm_matmul (both backends) vs the pure-jnp oracle, with readout."""
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    m, k, n = 150, 300, 70
    xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(jax.random.PRNGKey(8), (m,), minval=0.5, maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(9), (n,), minval=0.5, maxval=2.0)
    ref = tdvmm_matmul_ref(xc, wc, xs, ws, gain=1e-4, out_bits=6)
    got = {}
    for backend in ("jnp", "pallas"):
        got[backend] = tdvmm_matmul(xc, wc, xs, ws, gain=1e-4, out_bits=6,
                                    backend=backend)
        # vs the (un-jitted) oracle: identical math, so only ulp-level slack
        # for jit-vs-eager evaluation of the same expression
        np.testing.assert_allclose(np.asarray(got[backend]), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    # between backends (same jit context): bit for bit
    np.testing.assert_array_equal(np.asarray(got["jnp"]),
                                  np.asarray(got["pallas"]))


# --------------------------------------------------------------------------
# (b) padding round-trips for non-block-multiple shapes
# --------------------------------------------------------------------------
def test_empty_batch_both_backends():
    """M=0 (e.g. a serving batch filtered to nothing) must not crash —
    neither the ops layer nor the full td_matmul path (whose calibrated
    readout takes a global max over the empty output)."""
    xc = jnp.zeros((0, 64))
    wc = jnp.ones((64, 8))
    for backend in ("jnp", "pallas"):
        y = tdvmm_matmul(xc, wc, jnp.zeros((0,)), jnp.ones((8,)),
                         backend=backend)
        assert y.shape == (0, 8)
        cfg = TDVMMLayerConfig(enabled=True, backend=backend)
        y2 = td_matmul(jnp.zeros((0, 64)), jnp.ones((64, 8)), cfg)
        assert y2.shape == (0, 8)


@pytest.mark.parametrize("m,k,n", [(300, 520, 130), (7, 100, 3), (129, 513, 257)])
def test_padding_roundtrip_exact(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * n))
    xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    got = tdvmm_matmul(xc, wc, jnp.ones((m,)), jnp.ones((n,)),
                       backend="pallas")
    expect = jnp.dot(xc, wc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_pad_to_blocks_shapes():
    xc = jnp.ones((300, 520))
    wc = jnp.ones((520, 130))
    xp, wp = pad_to_blocks(xc, wc)
    assert xp.shape == (padded_size(300, 128, 8), padded_size(520, 512, 128))
    assert wp.shape == (xp.shape[1], padded_size(130, 128, 128))
    # every padded dim is kernel-divisible AND Mosaic-tileable
    for dim, block, tile in [(xp.shape[0], 128, 8), (xp.shape[1], 512, 128),
                             (wp.shape[1], 128, 128)]:
        assert dim % min(block, dim) == 0 and dim % tile == 0
    # padding is zeros => zero charge contribution
    assert float(jnp.sum(xp)) == 300 * 520 and float(jnp.sum(wp)) == 520 * 130


def test_accumulator_envelope_warning_dtype_aware():
    """The 2^24 exactness warning belongs to the f32 fallback only: 8-bit
    codes (|code| <= 255, can't store int8) past K ~ 258 warn; any
    int8-eligible width never does, for any K."""
    import warnings as w
    x = jnp.ones((2, 1024))
    wt = jnp.ones((1024, 8))
    cfg = TDVMMLayerConfig(enabled=True, bits=8, weight_bits=8, backend="jnp")
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        td_matmul(x, wt, cfg)
    assert any("2^24" in str(c.message) for c in caught)
    # int8/int32 path: exact for any K -> silent even far past the old
    # envelope ((2^7-1)^2 * 8192 = 1.3e8 >> 2^24)
    xl = jnp.ones((2, 8192))
    wl = jnp.ones((8192, 8))
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        td_matmul(xl, wl, cfg.replace(bits=7, weight_bits=7))
        td_matmul(x, wt, cfg.replace(bits=6, weight_bits=6))
    assert not caught
    # noise forces the f32 fallback (non-integer codes): the same 6-bit
    # shape that is silent on the int path warns once past 2^24
    noisy = cfg.replace(bits=6, weight_bits=6, noise=True)
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        td_matmul(xl, wl, noisy, key=jax.random.PRNGKey(0))
    assert any("2^24" in str(c.message) for c in caught)


def test_int8_backend_parity_beyond_f32_envelope():
    """int8-code matmul: jnp and pallas bit-for-bit AND exact vs int64 numpy
    for K deep enough that f32 accumulation would round (|acc| > 2^24)."""
    m, k, n = 8, 2048, 16
    # adversarial codes: |acc| = 127*127*2048 - 127 = 33 038 209 (odd, above
    # 2^24, hence NOT f32-representable) in column 0
    xc = np.full((m, k), 127, np.int8)
    wc = np.full((k, n), 127, np.int8)
    wc[0, 0] = 126
    exact = xc.astype(np.int64) @ wc.astype(np.int64)
    assert np.max(np.abs(exact)) > (1 << 24)
    got = {}
    for backend in ("jnp", "pallas"):
        got[backend] = tdvmm_matmul(
            jnp.asarray(xc), jnp.asarray(wc), jnp.ones((m,)), jnp.ones((n,)),
            backend=backend)
        np.testing.assert_array_equal(
            np.asarray(got[backend]), exact.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(got["jnp"]),
                                  np.asarray(got["pallas"]))


def test_int8_and_f32_code_paths_agree_within_envelope():
    """Same integer codes through code_dtype='int8' vs 'f32': bit-for-bit
    while the f32 envelope holds (the int path is a pure storage change)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(22))
    m, k, n = 33, 300, 40
    xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    xs = jnp.ones((m,))
    ws = jnp.ones((n,))
    for backend in ("jnp", "pallas"):
        y_int = tdvmm_matmul(xc, wc, xs, ws, gain=1e-3, out_bits=6,
                             out_scale=0.5, backend=backend,
                             code_dtype="int8")
        y_f32 = tdvmm_matmul(xc, wc, xs, ws, gain=1e-3, out_bits=6,
                             out_scale=0.5, backend=backend,
                             code_dtype="f32")
        np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_f32))


def test_fused_epilogue_matches_unfused_reference():
    """Fixed-window readout: the pallas fused-epilogue kernel vs the unfused
    jnp path and the pure-jnp oracle — bit-for-bit on integer codes."""
    kx, kw = jax.random.split(jax.random.PRNGKey(23))
    m, k, n = 100, 384, 72
    xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(jax.random.PRNGKey(24), (m,), minval=0.5, maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(25), (n,), minval=0.5, maxval=2.0)
    for out_bits, out_scale in [(6, 0.5), (4, 1.25), (None, None)]:
        args = dict(gain=1e-4, out_bits=out_bits, out_scale=out_scale)
        ref = tdvmm_matmul_ref(xc, wc, xs, ws, **args)
        y_fused = tdvmm_matmul(xc, wc, xs, ws, backend="pallas", **args)
        y_jnp = tdvmm_matmul(xc, wc, xs, ws, backend="jnp", **args)
        # fused kernel vs unfused jnp epilogue: identical expression, bit
        # for bit; vs the (un-jitted) oracle only ulp-level jit/eager slack
        np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_jnp))
        np.testing.assert_allclose(np.asarray(y_fused), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


def test_calibration_cache_out_scale():
    """calibrate() captures the readout window once; serving with the cached
    window matches the per-call data calibration on the calibration batch,
    and stays on the cached grid for new data."""
    from repro.core.layers import TDVMMLinear, calibrate_out_scale
    x = jax.random.normal(jax.random.PRNGKey(26), (16, 96))
    w = jax.random.normal(jax.random.PRNGKey(27), (96, 24)) * 0.1
    for backend in ("jnp", "pallas"):
        cfg = TDVMMLayerConfig(enabled=True, backend=backend)
        s = calibrate_out_scale(x, w, cfg)
        assert isinstance(s, float) and s > 0
        cached = cfg.replace(out_scale=s)
        y_dyn = td_matmul(x, w, cfg)
        y_fix = td_matmul(x, w, cached)
        np.testing.assert_allclose(np.asarray(y_fix), np.asarray(y_dyn),
                                   rtol=1e-6, atol=1e-7)
        # a fresh batch reuses the frozen window: outputs stay on the cached
        # p-bit grid (values quantized over s, then rescaled per-row/channel)
        x2 = jax.random.normal(jax.random.PRNGKey(28), (4, 96)) * 0.3
        y2 = td_matmul(x2, w, cached)
        assert y2.shape == (4, 24) and bool(jnp.all(jnp.isfinite(y2)))
        # TDVMMLinear.calibrate returns the pinned config
        params = {"w": w}
        cfg2 = TDVMMLinear.calibrate(params, x, cfg)
        assert cfg2.out_scale == pytest.approx(s)


def test_batched_expert_ops_matches_ref():
    """(E, M, K) x (E, K, N) batched grid vs the batched oracle, both
    backends, with per-expert calibrated readout."""
    ke = jax.random.PRNGKey(29)
    e, m, k, n = 3, 40, 200, 24
    kx, kw, ks1, ks2 = jax.random.split(ke, 4)
    xc = jnp.round(jax.random.uniform(kx, (e, m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (e, k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(ks1, (e, m), minval=0.5, maxval=2.0)
    ws = jax.random.uniform(ks2, (e, n), minval=0.5, maxval=2.0)
    for out_scale in (None, 0.5):
        ref = tdvmm_matmul_ref(xc, wc, xs, ws, gain=1e-4, out_bits=6,
                               out_scale=out_scale)
        got = {}
        for backend in ("jnp", "pallas"):
            got[backend] = tdvmm_matmul(xc, wc, xs, ws, gain=1e-4, out_bits=6,
                                        out_scale=out_scale, backend=backend)
            np.testing.assert_allclose(np.asarray(got[backend]),
                                       np.asarray(ref), rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got["jnp"]),
                                      np.asarray(got["pallas"]))


# --------------------------------------------------------------------------
# (c) STE gradients flow through every stage
# --------------------------------------------------------------------------
def test_ste_gradient_through_encode_input():
    x = jax.random.normal(jax.random.PRNGKey(10), (6, 50))
    g = jax.grad(lambda x: jnp.sum(quant.encode_input(x, 6).dequantize()))(x)
    # STE: dequantize(encode(x)) has identity gradient in the value domain
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), rtol=1e-5)


def test_ste_gradient_through_program_weights():
    w = jax.random.normal(jax.random.PRNGKey(11), (50, 20))
    g = np.asarray(jax.grad(
        lambda w: jnp.sum(quant.program_weights(w, 6).dequantize()))(w))
    # identity everywhere, including each column's max-magnitude weight (the
    # seed STE'd against the *unclipped* w/w_max; a clip in the STE path
    # would halve the gradient exactly at the scale-defining weights)
    np.testing.assert_allclose(g, np.ones_like(g), rtol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_qat_gradients_through_td_matmul(backend):
    cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6, backend=backend)
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 80))
    w = jax.random.normal(jax.random.PRNGKey(13), (80, 24)) * 0.1

    def loss(x, w):
        return jnp.sum(jnp.square(td_matmul(x, w, cfg)))

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert float(jnp.linalg.norm(gx)) > 0 and float(jnp.linalg.norm(gw)) > 0
    assert bool(jnp.all(jnp.isfinite(gx)) and jnp.all(jnp.isfinite(gw)))


def test_qat_gradients_backend_identical():
    """The custom VJP makes gradients backend-independent, exactly."""
    x = jax.random.normal(jax.random.PRNGKey(14), (2, 3, 90))
    w = jax.random.normal(jax.random.PRNGKey(15), (90, 40))

    def loss(cfg):
        return lambda x, w: jnp.sum(jnp.square(td_matmul(x, w, cfg)))

    base = TDVMMLayerConfig(enabled=True)
    gj = jax.grad(loss(base.replace(backend="jnp")), argnums=(0, 1))(x, w)
    gp = jax.grad(loss(base.replace(backend="pallas")), argnums=(0, 1))(x, w)
    for a, b in zip(gj, gp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# end-to-end: precision of the refactored layer is unchanged
# --------------------------------------------------------------------------
def test_layer_precision_band():
    """~6-bit TD-VMM error stays in the paper's ~2% band on both backends."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.1
    exact = x @ w
    for backend in ("jnp", "pallas"):
        cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6,
                               backend=backend)
        y = td_matmul(x, w, cfg)
        rel = float(jnp.max(jnp.abs(y - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.05, (backend, rel)


# --------------------------------------------------------------------------
# int4 nibble packing (p <= 3 codes, two per byte)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 3])
@pytest.mark.parametrize("k", [8, 7, 1])
@pytest.mark.parametrize("axis", [-1, -2])
def test_pack_int4_round_trip(bits, k, axis):
    """pack_int4/unpack_int4 round-trip every p <= 3 code exactly, on either
    axis, including odd lengths (the pad nibble is dropped on unpack)."""
    lim = 2 ** bits - 1
    shape = (5, k) if axis == -1 else (k, 5)
    rng = np.random.default_rng(bits * 10 + k)
    codes = jnp.asarray(
        rng.integers(-lim, lim + 1, size=shape).astype(np.int8))
    packed = quant.pack_int4(codes, axis=axis)
    assert packed.dtype == jnp.int8
    assert packed.shape[axis] == (k + 1) // 2
    back = quant.unpack_int4(packed, k, axis=axis)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_pack_int4_byte_layout():
    """Byte kp = code 2kp in the low nibble, code 2kp+1 in the high nibble —
    the layout tdvmm._unpack_nibbles assumes."""
    codes = jnp.asarray([[1, -2, 7, -8]], dtype=jnp.int8)
    packed = np.asarray(quant.pack_int4(codes, axis=-1))
    # 0xE1 = (-2 & 0xF) << 4 | 1, 0x87 = (-8 & 0xF) << 4 | 7, as int8
    expect = np.asarray([[0xE1, 0x87]], dtype=np.uint8).astype(np.int8)
    np.testing.assert_array_equal(packed, expect)


def test_concat_group_ragged_layout():
    """concat_group pads each member only to its own declared span: member
    codes land at their column offsets, pad columns are zero codes with 1.0
    scales (inert), and mismatched declarations raise."""
    ws = [jax.random.normal(jax.random.PRNGKey(i), (16, n)) * 0.1
          for i, n in enumerate((10, 3))]
    qws = [quant.program_weights(w, 6) for w in ws]
    widths = (16, 8)
    bank = quant.concat_group(qws, widths)
    codes = np.asarray(bank.codes)
    assert codes.shape == (16, 24)
    np.testing.assert_array_equal(codes[:, :10], np.asarray(qws[0].codes))
    np.testing.assert_array_equal(codes[:, 16:19], np.asarray(qws[1].codes))
    assert not codes[:, 10:16].any() and not codes[:, 19:].any()
    scale = np.asarray(bank.scale)
    assert scale.shape == (1, 24)
    np.testing.assert_array_equal(scale[0, 10:16], np.ones(6))
    np.testing.assert_array_equal(scale[0, 19:], np.ones(5))
    with pytest.raises(ValueError, match="exceed"):
        quant.concat_group(qws, (8, 8))
    with pytest.raises(ValueError, match="widths for"):
        quant.concat_group(qws, (16,))
