"""Unified quantized-code subsystem (core/quant.py) + backend dispatch.

The contract under test: one QuantizedTensor path from encoding to the Pallas
TD-VMM kernel, with (a) the jnp and Pallas-interpret integrate backends
bit-for-bit identical at model shapes, (b) exact padding round-trips for
non-block-multiple shapes, and (c) STE gradients flowing through every stage
so QAT works on either backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.layers import TDVMMLayerConfig, td_matmul
from repro.kernels.tdvmm.ops import tdvmm_matmul
from repro.kernels.tdvmm.ref import tdvmm_matmul_ref
from repro.kernels.tdvmm.tdvmm import pad_to_blocks, padded_size


# --------------------------------------------------------------------------
# QuantizedTensor stages
# --------------------------------------------------------------------------
def test_encode_input_codes_and_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 33)) * 3.0
    qt = quant.encode_input(x, bits=6)
    codes = np.asarray(qt.codes)
    assert qt.bits == 6 and qt.levels == 63
    assert codes.shape == x.shape and qt.scale.shape == (4, 7, 1)
    # codes are exact integers on the signed p-bit grid
    assert np.all(codes == np.round(codes))
    assert np.max(np.abs(codes)) <= 63
    # round-trip error bounded by half an LSB of the per-row range
    err = np.abs(np.asarray(qt.dequantize()) - np.asarray(x))
    bound = np.asarray(qt.scale) / (2 * 63) + 1e-6
    assert np.all(err <= bound)


def test_program_weights_per_channel_vs_per_tensor():
    w = jax.random.normal(jax.random.PRNGKey(1), (40, 9))
    q_pc = quant.program_weights(w, bits=6, per_channel=True)
    q_pt = quant.program_weights(w, bits=6, per_channel=False)
    assert q_pc.scale.shape == (1, 9) and q_pt.scale.shape == (1, 1)
    np.testing.assert_allclose(
        np.asarray(q_pc.scale[0]), np.abs(np.asarray(w)).max(axis=0))
    for q in (q_pc, q_pt):
        codes = np.asarray(q.codes)
        assert np.all(codes == np.round(codes)) and np.max(np.abs(codes)) <= 63


def test_readout_matches_inline_formula():
    y = jax.random.normal(jax.random.PRNGKey(2), (13, 21)) * 4.0
    for bits in (4, 6, 8):
        levels = (1 << bits) - 1
        s = float(jnp.max(jnp.abs(y)))
        expect = jnp.round(y / s * levels) / levels * s
        np.testing.assert_allclose(
            np.asarray(quant.readout(y, bits)), np.asarray(expect),
            rtol=1e-6, atol=1e-6)


def test_quantized_tensor_is_a_pytree():
    qt = quant.encode_input(jnp.ones((3, 5)), bits=6)
    out = jax.jit(lambda t: t.dequantize())(qt)
    assert out.shape == (3, 5)
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 2  # codes + scale; bits is static metadata


# --------------------------------------------------------------------------
# (a) jnp path == Pallas-interpret path, bit for bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [
    ((2, 9, 200), (200, 120)),     # non-block-multiple model shape
    ((8, 128), (128, 64)),         # the perceptron case-study shape
    ((3, 256), (256, 512)),        # block-aligned K/N, tiny M
])
def test_backend_parity_bit_for_bit(shape):
    x_shape, w_shape = shape
    x = jax.random.normal(jax.random.PRNGKey(3), x_shape)
    w = jax.random.normal(jax.random.PRNGKey(4), w_shape) * 0.2
    cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6, backend="jnp")
    y_jnp = td_matmul(x, w, cfg)
    y_pal = td_matmul(x, w, cfg.replace(backend="pallas"))
    assert y_jnp.shape == x_shape[:-1] + (w_shape[1],)
    assert np.array_equal(np.asarray(y_jnp), np.asarray(y_pal))


def test_backend_parity_without_io_quantize():
    """Time-chained tiles (no digital boundary) must agree too."""
    x = jax.random.normal(jax.random.PRNGKey(5), (5, 100))
    w = jax.random.normal(jax.random.PRNGKey(6), (100, 30))
    cfg = TDVMMLayerConfig(enabled=True, io_quantize=False, backend="jnp")
    y_jnp = td_matmul(x, w, cfg)
    y_pal = td_matmul(x, w, cfg.replace(backend="pallas"))
    assert np.array_equal(np.asarray(y_jnp), np.asarray(y_pal))


def test_ops_matches_ref_oracle():
    """ops.tdvmm_matmul (both backends) vs the pure-jnp oracle, with readout."""
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    m, k, n = 150, 300, 70
    xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(jax.random.PRNGKey(8), (m,), minval=0.5, maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(9), (n,), minval=0.5, maxval=2.0)
    ref = tdvmm_matmul_ref(xc, wc, xs, ws, gain=1e-4, out_bits=6)
    got = {}
    for backend in ("jnp", "pallas"):
        got[backend] = tdvmm_matmul(xc, wc, xs, ws, gain=1e-4, out_bits=6,
                                    backend=backend)
        # vs the (un-jitted) oracle: identical math, so only ulp-level slack
        # for jit-vs-eager evaluation of the same expression
        np.testing.assert_allclose(np.asarray(got[backend]), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    # between backends (same jit context): bit for bit
    np.testing.assert_array_equal(np.asarray(got["jnp"]),
                                  np.asarray(got["pallas"]))


# --------------------------------------------------------------------------
# (b) padding round-trips for non-block-multiple shapes
# --------------------------------------------------------------------------
def test_empty_batch_both_backends():
    """M=0 (e.g. a serving batch filtered to nothing) must not crash —
    neither the ops layer nor the full td_matmul path (whose calibrated
    readout takes a global max over the empty output)."""
    xc = jnp.zeros((0, 64))
    wc = jnp.ones((64, 8))
    for backend in ("jnp", "pallas"):
        y = tdvmm_matmul(xc, wc, jnp.zeros((0,)), jnp.ones((8,)),
                         backend=backend)
        assert y.shape == (0, 8)
        cfg = TDVMMLayerConfig(enabled=True, backend=backend)
        y2 = td_matmul(jnp.zeros((0, 64)), jnp.ones((64, 8)), cfg)
        assert y2.shape == (0, 8)


@pytest.mark.parametrize("m,k,n", [(300, 520, 130), (7, 100, 3), (129, 513, 257)])
def test_padding_roundtrip_exact(m, k, n):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * n))
    xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    got = tdvmm_matmul(xc, wc, jnp.ones((m,)), jnp.ones((n,)),
                       backend="pallas")
    expect = jnp.dot(xc, wc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_pad_to_blocks_shapes():
    xc = jnp.ones((300, 520))
    wc = jnp.ones((520, 130))
    xp, wp = pad_to_blocks(xc, wc)
    assert xp.shape == (padded_size(300, 128, 8), padded_size(520, 512, 128))
    assert wp.shape == (xp.shape[1], padded_size(130, 128, 128))
    # every padded dim is kernel-divisible AND Mosaic-tileable
    for dim, block, tile in [(xp.shape[0], 128, 8), (xp.shape[1], 512, 128),
                             (wp.shape[1], 128, 128)]:
        assert dim % min(block, dim) == 0 and dim % tile == 0
    # padding is zeros => zero charge contribution
    assert float(jnp.sum(xp)) == 300 * 520 and float(jnp.sum(wp)) == 520 * 130


def test_accumulator_envelope_warning():
    """8-bit codes past K ~ 258 leave the f32 integer-exact envelope."""
    import warnings as w
    x = jnp.ones((2, 1024))
    wt = jnp.ones((1024, 8))
    cfg = TDVMMLayerConfig(enabled=True, bits=8, weight_bits=8, backend="jnp")
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        td_matmul(x, wt, cfg)
    assert any("2^24" in str(c.message) for c in caught)
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        td_matmul(x, wt, cfg.replace(bits=6, weight_bits=6))
    assert not caught


# --------------------------------------------------------------------------
# (c) STE gradients flow through every stage
# --------------------------------------------------------------------------
def test_ste_gradient_through_encode_input():
    x = jax.random.normal(jax.random.PRNGKey(10), (6, 50))
    g = jax.grad(lambda x: jnp.sum(quant.encode_input(x, 6).dequantize()))(x)
    # STE: dequantize(encode(x)) has identity gradient in the value domain
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), rtol=1e-5)


def test_ste_gradient_through_program_weights():
    w = jax.random.normal(jax.random.PRNGKey(11), (50, 20))
    g = np.asarray(jax.grad(
        lambda w: jnp.sum(quant.program_weights(w, 6).dequantize()))(w))
    # identity everywhere, including each column's max-magnitude weight (the
    # seed STE'd against the *unclipped* w/w_max; a clip in the STE path
    # would halve the gradient exactly at the scale-defining weights)
    np.testing.assert_allclose(g, np.ones_like(g), rtol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_qat_gradients_through_td_matmul(backend):
    cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6, backend=backend)
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 80))
    w = jax.random.normal(jax.random.PRNGKey(13), (80, 24)) * 0.1

    def loss(x, w):
        return jnp.sum(jnp.square(td_matmul(x, w, cfg)))

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert float(jnp.linalg.norm(gx)) > 0 and float(jnp.linalg.norm(gw)) > 0
    assert bool(jnp.all(jnp.isfinite(gx)) and jnp.all(jnp.isfinite(gw)))


def test_qat_gradients_backend_identical():
    """The custom VJP makes gradients backend-independent, exactly."""
    x = jax.random.normal(jax.random.PRNGKey(14), (2, 3, 90))
    w = jax.random.normal(jax.random.PRNGKey(15), (90, 40))

    def loss(cfg):
        return lambda x, w: jnp.sum(jnp.square(td_matmul(x, w, cfg)))

    base = TDVMMLayerConfig(enabled=True)
    gj = jax.grad(loss(base.replace(backend="jnp")), argnums=(0, 1))(x, w)
    gp = jax.grad(loss(base.replace(backend="pallas")), argnums=(0, 1))(x, w)
    for a, b in zip(gj, gp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# end-to-end: precision of the refactored layer is unchanged
# --------------------------------------------------------------------------
def test_layer_precision_band():
    """~6-bit TD-VMM error stays in the paper's ~2% band on both backends."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.1
    exact = x @ w
    for backend in ("jnp", "pallas"):
        cfg = TDVMMLayerConfig(enabled=True, bits=6, weight_bits=6,
                               backend=backend)
        y = td_matmul(x, w, cfg)
        rel = float(jnp.max(jnp.abs(y - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.05, (backend, rel)
