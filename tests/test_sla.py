"""SLA-aware admission & dispatch: priority-with-aging fairness, deadline
and joule admission control, over-budget graceful degradation.

Hard contracts under test:

  * with every SLA field at its default, an ``SlaScheduler``-driven engine
    run replays the plain-FIFO run **bit-identically** (streams, finish
    reasons, finish steps) — the policy is provably inert until asked for;
  * aging bounds queue wait: a lowest-priority request under a continuous
    stream of high-priority arrivals is admitted within
    ``wait_bound(sla, P_max)`` steps (and a counterexample with enormous
    ``aging_steps`` starves past any horizon — the bound is the lever);
  * infeasible requests are rejected AT ADMISSION with zero compute
    (no tokens, no joules, no pages) and never count as deadline misses;
  * a request that crosses its ``joule_budget`` mid-stream finishes as
    ``over_budget`` with its already-streamed prefix intact and every
    neighbor's stream bit-equal;
  * scheduling decisions are independent of physical slot ids
    (``slot_order="lifo"`` serves identical streams under SLA).
"""
import jax
import numpy as np
import pytest

from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.core import energy
from repro.models import model
from repro.runtime.engine import Engine, EngineConfig, Request
from repro.runtime.sla import (SlaConfig, SlaScheduler, admission_verdict,
                               min_steps_to_finish, wait_bound)


# ==========================================================================
# Policy units (no model)
# ==========================================================================
def test_sla_config_validates_aging():
    with pytest.raises(ValueError, match="aging_steps"):
        SlaConfig(aging_steps=0)


def test_effective_priority_ages_with_wait():
    s = SlaScheduler(1, sla=SlaConfig(aging_steps=4))
    r = Request(rid=0, prompt=(1,), max_new_tokens=1, arrival_step=10,
                priority=1)
    assert s.effective_priority(r, 10) == 1      # just arrived
    assert s.effective_priority(r, 13) == 1      # 3 waited < aging_steps
    assert s.effective_priority(r, 14) == 2      # one level per 4 steps
    assert s.effective_priority(r, 22) == 4
    assert s.effective_priority(r, 5) == 1       # pre-arrival never negative


def test_head_picks_highest_effective_priority_ties_fifo():
    s = SlaScheduler(1, sla=SlaConfig(aging_steps=100))
    lo = Request(rid=0, prompt=(1,), max_new_tokens=1, priority=0)
    hi = Request(rid=1, prompt=(1,), max_new_tokens=1, priority=2)
    late_hi = Request(rid=2, prompt=(1,), max_new_tokens=1, arrival_step=5,
                      priority=2)
    s.add([lo, hi, late_hi])
    assert s.head(0) is hi                       # priority beats arrival
    assert s.pop_head() is hi                    # pop removes the selection
    assert s.head(0) is lo                       # rid 2 hasn't arrived yet
    assert s.head(6) is late_hi                  # now it has, and outranks
    s.pop_head()
    assert s.head(6) is lo and s.pop_head() is lo
    assert s.head(6) is None
    with pytest.raises(RuntimeError, match="pop_head"):
        s.pop_head()


def test_equal_priorities_replay_fifo_selection():
    sla = SlaScheduler(1, sla=SlaConfig())
    fifo_reqs = [Request(rid=r, prompt=(1,), max_new_tokens=1,
                         arrival_step=a)
                 for r, a in ((3, 0), (1, 0), (2, 1), (0, 2))]
    sla.add(fifo_reqs)
    order = []
    for step in range(4):
        got = sla.head(step)
        if got is not None:
            order.append(sla.pop_head().rid)
    assert order == [1, 3, 2, 0]                 # (arrival_step, rid) FIFO


def test_aging_bounds_wait_under_high_priority_flood():
    sla = SlaConfig(aging_steps=4)
    sched = SlaScheduler(1, sla=sla)
    low = Request(rid=0, prompt=(1,), max_new_tokens=1, priority=0)
    sched.add([low])
    bound = wait_bound(sla, max_priority=2)
    assert bound == 12                           # (2 - 0 + 1) * 4
    admitted_at = None
    for step in range(bound + 1):
        # one fresh high-priority arrival per step, one admission per step
        sched.add([Request(rid=100 + step, prompt=(1,), max_new_tokens=1,
                           arrival_step=step, priority=2)])
        if sched.head(step) is low:
            admitted_at = step
            break
        sched.pop_head()
    assert admitted_at is not None and admitted_at <= bound
    # counterexample: with aging effectively off the same flood starves the
    # low-priority request past any horizon — aging IS the fairness lever
    starved = SlaScheduler(1, sla=SlaConfig(aging_steps=10_000))
    starved.add([Request(rid=0, prompt=(1,), max_new_tokens=1, priority=0)])
    for step in range(200):
        starved.add([Request(rid=100 + step, prompt=(1,), max_new_tokens=1,
                             arrival_step=step, priority=2)])
        assert starved.head(step).rid != 0
        starved.pop_head()
    with pytest.raises(ValueError, match="unbounded"):
        wait_bound(SlaConfig(), max_priority=float("inf"))


def test_min_steps_to_finish_prices_chunked_prefill():
    r = Request(rid=0, prompt=tuple(range(1, 9)), max_new_tokens=3)
    assert min_steps_to_finish(r, chunk=4) == 2 + 2   # 2 chunks + 2 decodes
    assert min_steps_to_finish(r, chunk=16) == 1 + 2  # one-shot prefill
    one = Request(rid=1, prompt=(1,), max_new_tokens=1)
    assert min_steps_to_finish(one, chunk=4) == 1     # prefill emits token 1


def test_admission_verdict_deadline_and_joules():
    table = {"ops_per_token": 10.0, "energy_per_token_j": 1e-9}
    sla = SlaConfig()
    ok = Request(rid=0, prompt=(1, 2, 3), max_new_tokens=2,
                 deadline_steps=50, joule_budget=1e-6)
    assert admission_verdict(ok, 0, 4, table, sla) is None
    late = Request(rid=1, prompt=(1, 2, 3), max_new_tokens=10,
                   deadline_steps=2)
    v = admission_verdict(late, 0, 4, table, sla)
    assert v is not None and "deadline-infeasible" in v
    # waiting in queue eats the deadline: feasible at arrival, not at step 50
    # (earliest finish 50 + 2 - 1 = 51 steps after arrival > deadline 50)
    v2 = admission_verdict(ok, 50, 4, table, sla)
    assert v2 is not None and "deadline-infeasible" in v2
    poor = Request(rid=2, prompt=(1, 2, 3), max_new_tokens=2,
                   joule_budget=3.9e-9)          # min work = 4 tokens = 4nJ
    v3 = admission_verdict(poor, 0, 4, table, sla)
    assert v3 is not None and "joule-infeasible" in v3
    # policy switches gate each check
    off = SlaConfig(admission_deadline=False, admission_energy=False)
    assert admission_verdict(late, 0, 4, table, off) is None
    assert admission_verdict(poor, 0, 4, table, off) is None


# ==========================================================================
# Engine integration (tiny model)
# ==========================================================================
def _cfg():
    return smoke(get_config("qwen1.5-0.5b")).replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("ffn.*", enabled=True, backend="jnp"),)))


ECFG = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    calib = model.calibrate(params, batch, cfg, max_len=48)
    return cfg, params, calib


def _trace(vocab, n=4, seed=0, **sla_fields):
    rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for rid in range(n):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(
                0, vocab, rng.integers(3, 11))),
            max_new_tokens=int(rng.integers(2, 6)),
            arrival_step=arrival, **sla_fields))
        arrival += int(rng.integers(0, 2))
    return reqs


@pytest.fixture(scope="module")
def baseline(served):
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size)
    rep = Engine(cfg, params, ECFG, calib=calib).run(reqs)
    return reqs, rep


def _same_streams(a, b):
    for ra, rb in zip(a.requests, b.requests):
        assert ra["tokens"] == rb["tokens"], (ra, rb)
        assert ra["finish_reason"] == rb["finish_reason"], (ra, rb)
        assert ra["finished_step"] == rb["finished_step"], (ra, rb)
    assert a.steps == b.steps


def test_default_sla_replays_fifo_bit_identically(served, baseline):
    """The acceptance gate: SlaScheduler with every priority at 0 IS plain
    FIFO — enabling the policy without using it changes nothing."""
    cfg, params, calib = served
    reqs, base = baseline
    rep = Engine(cfg, params, ECFG, calib=calib,
                 sla=SlaConfig()).run(reqs)
    _same_streams(base, rep)
    assert rep.compiled_steps == 2
    assert rep.rejected == 0 and rep.over_budget == 0


def test_priority_reorders_admission_not_token_values(served):
    cfg, params, calib = served
    rng = np.random.default_rng(4)
    prompts = [tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 6))
               for _ in range(3)]
    def mk(pri):
        return [Request(rid=i, prompt=p, max_new_tokens=3, priority=pri[i])
                for i, p in enumerate(prompts)]

    solo_ecfg = EngineConfig(slots=1, page_size=4, num_pages=32, chunk=4)
    fifo = Engine(cfg, params, solo_ecfg, calib=calib).run(mk((0, 0, 0)))
    rep = Engine(cfg, params, solo_ecfg, calib=calib,
                 sla=SlaConfig(aging_steps=64)).run(mk((0, 2, 1)))
    by_rid = {r["rid"]: r for r in rep.requests}
    # one slot: service order == admission order == descending priority
    assert (by_rid[1]["admitted_step"] < by_rid[2]["admitted_step"]
            < by_rid[0]["admitted_step"])
    # reordering never changes token VALUES (slots don't couple)
    for base_rec, rec in zip(fifo.requests, rep.requests):
        assert rec["tokens"] == base_rec["tokens"]
        assert rec["priority"] == (0, 2, 1)[rec["rid"]]


def test_deadline_infeasible_rejected_with_zero_compute(served, baseline):
    cfg, params, calib = served
    reqs, base = baseline
    doomed = Request(rid=900, prompt=tuple(range(1, 9)), max_new_tokens=20,
                     deadline_steps=1)
    easy = Request(rid=901, prompt=tuple(range(9, 14)), max_new_tokens=2,
                   deadline_steps=500)
    rep = Engine(cfg, params, ECFG, calib=calib, sla=SlaConfig()).run(
        reqs + [doomed, easy])
    by_rid = {r["rid"]: r for r in rep.requests}
    rej = by_rid[900]
    assert rej["finish_reason"] == "rejected"
    assert "deadline-infeasible" in rej["reject_reason"]
    assert rej["tokens"] == [] and rej["first_token_step"] == -1
    assert rej["analog_ops"] == 0.0 and rej["joules_used"] == 0.0
    assert rej["deadline_hit"] is False
    assert rep.rejected == 1
    # a rejection is admission control working, not a deadline miss
    assert rep.deadline_misses == 0 and rep.deadline_hits == 1
    assert by_rid[901]["deadline_hit"] is True
    # neighbors stream exactly their baseline tokens
    base_by = {r["rid"]: r for r in base.requests}
    for rid, rec in by_rid.items():
        if rid in base_by:
            assert rec["tokens"] == base_by[rid]["tokens"], rid


def test_joule_infeasible_rejected_at_admission(served, baseline):
    cfg, params, calib = served
    reqs, _ = baseline
    eng = Engine(cfg, params, ECFG, calib=calib, sla=SlaConfig())
    e_tok = eng.energy["energy_per_token_j"]
    assert e_tok > 0                             # ffn sites meter
    # budget below the cheapest served outcome (prompt + 1 token)
    poor = Request(rid=900, prompt=tuple(range(1, 7)), max_new_tokens=4,
                   joule_budget=3 * e_tok)
    rep = eng.run(reqs + [poor])
    rec = {r["rid"]: r for r in rep.requests}[900]
    assert rec["finish_reason"] == "rejected"
    assert "joule-infeasible" in rec["reject_reason"]
    assert rec["tokens"] == [] and rec["joules_used"] == 0.0
    assert rep.rejected == 1


def test_over_budget_finishes_gracefully_neighbors_bit_equal(
        served, baseline):
    cfg, params, calib = served
    reqs, base = baseline
    eng = Engine(cfg, params, ECFG, calib=calib, sla=SlaConfig())
    e_tok = eng.energy["energy_per_token_j"]
    prompt = tuple(range(1, 7))
    # passes admission (min work = 7 tokens) but cannot afford its full
    # budget of 6 generated tokens — crosses mid-stream
    capped = Request(rid=900, prompt=prompt, max_new_tokens=6,
                     joule_budget=(len(prompt) + 2.5) * e_tok)
    rep = eng.run(reqs + [capped])
    rec = {r["rid"]: r for r in rep.requests}[900]
    assert rec["finish_reason"] == "over_budget"
    assert 1 <= len(rec["tokens"]) < capped.max_new_tokens
    assert rec["joules_used"] > capped.joule_budget   # the crossing token
    assert rep.over_budget == 1 and rep.rejected == 0
    # the partial stream is a prefix of the request's unbudgeted stream
    free = Engine(cfg, params, ECFG, calib=calib, sla=SlaConfig()).run(
        reqs + [Request(rid=900, prompt=prompt, max_new_tokens=6)])
    free_rec = {r["rid"]: r for r in free.requests}[900]
    assert rec["tokens"] == free_rec["tokens"][:len(rec["tokens"])]
    # neighbors bit-equal to the SLA-less baseline
    base_by = {r["rid"]: r for r in base.requests}
    for r in rep.requests:
        if r["rid"] in base_by:
            assert r["tokens"] == base_by[r["rid"]]["tokens"], r["rid"]


def test_lifo_slot_order_identical_streams_under_sla(served):
    """Slot-permutation invariance survives the SLA policy: selection
    depends on (pending, step), never on physical slot ids."""
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size, n=5, seed=2)
    sla_reqs = [Request(rid=r.rid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens,
                        arrival_step=r.arrival_step, priority=r.rid % 3)
                for r in reqs]
    fifo = Engine(cfg, params, ECFG, calib=calib,
                  sla=SlaConfig(aging_steps=8)).run(sla_reqs)
    lifo_ecfg = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4,
                             slot_order="lifo")
    lifo = Engine(cfg, params, lifo_ecfg, calib=calib,
                  sla=SlaConfig(aging_steps=8)).run(sla_reqs)
    for ra, rb in zip(fifo.requests, lifo.requests):
        assert ra["tokens"] == rb["tokens"]
        assert ra["finish_reason"] == rb["finish_reason"]
        assert ra["finished_step"] == rb["finished_step"]
    assert fifo.steps == lifo.steps
