"""Model-component unit tests: attention (incl. SWA + flash), MoE dispatch,
SSD chunking, rope, and the TD-VMM layer inside blocks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke
from repro.configs.base import TDVMMPlan, tdvmm_rule
from repro.models import attention, common, moe, ssm
from repro.models.ssm import ssd_chunked
from repro.kernels.ssd.ref import ssd_naive


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _attn_cfg(**kw):
    cfg = smoke(get_config("yi-34b"))
    return cfg.replace(**kw) if kw else cfg


def test_flash_matches_dense_attention():
    """The blocked online-softmax path must equal the direct softmax path."""
    cfg = _attn_cfg()
    b, s, h, d = 2, 4096, cfg.n_heads, cfg.resolved_head_dim
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, s, h, d)) * 0.5
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.n_kv_heads, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.n_kv_heads, d))
    out_flash = attention._attend_flash(q, kk, v, cfg)
    mask = attention._causal_mask(s, s, 0, None)
    out_dense = attention._attend(q, kk, v, mask, cfg)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_dense),
                               rtol=2e-3, atol=2e-3)


def test_flash_swa_matches_dense():
    cfg = _attn_cfg(swa_window=1536)
    b, s, h, d = 1, 4096, cfg.n_heads, cfg.resolved_head_dim
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (b, s, h, d)) * 0.5
    kk = jax.random.normal(jax.random.PRNGKey(4), (b, s, cfg.n_kv_heads, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, cfg.n_kv_heads, d))
    out_flash = attention._attend_flash(q, kk, v, cfg)
    mask = attention._causal_mask(s, s, 0, cfg.swa_window)
    out_dense = attention._attend(q, kk, v, mask, cfg)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_dense),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [None, 1024, 1536])
def test_flash_block_skip_matches_dense(window):
    """Perf it.2 path: static tile-pair iteration must be exact, causal + SWA
    (incl. windows not aligned to the block size)."""
    cfg = _attn_cfg(swa_window=window)
    b, s, h, d = 1, 4096, cfg.n_heads, cfg.resolved_head_dim
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, s, h, d)) * 0.5
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.n_kv_heads, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.n_kv_heads, d))
    out_b = attention._attend_flash_blocks(q, kk, v, cfg)
    mask = attention._causal_mask(s, s, 0, window)
    out_d = attention._attend(q, kk, v, mask, cfg)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_d),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("s", [2049, 3000])
def test_flash_non_block_multiple_s(s):
    """Bugfix: S > FLASH_THRESHOLD not divisible by the flash block used to
    hit a trace-time assert; the padded+masked path must match dense."""
    cfg = _attn_cfg()
    b, h, d = 1, cfg.n_heads, cfg.resolved_head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d)) * 0.5
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.n_kv_heads, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.n_kv_heads, d))
    mask = attention._causal_mask(s, s, 0, None)
    out_dense = attention._attend(q, kk, v, mask, cfg)
    out_flash = attention._attend_flash(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_dense),
                               rtol=2e-3, atol=2e-3)
    out_blocks = attention._attend_flash_blocks(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(out_blocks), np.asarray(out_dense),
                               rtol=2e-3, atol=2e-3)


def test_flash_non_block_multiple_s_swa():
    """Same ragged-length fix under a sliding window (padded key tail must
    stay masked when the window mask is also active)."""
    s = 2049
    cfg = _attn_cfg(swa_window=1000)
    b, h, d = 1, cfg.n_heads, cfg.resolved_head_dim
    q = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, d)) * 0.5
    kk = jax.random.normal(jax.random.PRNGKey(4), (b, s, cfg.n_kv_heads, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, cfg.n_kv_heads, d))
    mask = attention._causal_mask(s, s, 0, cfg.swa_window)
    out_dense = attention._attend(q, kk, v, mask, cfg)
    out_flash = attention._attend_flash(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_dense),
                               rtol=2e-3, atol=2e-3)
    out_blocks = attention._attend_flash_blocks(q, kk, v, cfg)
    np.testing.assert_allclose(np.asarray(out_blocks), np.asarray(out_dense),
                               rtol=2e-3, atol=2e-3)


def test_apply_train_odd_length_above_flash_threshold():
    """End-to-end: apply_train at S=2049 routes through flash without the
    old trace-time block-divisibility assert."""
    cfg = _attn_cfg()
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, attention.FLASH_THRESHOLD + 1
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = attention.apply_train(params, x, cfg, positions)
    assert y.shape == (b, s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_decode_past_cache_capacity_rejected():
    """Bugfix: non-SWA decode past max_len used to silently overwrite the
    last KV slot; with concrete positions it must raise."""
    cfg = _attn_cfg()
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    cache = attention.init_cache(cfg, b, max_len=s, dtype=jnp.float32)
    _, cache = attention.apply_prefill(params, x, cfg, cache)
    tok = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model)) * 0.3
    assert int(cache.pos[0]) == s      # cache exactly full
    with pytest.raises(ValueError, match="capacity"):
        attention.apply_decode(params, tok, cfg, cache)


def test_decode_past_cache_capacity_jit_poisons_not_corrupts():
    """Under jit (traced positions) an overflowing row fails loudly — NaN
    output, frozen pos — and leaves the cache bytes untouched."""
    cfg = _attn_cfg()
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    cache = attention.init_cache(cfg, b, max_len=s + 1, dtype=jnp.float32)
    _, cache = attention.apply_prefill(params, x, cfg, cache)
    # row 0 overflows (pos == size), row 1 still has one free slot
    cache = cache._replace(pos=jnp.array([s + 1, s], jnp.int32))
    tok = jax.random.normal(jax.random.PRNGKey(2), (b, 1, cfg.d_model)) * 0.3
    step = jax.jit(lambda p, t, c: attention.apply_decode(p, t, cfg, c))
    y, new_cache = step(params, tok, cache)
    assert bool(jnp.all(jnp.isnan(y[0]))) and bool(jnp.all(jnp.isfinite(y[1])))
    np.testing.assert_array_equal(np.asarray(new_cache.k[0]),
                                  np.asarray(cache.k[0]))
    assert int(new_cache.pos[0]) == s + 1 and int(new_cache.pos[1]) == s + 1


# --------------------------------------------------------------------------
# grouped-projection TD-VMM launches
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_grouped_qkv_matches_sequential_dense(backend):
    """attn.qkv as ONE grouped launch == the three per-projection td_matmul
    calls, bit for bit (matching data-calibrated windows)."""
    cfg = _attn_cfg().replace(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("attn.qkv", enabled=True, backend=backend),)))
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model)) * 0.3
    td = cfg.site_tdvmm("attn.qkv")
    grouped = common.dense_group(
        (params["wq"], params["wk"], params["wv"]), x, td)
    for got, name in zip(grouped, ("wq", "wk", "wv")):
        seq = common.dense(params[name], x, td)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(seq))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_grouped_ssm_project_matches_sequential_dense(backend):
    """ssm.in_proj's five projections as ONE grouped launch == five
    sequential td_matmul calls, bit for bit (uneven N: z/x are d_inner wide,
    B/C are n_groups*d_state, dt is n_heads)."""
    cfg = smoke(get_config("mamba2-1.3b")).replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("ssm.in_proj", enabled=True, backend=backend),)))
    params = ssm.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.d_model)) * 0.3
    td = cfg.site_tdvmm("ssm.in_proj")
    grouped = ssm._project(params, u, cfg, None)
    widths = {y.shape[-1] for y in grouped}
    assert len(grouped) == 5 and len(widths) > 1   # genuinely uneven N
    for got, name in zip(grouped, ("wz", "wx", "wB", "wC", "wdt")):
        seq = common.dense(params[name], u, td)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(seq))


def test_grouped_qkv_train_grads_match_sequential():
    """QAT gradients through the grouped launch equal the sequential path."""
    cfg = _attn_cfg().replace(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("attn.qkv", enabled=True, backend="jnp"),)))
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model)) * 0.3
    td = cfg.site_tdvmm("attn.qkv")
    names = ("wq", "wk", "wv")

    def loss_grouped(p, x_):
        ys = common.dense_group(tuple(p[n] for n in names), x_, td)
        return sum(jnp.sum(y ** 2) for y in ys)

    def loss_seq(p, x_):
        return sum(jnp.sum(common.dense(p[n], x_, td) ** 2) for n in names)

    g1, gx1 = jax.grad(loss_grouped, argnums=(0, 1))(params, x)
    g2, gx2 = jax.grad(loss_seq, argnums=(0, 1))(params, x)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               rtol=1e-5, atol=1e-6)
    for n in names:
        np.testing.assert_allclose(np.asarray(g1[n]["w"]),
                                   np.asarray(g2[n]["w"]),
                                   rtol=1e-5, atol=1e-6)


def test_swa_ring_buffer_decode():
    """Decode with a rolling window cache == full attention restricted to the
    last `window` tokens."""
    cfg = _attn_cfg(swa_window=8)
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, 20
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    # reference: full-sequence SWA attention, last position
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    ref = attention.apply_train(params, x, cfg, positions)[:, -1]
    # decode path: prefill s-1 then one decode step
    cache = attention.init_cache(cfg, b, max_len=s, dtype=jnp.float32)
    _, cache = attention.apply_prefill(params, x[:, :-1], cfg, cache)
    out, cache = attention.apply_decode(params, x[:, -1:], cfg, cache)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert int(cache.pos[0]) == s


def test_ragged_decode_positions():
    """Per-sequence cache positions: two sequences decoding at different
    offsets must match their aligned single-sequence runs."""
    cfg = _attn_cfg()
    params = attention.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, 5, cfg.d_model)) * 0.3
    x2 = jax.random.normal(jax.random.PRNGKey(2), (1, 9, cfg.d_model)) * 0.3
    tok = jax.random.normal(jax.random.PRNGKey(3), (2, 1, cfg.d_model)) * 0.3

    def single(xp, t):
        c = attention.init_cache(cfg, 1, 16, jnp.float32)
        _, c = attention.apply_prefill(params, xp, cfg, c)
        y, _ = attention.apply_decode(params, t, cfg, c)
        return y

    y1 = single(x1, tok[:1])
    y2 = single(x2, tok[1:])
    # batched ragged: merge caches at different positions
    c = attention.init_cache(cfg, 2, 16, jnp.float32)
    c1 = attention.init_cache(cfg, 1, 16, jnp.float32)
    _, c1 = attention.apply_prefill(params, x1, cfg, c1)
    c2 = attention.init_cache(cfg, 1, 16, jnp.float32)
    _, c2 = attention.apply_prefill(params, x2, cfg, c2)
    c = attention.KVCache(
        k=c.k.at[0].set(c1.k[0]).at[1].set(c2.k[0]),
        v=c.v.at[0].set(c1.v[0]).at[1].set(c2.v[0]),
        pos=jnp.array([5, 9], jnp.int32))
    y, _ = attention.apply_decode(params, tok, cfg, c)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y1[0]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(y2[0]), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_rope_relative_property(seed):
    """<rope(q,p), rope(k,p+d)> depends only on d (relative positions)."""
    d = 32
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (1, 1, 1, d))
    kk = jax.random.normal(jax.random.split(k)[0], (1, 1, 1, d))
    def dot_at(p0, p1):
        qp = common.apply_rope(q, jnp.array([[p0]]), 10000.0)
        kp = common.apply_rope(kk, jnp.array([[p1]]), 10000.0)
        return float(jnp.sum(qp * kp))
    assert dot_at(3, 7) == pytest.approx(dot_at(103, 107), rel=1e-4)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def _moe_cfg(**kw):
    cfg = smoke(get_config("mixtral-8x7b"))
    if kw:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, **kw))
    return cfg


def test_moe_dispatch_combine_identity():
    """With no drops, dispatch->identity-experts->combine == weighted passthrough."""
    cfg = _moe_cfg(capacity_factor=64.0)
    m = cfg.moe
    x = jax.random.normal(jax.random.PRNGKey(0), (64, cfg.d_model))
    ids = jax.random.randint(jax.random.PRNGKey(1), (64, m.top_k), 0, m.n_experts)
    gates = jnp.full((64, m.top_k), 1.0 / m.top_k)
    cap = moe._capacity(64, m.top_k, m.n_experts, 64.0)
    se, pos, order, tok = moe._dispatch_indices(ids, m.top_k)
    buf = moe._scatter_to_buffer(x, se, pos, tok, m.n_experts, cap)
    y = moe._gather_from_buffer(buf, se, pos, order, gates, m.top_k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5, atol=1e-6)


def test_moe_capacity_drops_are_zero():
    """Dropped tokens contribute zero (not garbage) to the combined output."""
    cfg = _moe_cfg(capacity_factor=0.01)    # tiny capacity -> mass dropping
    params = moe.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe.apply(params, x, cfg)
    assert jnp.all(jnp.isfinite(y))
    # with capacity ~4 slots/expert most tokens drop; norm must shrink
    cfg_big = _moe_cfg(capacity_factor=64.0)
    y_big, _ = moe.apply(params, x, cfg_big)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_big))


def test_moe_load_balance_loss_uniform_is_one():
    """LB loss == E * sum(me*ce) -> 1.0 for perfectly uniform routing."""
    cfg = _moe_cfg()
    t, e, k = 1024, cfg.moe.n_experts, cfg.moe.top_k
    probs = jnp.full((t, e), 1.0 / e)
    me = probs.mean(0)
    ids = jnp.stack([(jnp.arange(t) + i) % e for i in range(k)], 1)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(ids, e), axis=1), axis=0)
    lb = e * jnp.sum(me * ce)
    assert float(lb) == pytest.approx(k, rel=1e-5)


def test_moe_grads_flow_to_experts_and_router():
    cfg = _moe_cfg(capacity_factor=8.0)
    params = moe.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe.apply(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["experts"]["w_up"])) > 0
    assert float(jnp.linalg.norm(g["router"]["w"])) > 0


def test_int8_kv_cache_decode_close_to_full():
    """Perf it.9: int8 KV cache decode must track the full-precision forward."""
    from repro.models import model
    attention.set_kv_cache_int8(True)
    try:
        cfg = smoke(get_config("yi-34b"))
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        b, s = 2, 12
        inputs = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
        full, _ = model.forward(params, {"inputs": inputs, "targets": inputs}, cfg)
        caches = model.init_caches(cfg, b, max_len=s)
        assert caches["seg0"].k.dtype == jnp.int8
        _, caches = model.prefill_step(params, {"inputs": inputs[:, :-1]}, caches, cfg)
        dec, _ = model.decode_step(params, {"inputs": inputs[:, -1:]}, caches, cfg)
        err = float(jnp.max(jnp.abs(full[:, -1] - dec[:, 0])))
        assert err < 0.15, err
    finally:
        attention.set_kv_cache_int8(False)


# --------------------------------------------------------------------------
# SSD
# --------------------------------------------------------------------------
def test_ssd_chunked_equals_naive():
    b, l, h, p, g, s = 2, 64, 4, 16, 2, 8
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(keys[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, h))) * 0.1
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = jax.random.normal(keys[2], (b, l, g, s)) * 0.3
    cc = jax.random.normal(keys[3], (b, l, g, s)) * 0.3
    y1, f1 = ssd_chunked(x, dt, a_log, bb, cc, 16)
    y2, f2 = ssd_naive(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4, atol=1e-5)


def test_ssm_decode_matches_prefill():
    cfg = smoke(get_config("mamba2-1.3b"))
    params = ssm.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, 16
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    y_full = ssm.apply_train(params, u, cfg)
    cache = ssm.init_cache(cfg, b, jnp.float32)
    _, cache = ssm.apply_prefill(params, u[:, :-1], cfg, cache)
    y_dec, cache = ssm.apply_decode(params, u[:, -1:], cfg, cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    assert int(cache.pos[0]) == s
