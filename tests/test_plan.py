"""Site-addressable TD-VMM plans: resolution, per-site settings, the legacy
single-config shim, and declared time-domain chaining.

Contract under test (ISSUE 3 acceptance criteria):
  * a plan giving different bits/backend/out_scale to ``attn.qkv``,
    ``ffn.*`` and ``head`` resolves and runs all three sites with their own
    settings;
  * legacy ``ModelConfig.tdvmm``-only configs resolve every site to that
    config and produce bit-identical outputs to an explicit plan carrying
    the same default (the deprecation shim);
  * ``chain=True`` on ``ffn.in`` drops the intermediate p-bit readout
    (``io_quantize=False`` upstream, validated at resolve time).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import model_sites, resolve_plan
from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, TDVMMLayerConfig, TDVMMPlan,
    tdvmm_rule)
from repro.core import calibration
from repro.models import model


def _dense_cfg(**kw):
    base = dict(name="plan-test", family="dense", n_layers=1, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                vocab_pad_multiple=16, dtype="float32", remat_policy="none")
    base.update(kw)
    return ModelConfig(**base)


def _batch(cfg, b=2, s=8, seed=0):
    return {"inputs": jax.random.randint(
        jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)}


# --------------------------------------------------------------------------
# Site naming + config hygiene
# --------------------------------------------------------------------------
def test_model_sites_by_family():
    assert model_sites(_dense_cfg()) == (
        "attn.qkv", "attn.out", "ffn.in", "ffn.out", "head")
    assert "head" not in model_sites(_dense_cfg(tie_embeddings=True))
    moe = _dense_cfg(family="moe", moe=MoEConfig(
        n_experts=4, top_k=2, d_ff=32, n_shared_experts=1, first_k_dense=1))
    assert model_sites(moe) == (
        "attn.qkv", "attn.out", "ffn.in", "ffn.out",
        "moe.expert.in", "moe.expert.out", "moe.shared.in", "moe.shared.out",
        "head")
    ssm = _dense_cfg(family="ssm", ssm=SSMConfig(d_state=16, head_dim=16))
    assert model_sites(ssm) == ("ssm.in_proj", "ssm.out", "head")
    hyb = _dense_cfg(family="hybrid", ssm=SSMConfig(d_state=16, head_dim=16),
                     hybrid_attn_every=2, hybrid_concat_embed=True)
    assert model_sites(hyb) == (
        "ssm.in_proj", "ssm.out", "attn.qkv", "attn.out", "ffn.in",
        "ffn.out", "hybrid.fuse", "head")


def test_layer_config_hashable_and_jit_static():
    """Satellite: TDVMMSpec is a frozen, hashable field — resolved site
    configs key caches and pass as jit-static arguments."""
    a, b = TDVMMLayerConfig(), TDVMMLayerConfig()
    assert a == b and hash(a) == hash(b)
    assert {a: 1}[b] == 1          # usable as a dict/cache key

    f = jax.jit(lambda x, cfg: x * cfg.bits, static_argnums=1)
    assert float(f(jnp.float32(2.0), a)) == 12.0
    # per-expert window tuples stay hashable too
    assert hash(a.replace(out_scale=(0.5, 0.25))) is not None


def test_rule_validation_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown TDVMMLayerConfig field"):
        tdvmm_rule("ffn.*", bitz=7)


# --------------------------------------------------------------------------
# Per-site settings (acceptance criterion 1)
# --------------------------------------------------------------------------
def test_plan_resolves_and_runs_per_site_settings():
    plan = TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True, backend="jnp"),
        tdvmm_rule("attn.qkv", bits=5),
        tdvmm_rule("ffn.*", bits=7, backend="pallas"),
        tdvmm_rule("head", bits=4, out_scale=0.3),
    ))
    cfg = _dense_cfg(tdvmm_plan=plan)
    rp = resolve_plan(cfg)
    assert rp["attn.qkv"].bits == 5 and rp["attn.qkv"].backend == "jnp"
    assert rp["ffn.in"].bits == 7 and rp["ffn.in"].backend == "pallas"
    assert rp["ffn.out"].bits == 7 and rp["ffn.out"].backend == "pallas"
    assert rp["head"].bits == 4 and rp["head"].out_scale == 0.3
    assert rp["attn.out"].bits == 6            # default rule only
    assert all(c.site == s for s, c in rp.sites)

    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, _ = model.forward(params, batch, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # every site actually executed with its own config: the calibration
    # collector is keyed by resolved site name, and each site's window
    # reflects its own code grid — changing one site's bits changes only
    # that site's codes.
    caches = model.init_caches(cfg, 2, 8)
    with calibration.collect() as col:
        model.prefill_step(params, batch, caches, cfg)
    assert set(col) == {"attn.qkv", "attn.out", "ffn.in", "ffn.out", "head"}

    # and the settings are *load-bearing*: a uniform-bits plan differs
    uniform = _dense_cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True, backend="jnp"),)))
    logits_u, _ = model.forward(params, batch, uniform)
    assert not np.array_equal(np.asarray(logits), np.asarray(logits_u))


# --------------------------------------------------------------------------
# Legacy shim (acceptance criterion 2)
# --------------------------------------------------------------------------
def test_legacy_tdvmm_only_config_is_plan_default():
    td = TDVMMLayerConfig(enabled=True, bits=6, backend="jnp")
    legacy = _dense_cfg(tdvmm=td)                      # no plan at all
    empty_plan = legacy.replace(tdvmm_plan=TDVMMPlan())
    explicit = legacy.replace(tdvmm_plan=TDVMMPlan(default=td))

    # structural parity: every site resolves to the legacy config
    for cfg in (legacy, empty_plan, explicit):
        for site, resolved in resolve_plan(cfg).sites:
            assert resolved == td.replace(site=site), (site, resolved)

    # numeric parity: identical logits bit for bit
    params = model.init_params(jax.random.PRNGKey(1), legacy)
    batch = _batch(legacy)
    ref, _ = model.forward(params, batch, legacy)
    for cfg in (empty_plan, explicit):
        got, _ = model.forward(params, batch, cfg)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_disabled_default_keeps_digital_model_exact():
    """A no-plan, disabled-tdvmm config must stay the plain digital model."""
    cfg = _dense_cfg()
    params = model.init_params(jax.random.PRNGKey(2), cfg)
    batch = _batch(cfg)
    ref, _ = model.forward(params, batch, cfg)
    got, _ = model.forward(params, batch, cfg.replace(tdvmm_plan=TDVMMPlan()))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# --------------------------------------------------------------------------
# Declared time-domain chaining (acceptance criterion 3)
# --------------------------------------------------------------------------
def test_chained_ffn_skips_intermediate_readout():
    base_rules = (tdvmm_rule("*", enabled=True, backend="jnp"),)
    chained = _dense_cfg(tdvmm_plan=TDVMMPlan(
        rules=base_rules + (tdvmm_rule("ffn.in", chain=True),)))
    unchained = _dense_cfg(tdvmm_plan=TDVMMPlan(rules=base_rules))
    manual = _dense_cfg(tdvmm_plan=TDVMMPlan(
        rules=base_rules + (tdvmm_rule("ffn.in", io_quantize=False),)))

    rp = resolve_plan(chained)
    assert rp.chains == (("ffn.in", "ffn.out"),)
    assert rp["ffn.in"].io_quantize is False
    assert rp["ffn.out"].io_quantize is True
    # one fewer digital (p-bit readout) boundary than the unchained plan
    assert (rp.report()["n_digital_boundaries"]
            == resolve_plan(unchained).report()["n_digital_boundaries"] - 1)
    assert "analog" in rp.report()["sites"]["ffn.in"]["boundary"]

    params = model.init_params(jax.random.PRNGKey(3), chained)
    batch = _batch(chained)
    y_chain, _ = model.forward(params, batch, chained)
    y_plain, _ = model.forward(params, batch, unchained)
    y_manual, _ = model.forward(params, batch, manual)
    # dropping the ffn.in ADC boundary changes the numerics...
    assert not np.array_equal(np.asarray(y_chain), np.asarray(y_plain))
    # ...and is exactly the io_quantize=False rewrite, nothing more
    np.testing.assert_array_equal(np.asarray(y_chain), np.asarray(y_manual))


def test_chain_validation_errors():
    # not an adjacent tile pair
    cfg = _dense_cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True),
        tdvmm_rule("attn.qkv", chain=True))))
    with pytest.raises(ValueError, match="no adjacent downstream tile"):
        resolve_plan(cfg)
    # both ends must be TD-VMM-enabled
    cfg = _dense_cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("ffn.in", enabled=True, chain=True),)))
    with pytest.raises(ValueError, match="enabled on both sites"):
        resolve_plan(cfg)
    # downstream tile must exist in the model
    ssm_cfg = _dense_cfg(family="ssm", ssm=SSMConfig(d_state=16, head_dim=16),
                         tdvmm_plan=TDVMMPlan(rules=(
                             tdvmm_rule("*", enabled=True),
                             tdvmm_rule("ssm.in_proj", chain=True))))
    with pytest.raises(ValueError, match="no adjacent downstream tile"):
        resolve_plan(ssm_cfg)


def test_chained_moe_experts():
    cfg = _dense_cfg(
        family="moe", moe=MoEConfig(n_experts=4, top_k=2, d_ff=32),
        tdvmm_plan=TDVMMPlan(rules=(
            tdvmm_rule("moe.*", enabled=True, backend="jnp"),
            tdvmm_rule("moe.expert.in", chain=True))))
    rp = resolve_plan(cfg)
    assert rp.chains == (("moe.expert.in", "moe.expert.out"),)
    params = model.init_params(jax.random.PRNGKey(4), cfg)
    logits, _ = model.forward(params, _batch(cfg), cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_unmatched_rules_reported_and_strict_raises():
    rules = (tdvmm_rule("*", enabled=True),
             tdvmm_rule("atn.qkv", bits=4),          # typo'd pattern
             tdvmm_rule("moe.*", backend="pallas"))  # no moe sites on dense
    rp = resolve_plan(_dense_cfg(tdvmm_plan=TDVMMPlan(rules=rules)))
    assert rp.unmatched == ("atn.qkv", "moe.*")
    assert rp.report()["unmatched_rules"] == ["atn.qkv", "moe.*"]
    with pytest.raises(ValueError, match="match no site"):
        resolve_plan(_dense_cfg(
            tdvmm_plan=TDVMMPlan(rules=rules, strict=True)))


def test_resolution_is_cached():
    cfg = _dense_cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True),)))
    assert resolve_plan(cfg) is resolve_plan(dataclasses.replace(cfg))
