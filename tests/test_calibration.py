"""Model-wide TD-VMM calibration: capture, serving parity, persistence.

Contract under test:
  * ``models.model.calibrate`` captures per-site scalar windows and
    per-expert ``(E,)`` vector windows in one prefill pass;
  * calibrated decode is bit-for-bit identical to per-call
    ``output_calibration`` when the captured window equals the per-call one
    (single-matmul sites, one layer — the window IS the per-call max);
  * ``CalibrationState`` checkpoint round-trips (scalar + ``(E,)`` leaves)
    through checkpoint/checkpoint.py;
  * per-expert ``(E,)`` windows reach ``td_expert_matmul``'s fused epilogue
    (jnp and Pallas bit-for-bit).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.configs.base import (
    ModelConfig, MoEConfig, SSMConfig, TDVMMLayerConfig, TDVMMPlan,
    tdvmm_rule)
from repro.core import calibration
from repro.core.calibration import CalibrationState, apply_calibration
from repro.core.layers import (
    calibrate_out_scale, td_expert_matmul, td_matmul)
from repro.models import model


def _cfg(**kw):
    base = dict(name="calib-test", family="dense", n_layers=1, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                vocab_pad_multiple=16, dtype="float32", remat_policy="none")
    base.update(kw)
    return ModelConfig(**base)


def _batch(cfg, b=2, s=8, seed=0):
    return {"inputs": jax.random.randint(
        jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)}


def test_calibrate_captures_scalar_and_expert_windows():
    cfg = _cfg(family="moe", moe=MoEConfig(n_experts=4, top_k=2, d_ff=32),
               tdvmm_plan=TDVMMPlan(rules=(
                   tdvmm_rule("*", enabled=True, backend="jnp"),)))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    calib = model.calibrate(params, _batch(cfg), cfg)
    assert calib.sites() == ("attn.out", "attn.qkv", "head",
                             "moe.expert.in", "moe.expert.out")
    for site, w in calib.windows.items():
        if site.startswith("moe.expert"):
            expected = (4,)            # one window per expert tile
        elif site == "attn.qkv":
            expected = (3,)            # grouped launch: wq/wk/wv tiles
        else:
            expected = ()
        assert w.shape == expected, (site, w.shape)
        assert bool(jnp.all(w > 0.0))


def test_calibrate_skips_chained_and_disabled_sites():
    cfg = _cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("ffn.*", enabled=True, backend="jnp"),
        tdvmm_rule("ffn.in", chain=True))))
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    calib = model.calibrate(params, _batch(cfg), cfg)
    # ffn.in is analog (chained: no readout boundary to calibrate); attn and
    # head have TD-VMM off entirely.
    assert calib.sites() == ("ffn.out",)


def test_apply_calibration_bakes_site_windows():
    cfg = _cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True, backend="jnp"),)))
    calib = CalibrationState(windows={
        "head": jnp.float32(0.25),
        "moe.expert.in": jnp.asarray([0.5, 0.125], jnp.float32)})
    baked = apply_calibration(cfg, calib)
    assert baked.site_tdvmm("head").out_scale == 0.25
    assert baked.site_tdvmm("moe.expert.in").out_scale == (0.5, 0.125)
    assert baked.site_tdvmm("ffn.in").out_scale is None      # untouched
    assert apply_calibration(cfg, None) is cfg


def test_calibrated_decode_bit_for_bit_with_per_call_window():
    """Serve-path parity: when the pinned window equals the window per-call
    ``output_calibration`` would compute (single-matmul sites, one layer,
    windows captured on the very decode step under test), calibrated decode
    is bit-for-bit identical to the uncalibrated path."""
    # ffn.out and head are single-matmul sites: one td_matmul call per step,
    # so the captured site max IS the per-call data-calibrated window.
    cfg = _cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("ffn.out", enabled=True, backend="jnp"),
        tdvmm_rule("head", enabled=True, backend="jnp"))))
    params = model.init_params(jax.random.PRNGKey(2), cfg)
    caches = model.init_caches(cfg, 2, 16)
    _, caches = model.prefill_step(params, _batch(cfg), caches, cfg)
    tok = {"inputs": jnp.full((2, 1), 3, jnp.int32)}

    with calibration.collect() as col:
        ref, _ = model.decode_step(params, tok, caches, cfg)
    calib = CalibrationState.from_collected(col)
    assert calib.sites() == ("ffn.out", "head")

    got, _ = model.decode_step(params, tok, caches, cfg, calib=calib)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    # and prefill over the capture batch matches the same way
    with calibration.collect() as col2:
        pref, _ = model.prefill_step(
            params, _batch(cfg), model.init_caches(cfg, 2, 16), cfg)
    calib2 = CalibrationState.from_collected(col2)
    pgot, _ = model.prefill_step(
        params, _batch(cfg), model.init_caches(cfg, 2, 16), cfg,
        calib=calib2)
    np.testing.assert_array_equal(np.asarray(pref), np.asarray(pgot))


def test_calibrated_decode_runs_under_jit_closure():
    cfg = _cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True, backend="jnp"),)))
    params = model.init_params(jax.random.PRNGKey(3), cfg)
    calib = model.calibrate(params, _batch(cfg), cfg, max_len=16)
    caches = model.init_caches(cfg, 2, 16)
    prefill = jax.jit(
        lambda p, b, c: model.prefill_step(p, b, c, cfg, calib=calib))
    decode = jax.jit(
        lambda p, b, c: model.decode_step(p, b, c, cfg, calib=calib))
    logits, caches = prefill(params, _batch(cfg), caches)
    logits, _ = decode(params, {"inputs": jnp.zeros((2, 1), jnp.int32)}, caches)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_expert_vector_window_matches_per_call_calibration():
    """Satellite: td_expert_matmul with a captured (E,)-vector out_scale is
    bit-for-bit the per-call (per-expert-tile) data calibration, on both
    backends."""
    e, c, k, n = 3, 8, 48, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (e, c, k))
    w = jax.random.normal(jax.random.PRNGKey(5), (e, k, n)) * 0.2
    base = TDVMMLayerConfig(enabled=True, backend="jnp",
                            site="moe.expert.in")
    with calibration.collect() as col:
        ref = td_expert_matmul(x, w, base)       # per-call per-expert window
    windows = tuple(float(v) for v in col["moe.expert.in"])
    assert len(windows) == e
    for backend in ("jnp", "pallas"):
        got = td_expert_matmul(
            x, w, base.replace(backend=backend, out_scale=windows))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_expert_window_length_mismatch_raises():
    x = jnp.ones((3, 4, 32))
    w = jnp.ones((3, 32, 8))
    cfg = TDVMMLayerConfig(enabled=True, backend="jnp", out_scale=(0.5, 0.5))
    with pytest.raises(ValueError, match="windows for 3 experts"):
        td_expert_matmul(x, w, cfg)
    from repro.core.layers import td_matmul
    with pytest.raises(ValueError, match="per-expert"):
        td_matmul(jnp.ones((4, 32)), w[0], cfg)


def test_calibration_state_checkpoint_roundtrip(tmp_path):
    calib = CalibrationState(windows={
        "attn.qkv": jnp.float32(0.75),
        "ffn.out": jnp.float32(0.125),
        "moe.expert.in": jnp.asarray([0.5, 0.25, 0.125, 1.0], jnp.float32),
    })
    checkpoint.save_calibration(calib, tmp_path, step=7)
    assert checkpoint.latest_calibration_step(tmp_path) == 7
    restored, step = checkpoint.restore_calibration(calib, tmp_path)
    assert step == 7
    assert isinstance(restored, CalibrationState)
    assert restored.sites() == calib.sites()
    for site in calib.windows:
        np.testing.assert_array_equal(
            np.asarray(calib.windows[site]), np.asarray(restored.windows[site]))
    # restored state is directly servable: bake it into a config
    cfg = _cfg(family="moe", moe=MoEConfig(n_experts=4, top_k=2, d_ff=32))
    baked = apply_calibration(cfg, restored)
    assert baked.site_tdvmm("moe.expert.in").out_scale == (0.5, 0.25, 0.125, 1.0)


def test_nested_collect_rejected():
    with calibration.collect():
        with pytest.raises(RuntimeError, match="nested"):
            with calibration.collect():
                pass


# --------------------------------------------------------------------------
# grouped sites (attn.qkv / ssm.in_proj): one (G,) window per launch
# --------------------------------------------------------------------------
def test_grouped_attn_qkv_calibration_roundtrip():
    """attn.qkv captures ONE (3,) per-member window vector (not 3 max-merged
    scalars), and pinning it reproduces the per-call data-calibrated decode
    bit for bit."""
    cfg = _cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("attn.qkv", enabled=True, backend="jnp"),)))
    params = model.init_params(jax.random.PRNGKey(4), cfg)
    caches = model.init_caches(cfg, 2, 16)
    _, caches = model.prefill_step(params, _batch(cfg), caches, cfg)
    tok = {"inputs": jnp.full((2, 1), 5, jnp.int32)}

    with calibration.collect() as col:
        ref, _ = model.decode_step(params, tok, caches, cfg)
    calib = CalibrationState.from_collected(col)
    assert calib.sites() == ("attn.qkv",)
    assert calib.windows["attn.qkv"].shape == (3,)

    got, _ = model.decode_step(params, tok, caches, cfg, calib=calib)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    baked = apply_calibration(cfg, calib)
    assert baked.site_tdvmm("attn.qkv").out_scale == tuple(
        float(v) for v in calib.windows["attn.qkv"])


def test_grouped_ssm_in_proj_calibration_roundtrip():
    """ssm.in_proj captures a (5,) vector (z/x/B/C/dt tiles) whose pinned
    form reproduces the per-call data-calibrated prefill bit for bit."""
    cfg = _cfg(family="ssm", ssm=SSMConfig(d_state=16, head_dim=32),
               tdvmm_plan=TDVMMPlan(rules=(
                   tdvmm_rule("ssm.in_proj", enabled=True, backend="jnp"),)))
    params = model.init_params(jax.random.PRNGKey(5), cfg)

    with calibration.collect() as col:
        ref, _ = model.prefill_step(
            params, _batch(cfg), model.init_caches(cfg, 2, 16), cfg)
    calib = CalibrationState.from_collected(col)
    assert calib.sites() == ("ssm.in_proj",)
    assert calib.windows["ssm.in_proj"].shape == (5,)

    got, _ = model.prefill_step(
        params, _batch(cfg), model.init_caches(cfg, 2, 16), cfg, calib=calib)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_apply_calibration_rejects_wrong_group_width():
    cfg = _cfg(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("*", enabled=True, backend="jnp"),)))
    calib = CalibrationState(windows={
        "attn.qkv": jnp.asarray([0.5, 0.25], jnp.float32)})  # 2 != 3 members
    with pytest.raises(ValueError, match="3-member"):
        apply_calibration(cfg, calib)


# --------------------------------------------------------------------------
# noisy serving configs: calibrate_out_scale must see the noisy codes
# --------------------------------------------------------------------------
def test_calibrate_out_scale_threads_noise_key():
    """Satellite bugfix: a window calibrated for a noisy deploy config must
    be captured over the *noisy* programmed codes — the same max|z| the
    noisy serving path data-calibrates — not the noise-free ones."""
    cfg = TDVMMLayerConfig(enabled=True, backend="jnp", noise=True,
                           site="noisy.site")
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 24)) * 0.2
    key = jax.random.PRNGKey(2)

    clean = calibrate_out_scale(x, w, cfg)            # key=None: noise-free
    noisy = calibrate_out_scale(x, w, cfg, key=key)
    assert noisy != clean

    # the noisy window is exactly what the noisy serving call would
    # data-calibrate (same cfg, same key)
    with calibration.collect() as col:
        td_matmul(x, w, cfg, key=key)
    assert noisy == pytest.approx(float(col["noisy.site"]), rel=0, abs=0)
