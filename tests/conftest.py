"""Shared test fixtures and dependency gating.

``hypothesis`` is an optional test dependency (the ``test`` extra in
pyproject.toml).  Environments without it — e.g. a bare container with only
jax — would otherwise fail *collection* of every module that property-tests.
This shim keeps those modules importable: ``@given`` tests skip cleanly,
every plain test in the same module still runs.
"""
from __future__ import annotations

import importlib.util
import sys
import types

if importlib.util.find_spec("hypothesis") is None:
    def _given(*_a, **_k):
        def deco(fn):
            # Zero-arg on purpose (and no functools.wraps: pytest would follow
            # __wrapped__ back to the parametrized signature and demand
            # fixtures for the strategy arguments).
            def wrapper():
                import pytest
                pytest.skip("hypothesis not installed (pip install '.[test]')")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return lambda *a, **k: None

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
