"""Per-kernel validation: shape/dtype sweeps + allclose vs pure-jnp oracles.

Kernels run in interpret mode (Python execution of the kernel body) on CPU;
on TPU the same pallas_call compiles to Mosaic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.crossing.crossing import crossing_kernel
from repro.kernels.crossing.ref import crossing_ref
from repro.kernels.ssd.ref import ssd_naive
from repro.kernels.ssd.ssd import ssd_kernel
from repro.kernels.tdvmm.ref import tdvmm_matmul_ref
from repro.kernels.tdvmm.tdvmm import (
    autotune_blocks, pad_to_blocks, tdvmm_fused_kernel, tdvmm_matmul_kernel)
from repro.models.ssm import ssd_chunked


# --------------------------------------------------------------------------
# tdvmm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (128, 256, 128, 128, 128, 128),
    (256, 1024, 256, 128, 512, 128),
    (128, 128, 384, 64, 128, 128),
    (512, 512, 128, 256, 256, 64),
])
def test_tdvmm_shapes(m, k, n, bm, bk, bn):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + n))
    xq = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wq = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    out = tdvmm_matmul_kernel(xq, wq, bm=bm, bk=bk, bn=bn, interpret=True)
    ref = tdvmm_matmul_ref(xq, wq, jnp.ones((m,)), jnp.ones((n,)), 1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_tdvmm_bit_widths(bits):
    lv = (1 << bits) - 1
    kx, kw = jax.random.split(jax.random.PRNGKey(bits))
    xq = jnp.round(jax.random.uniform(kx, (128, 256), minval=-lv, maxval=lv))
    wq = jnp.round(jax.random.uniform(kw, (256, 128), minval=-lv, maxval=lv))
    out = tdvmm_matmul_kernel(xq, wq, interpret=True)
    ref = jnp.dot(xq, wq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
    # integer-exactness: charge sums are exact in f32 up to 2^24
    assert float(jnp.max(jnp.abs(out - jnp.round(out)))) == 0.0


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (128, 256, 128, 128, 128, 128),
    (256, 1024, 256, 128, 512, 128),
    (64, 512, 128, 32, 256, 128),
])
def test_tdvmm_int8_kernel_exact(m, k, n, bm, bk, bn):
    """int8 codes -> int32 accumulation: exact vs int64 numpy."""
    rng = np.random.default_rng(m + n)
    xq = rng.integers(-127, 128, (m, k), dtype=np.int8)
    wq = rng.integers(-127, 128, (k, n), dtype=np.int8)
    out = tdvmm_matmul_kernel(jnp.asarray(xq), jnp.asarray(wq),
                              bm=bm, bk=bk, bn=bn, interpret=True)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(out), xq.astype(np.int64) @ wq.astype(np.int64))


def test_tdvmm_int8_kernel_exact_beyond_f32_envelope():
    """Saturated codes drive |acc| past 2^24 onto odd values no f32 holds —
    the int32 path must still be exact."""
    k = 2048
    xq = np.full((32, k), 127, np.int8)
    wq = np.full((k, 128), 127, np.int8)
    wq[0, 0] = 126
    exact = xq.astype(np.int64) @ wq.astype(np.int64)
    assert np.max(exact) > (1 << 24) and int(exact[0, 0]) % 2 == 1
    out = tdvmm_matmul_kernel(jnp.asarray(xq), jnp.asarray(wq), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), exact)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float32])
def test_tdvmm_batched_expert_grid(dtype):
    """(E, M, K) x (E, K, N) batched grid vs per-expert einsum."""
    e, m, k, n = 3, 64, 256, 128
    rng = np.random.default_rng(e)
    xq = rng.integers(-63, 64, (e, m, k)).astype(dtype)
    wq = rng.integers(-63, 64, (e, k, n)).astype(dtype)
    out = tdvmm_matmul_kernel(jnp.asarray(xq), jnp.asarray(wq), interpret=True)
    exact = np.einsum("emk,ekn->emn", xq.astype(np.int64), wq.astype(np.int64))
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), exact)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float32])
def test_tdvmm_fused_kernel_matches_oracle(dtype):
    """Fused gain+readout+rescale epilogue vs the pure-jnp oracle."""
    e, m, k, n = 2, 64, 256, 128
    rng = np.random.default_rng(7)
    xq = rng.integers(-63, 64, (e, m, k)).astype(dtype)
    wq = rng.integers(-63, 64, (e, k, n)).astype(dtype)
    xs = rng.uniform(0.5, 2.0, (e, m)).astype(np.float32)
    ws = rng.uniform(0.5, 2.0, (e, n)).astype(np.float32)
    gain, out_bits, out_scale = 1e-4, 6, 0.5
    got = tdvmm_fused_kernel(
        jnp.asarray(xq), jnp.asarray(wq),
        jnp.asarray(xs)[..., :, None], jnp.asarray(ws)[..., None, :],
        gain=gain, out_bits=out_bits, out_scale=out_scale, interpret=True)
    assert got.dtype == jnp.float32
    ref = tdvmm_matmul_ref(jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(xs),
                           jnp.asarray(ws), gain=gain, out_bits=out_bits,
                           out_scale=out_scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float32])
def test_tdvmm_shared_x_grouped_grid(dtype):
    """(1, M, K) x (G, K, N) shared-input grouped grid: one code copy feeds
    every group tile, exactly equal to the per-tile einsum."""
    g, m, k, n = 4, 64, 256, 128
    rng = np.random.default_rng(11)
    xq = rng.integers(-63, 64, (1, m, k)).astype(dtype)
    wq = rng.integers(-63, 64, (g, k, n)).astype(dtype)
    out = tdvmm_matmul_kernel(jnp.asarray(xq), jnp.asarray(wq), interpret=True)
    exact = np.einsum("mk,gkn->gmn", xq[0].astype(np.int64),
                      wq.astype(np.int64))
    assert out.shape == (g, m, n)
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), exact)


def test_tdvmm_shared_x_ops_matches_sequential():
    """ops.tdvmm_matmul with 2-D x against a (G, K, N) bank == the G
    sequential 2-D launches, bit for bit, on both backends and with scalar,
    per-member, and data-calibrated readout windows."""
    from repro.kernels.tdvmm import ops
    g, m, k, n = 3, 33, 96, 40
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    xq = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wq = jnp.round(jax.random.uniform(kw, (g, k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(jax.random.PRNGKey(1), (m,), minval=0.5, maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(2), (g, n), minval=0.5, maxval=2.0)
    for out_bits, out_scale in [(None, None), (6, 0.5),
                                (6, (0.5, 0.25, 1.0)), (6, None)]:
        for backend in ("jnp", "pallas"):
            got = ops.tdvmm_matmul(xq, wq, xs, ws, gain=1e-4,
                                   out_bits=out_bits, out_scale=out_scale,
                                   backend=backend)
            assert got.shape == (g, m, n)
            for i in range(g):
                s = out_scale[i] if isinstance(out_scale, tuple) else out_scale
                seq = ops.tdvmm_matmul(xq, wq[i], xs, ws[i], gain=1e-4,
                                       out_bits=out_bits, out_scale=s,
                                       backend=backend)
                np.testing.assert_array_equal(np.asarray(got[i]),
                                              np.asarray(seq))


def test_tdvmm_shared_x_vjp_sums_over_group():
    """The shared input's cotangent accumulates over all G tiles (matching
    G independent matmuls that share x)."""
    from repro.kernels.tdvmm import ops
    g, m, k, n = 3, 16, 48, 24
    xq = jnp.round(jax.random.uniform(jax.random.PRNGKey(3), (m, k),
                                      minval=-31, maxval=31))
    wq = jnp.round(jax.random.uniform(jax.random.PRNGKey(4), (g, k, n),
                                      minval=-31, maxval=31))
    xs = jnp.ones((m,))
    ws = jnp.ones((g, n))

    def grouped(x_, w_):
        return jnp.sum(ops.tdvmm_matmul(x_, w_, xs, ws, gain=1e-3,
                                        backend="jnp") ** 2)

    def sequential(x_, w_):
        return sum(jnp.sum(ops.tdvmm_matmul(x_, w_[i], xs, ws[i], gain=1e-3,
                                            backend="jnp") ** 2)
                   for i in range(g))

    gx, gw = jax.grad(grouped, argnums=(0, 1))(xq, wq)
    gx2, gw2 = jax.grad(sequential, argnums=(0, 1))(xq, wq)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw2),
                               rtol=1e-6, atol=1e-6)


def test_tdvmm_batched_x_w_mismatch_raises():
    from repro.kernels.tdvmm import ops
    with pytest.raises(ValueError, match="shared-x"):
        ops.tdvmm_matmul(jnp.ones((2, 8, 16)), jnp.ones((3, 16, 8)),
                         jnp.ones((2, 8)), jnp.ones((3, 8)), backend="jnp")


def test_autotune_table_and_padding_alignment():
    """Autotuned blocks are always launchable after pad_to_blocks, and int8
    padding respects the (32, 128) minimum tile."""
    for (m, k, n) in [(512, 1024, 4096), (100, 300, 50), (8, 128, 64),
                      (1, 1, 1)]:
        for dtype in (jnp.int8, jnp.float32):
            bm, bk, bn = autotune_blocks(m, k, n, dtype)
            x = jnp.zeros((m, k), dtype)
            w = jnp.zeros((k, n), dtype)
            xp, wp = pad_to_blocks(x, w, bm, bk, bn)
            mp, kp = xp.shape
            np_ = wp.shape[1]
            sub = 32 if dtype == jnp.int8 else 8
            assert mp % sub == 0 and kp % 128 == 0 and np_ % 128 == 0
            for dim, blk in [(mp, bm), (kp, bk), (np_, bn)]:
                assert dim % min(blk, dim) == 0
    # int8 heuristic doubles the K block at equal VMEM bytes
    assert autotune_blocks(999, 4096, 999, jnp.int8)[1] == \
        2 * autotune_blocks(999, 4096, 999, jnp.float32)[1]


@pytest.mark.parametrize("k", [96, 95, 1])
def test_tdvmm_int4_ops_matches_int8(k):
    """Nibble-packed launches (p <= 3 codes, two per byte, unpacked in-VMEM)
    are bit-for-bit identical to int8 — including odd K, where the pack pads
    a zero nibble that integrates zero charge."""
    from repro.kernels.tdvmm import ops
    m, n = 17, 40
    kx, kw = jax.random.split(jax.random.PRNGKey(k))
    xq = jnp.round(jax.random.uniform(kx, (m, k), minval=-7, maxval=7)
                   ).astype(jnp.int8)
    wq = jnp.round(jax.random.uniform(kw, (k, n), minval=-7, maxval=7)
                   ).astype(jnp.int8)
    xs = jax.random.uniform(jax.random.PRNGKey(1), (m,), minval=0.5,
                            maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.5,
                            maxval=2.0)
    for out_bits, out_scale in [(None, None), (6, 0.5), (6, None)]:
        ref = ops.tdvmm_matmul(xq, wq, xs, ws, gain=1e-3, out_bits=out_bits,
                               out_scale=out_scale, backend="jnp")
        for code_dtype in ("int8", "int4"):
            got = ops.tdvmm_matmul(xq, wq, xs, ws, gain=1e-3,
                                   out_bits=out_bits, out_scale=out_scale,
                                   backend="pallas", code_dtype=code_dtype)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                          err_msg=f"{code_dtype} {out_scale}")


def test_tdvmm_ragged_group_widths_matches_sequential():
    """A ragged concat launch (group_widths) equals the per-member 2-D
    launches bit for bit on both backends, for scalar, per-member-tuple,
    and data-calibrated readout windows."""
    from repro.kernels.tdvmm import ops
    m, k, widths = 9, 64, (128, 64)
    n = sum(widths)
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    xq = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
    wq = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(jax.random.PRNGKey(6), (m,), minval=0.5,
                            maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(7), (n,), minval=0.5,
                            maxval=2.0)
    # bn=64 divides every member width, so calibrated slots land on member
    # boundaries — the same invariant layers.td_grouped_matmul keeps via gcd.
    blocks = (64, 64, 64)
    for out_bits, out_scale in [(None, None), (6, 0.5), (6, (0.5, 0.25)),
                                (6, None)]:
        for backend in ("jnp", "pallas"):
            got = ops.tdvmm_matmul(xq, wq, xs, ws, gain=1e-4,
                                   out_bits=out_bits, out_scale=out_scale,
                                   backend=backend, block_sizes=blocks,
                                   group_widths=widths)
            off = 0
            for i, wd in enumerate(widths):
                s = out_scale[i] if isinstance(out_scale, tuple) else out_scale
                seq = ops.tdvmm_matmul(
                    xq, wq[:, off:off + wd], xs, ws[off:off + wd],
                    gain=1e-4, out_bits=out_bits, out_scale=s,
                    backend=backend, block_sizes=blocks)
                np.testing.assert_array_equal(
                    np.asarray(got[:, off:off + wd]), np.asarray(seq),
                    err_msg=f"{backend} member {i} window {out_scale}")
                off += wd


def test_tdvmm_fused_calibration_matches_unfused():
    """The two-phase calibrated kernel (max|z| folded into the accumulator
    walk, one launch, one HBM write) is bit-for-bit with the legacy two-pass
    path and with the jnp oracle — batched experts included."""
    from repro.kernels.tdvmm import ops
    e, m, k, n = 2, 33, 96, 40
    kx, kw = jax.random.split(jax.random.PRNGKey(8))
    xq = jnp.round(jax.random.uniform(kx, (e, m, k), minval=-63, maxval=63))
    wq = jnp.round(jax.random.uniform(kw, (e, k, n), minval=-63, maxval=63))
    xs = jax.random.uniform(jax.random.PRNGKey(9), (e, m), minval=0.5,
                            maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(10), (e, n), minval=0.5,
                            maxval=2.0)
    kwargs = dict(gain=1e-4, out_bits=6, out_scale=None)
    fused = ops.tdvmm_matmul(xq, wq, xs, ws, backend="pallas",
                             fused_calibration=True, **kwargs)
    unfused = ops.tdvmm_matmul(xq, wq, xs, ws, backend="pallas",
                               fused_calibration=False, **kwargs)
    oracle = ops.tdvmm_matmul(xq, wq, xs, ws, backend="jnp", **kwargs)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(oracle))


# --------------------------------------------------------------------------
# crossing
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,k,n", [(1, 32, 128), (4, 64, 128), (2, 128, 256)])
def test_crossing_shapes(b, k, n):
    kt, kc = jax.random.split(jax.random.PRNGKey(b * k + n))
    t_on = jax.random.uniform(kt, (b, k), maxval=1.0)
    cur = jax.random.uniform(kc, (k, n), minval=0.01, maxval=1.0)
    charge = float(0.3 * k)
    got = crossing_kernel(t_on, cur, charge, t_hi=2.0, iters=30, interpret=True)
    ref = crossing_ref(t_on, cur, charge)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.floats(0.05, 0.9))
def test_crossing_bisection_converges(seed, frac):
    """Property: bisection resolves the exact (sort-based) crossing to the
    bisection tolerance for random currents/charges."""
    k, n = 32, 128
    kt, kc = jax.random.split(jax.random.PRNGKey(seed))
    t_on = jax.random.uniform(kt, (2, k), maxval=1.0)
    cur = jax.random.uniform(kc, (k, n), minval=0.05, maxval=1.0)
    charge = float(frac * 0.5 * k)
    got = crossing_kernel(t_on, cur, charge, t_hi=2.0, iters=32, interpret=True)
    ref = crossing_ref(t_on, cur, charge)
    assert float(jnp.max(jnp.abs(got - ref))) < 2.0 / (1 << 30) + 1e-6


# --------------------------------------------------------------------------
# ssd
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,l,h,p,g,s,chunk", [
    (2, 64, 4, 16, 2, 8, 16),
    (1, 128, 2, 32, 1, 16, 32),
    (2, 32, 8, 8, 8, 8, 8),     # G == H (no grouping)
    (1, 64, 4, 64, 1, 64, 64),  # full-width tiles
])
def test_ssd_shapes(b, l, h, p, g, s, chunk):
    keys = jax.random.split(jax.random.PRNGKey(l + h), 5)
    x = jax.random.normal(keys[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, h))) * 0.1
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = jax.random.normal(keys[2], (b, l, g, s)) * 0.3
    cc = jax.random.normal(keys[3], (b, l, g, s)) * 0.3
    yk = ssd_kernel(x, dt, a_log, bb, cc, chunk=chunk, interpret=True)
    yn, _ = ssd_naive(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yn),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_chunked_jnp():
    """Kernel vs the pjit-path chunked implementation (must be identical
    algebra, so tolerance is tight)."""
    b, l, h, p, g, s = 2, 128, 4, 16, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(keys[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, h))) * 0.1
    a_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    bb = jax.random.normal(keys[2], (b, l, g, s)) * 0.3
    cc = jax.random.normal(keys[3], (b, l, g, s)) * 0.3
    yk = ssd_kernel(x, dt, a_log, bb, cc, chunk=32, interpret=True)
    yc, _ = ssd_chunked(x, dt, a_log, bb, cc, 32)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yc),
                               rtol=1e-5, atol=1e-5)


def test_ssd_state_carry_across_chunks():
    """Chunk boundaries must be invisible: chunk=L vs chunk=L/4 agree."""
    b, l, h, p, g, s = 1, 64, 2, 16, 1, 8
    keys = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(keys[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, l, h))) * 0.1
    a_log = jnp.zeros((h,))
    bb = jax.random.normal(keys[2], (b, l, g, s)) * 0.3
    cc = jax.random.normal(keys[3], (b, l, g, s)) * 0.3
    y1 = ssd_kernel(x, dt, a_log, bb, cc, chunk=64, interpret=True)
    y2 = ssd_kernel(x, dt, a_log, bb, cc, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
