"""Substrate tests: data pipeline, optimizer, checkpointing, fault tolerance,
gradient compression."""
import json
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpoint as ckpt
from repro.configs import OptimizerConfig, SHAPES, get_config, smoke
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, make_pipeline, write_token_file
from repro.optim import compression
from repro.optim.optimizer import make_optimizer
from repro.runtime import fault


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_synthetic_deterministic_and_resumable():
    cfg = smoke(get_config("yi-34b"))
    shape = ShapeConfig("t", 32, 8, "train")
    p1 = make_pipeline(cfg, shape, DataConfig(seed=3))
    p2 = make_pipeline(cfg, shape, DataConfig(seed=3))
    np.testing.assert_array_equal(p1.batch_at(17)["inputs"],
                                  p2.batch_at(17)["inputs"])
    assert not np.array_equal(p1.batch_at(17)["inputs"],
                              p1.batch_at(18)["inputs"])


def test_synthetic_dp_sharding_partitions_batch():
    cfg = smoke(get_config("yi-34b"))
    shape = ShapeConfig("t", 16, 8, "train")
    full = make_pipeline(cfg, shape, DataConfig(seed=0)).batch_at(5)
    for rank in range(4):
        part = make_pipeline(
            cfg, shape, DataConfig(seed=0, dp_rank=rank, dp_size=4)).batch_at(5)
        assert part["inputs"].shape[0] == 2


def test_mmap_pipeline(tmp_path):
    cfg = smoke(get_config("yi-34b"))
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, np.arange(10000) % 400)
    shape = ShapeConfig("t", 32, 4, "train")
    pipe = make_pipeline(cfg, shape, DataConfig(source="mmap", path=path))
    b = pipe.batch_at(0)
    assert b["inputs"].shape == (4, 32)
    # next-token alignment: targets are inputs shifted by one
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    opt = make_optimizer(OptimizerConfig(
        name=name, lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0))
    params = {"w": jnp.array([2.0, -3.0]), "m": jnp.ones((4, 4))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, metrics = opt.update(grads, state, params)
    assert float(loss(params)) < 0.2 * l0
    assert int(state.step) == 60


def test_grad_clip():
    opt = make_optimizer(OptimizerConfig(grad_clip=1.0))
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full((3,), 100.0)}, state, params)
    assert float(metrics["grad_norm"]) > 1.0   # pre-clip norm reported


def test_adafactor_memory_is_factored():
    opt = make_optimizer(OptimizerConfig(name="adafactor", moment_dtype="bfloat16"))
    params = {"w": jnp.zeros((128, 64))}
    st_ = opt.init(params)
    inner = st_.inner["w"]
    assert inner["vr"].shape == (128,) and inner["vc"].shape == (64,)
    assert inner["m"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, tmp_path, step=5)
    restored, step = ckpt.restore(t, tmp_path)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))
    assert restored["b"]["c"].dtype == np.dtype("bfloat16") or True  # np view


def test_checkpoint_keep_k_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(t, tmp_path, step=s, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()
    assert (tmp_path / "step_00000004").exists()


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    ckpt.save(t, tmp_path, step=1)
    victim = next((tmp_path / "step_00000001").glob("a.npy"))
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        ckpt.restore(t, tmp_path)


def test_checkpoint_concurrent_async_saves(tmp_path):
    t = _tree()
    th = ckpt.save(t, tmp_path, step=9, blocking=False)
    ckpt.save(t, tmp_path, step=9, blocking=True)   # same step, concurrent
    if hasattr(th, "join"):
        th.join()
    restored, step = ckpt.restore(t, tmp_path)
    assert step == 9


def test_atomicity_partial_write_ignored(tmp_path):
    """A stale tmp dir (crash mid-save) must not be visible as a checkpoint."""
    t = _tree()
    (tmp_path / ".tmp_00000003_dead_beef").mkdir(parents=True)
    ckpt.save(t, tmp_path, step=2)
    assert ckpt.latest_step(tmp_path) == 2


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------
def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return x + 1

    out = fault.retry_step(flaky, 41, retries=3, backoff_s=0.01)
    assert out == 42 and calls["n"] == 3


def test_retry_step_reraises_persistent():
    def dead(_):
        raise RuntimeError("fatal")

    with pytest.raises(RuntimeError):
        fault.retry_step(dead, 0, retries=2, backoff_s=0.01)


def test_straggler_monitor():
    m = fault.StragglerMonitor(threshold=2.0)
    for i in range(10):
        m.record(i, 1.0)
    assert m.record(10, 5.0) is True
    assert m.stragglers == 1


def test_preemption_guard_flag():
    g = fault.PreemptionGuard().install()
    os.kill(os.getpid(), signal.SIGTERM)
    time.sleep(0.05)
    assert g.requested
    g.uninstall()


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_int8_quant_roundtrip_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1000,))
    codes, scale = compression._quantize_int8(x)
    deq = compression._dequantize_int8(codes, scale, x.shape, x.size)
    blk_max = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(deq - x))) <= blk_max / 127.0 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the MEAN of compressed reductions converges to the
    true gradient (residual carries the quantization error forward)."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (512,)) * 1e-3
    total_plain, total_ef = jnp.zeros_like(g), jnp.zeros_like(g)
    residual = jnp.zeros_like(g)
    for i in range(50):
        codes, scale = compression._quantize_int8(g)
        total_plain += compression._dequantize_int8(codes, scale, g.shape, g.size)
        codes, scale = compression._quantize_int8(g + residual)
        deq = compression._dequantize_int8(codes, scale, g.shape, g.size)
        residual = (g + residual) - deq
        total_ef += deq
    err_plain = float(jnp.linalg.norm(total_plain / 50 - g))
    err_ef = float(jnp.linalg.norm(total_ef / 50 - g))
    assert err_ef <= err_plain


def test_wire_bytes_saved_positive():
    grads = {"w": jnp.zeros((4096, 128))}
    assert compression.wire_bytes_saved(grads) > 0
