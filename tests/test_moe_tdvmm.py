"""MoE expert matmuls through the QuantizedTensor path.

Contract: with ``cfg.tdvmm.enabled`` every expert einsum in models/moe.py
executes via core/layers.td_expert_matmul — the batched (E, C, K) x (E, K, N)
TD-VMM kernel grid, one analog tile per expert — honoring the backend knob
(jnp and Pallas-interpret bit-for-bit identical on the int8 code path), and
staying exact under capacity padding (ragged expert batches are all-zero
code rows = zero charge).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, ModelConfig, TDVMMLayerConfig
from repro.core.layers import td_expert_matmul
from repro.models import moe


def _cfg(backend="jnp", **td_kw):
    return ModelConfig(
        name="moe-tiny", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, act="silu_glu", dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=48),
        tdvmm=TDVMMLayerConfig(enabled=True, backend=backend, **td_kw))


# --------------------------------------------------------------------------
# td_expert_matmul: the batched layer primitive
# --------------------------------------------------------------------------
def test_td_expert_matmul_disabled_is_plain_einsum():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 10, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 8))
    cfg = TDVMMLayerConfig(enabled=False)
    np.testing.assert_array_equal(
        np.asarray(td_expert_matmul(x, w, cfg)),
        np.asarray(jnp.einsum("eck,ekn->ecn", x, w)))


@pytest.mark.parametrize("shape", [(4, 11, 40, 24), (2, 128, 96, 32)])
def test_td_expert_matmul_backend_parity(shape):
    e, c, k, n = shape
    x = jax.random.normal(jax.random.PRNGKey(2), (e, c, k))
    w = jax.random.normal(jax.random.PRNGKey(3), (e, k, n)) * 0.2
    cfg = TDVMMLayerConfig(enabled=True, backend="jnp")
    y_jnp = td_expert_matmul(x, w, cfg)
    y_pal = td_expert_matmul(x, w, cfg.replace(backend="pallas"))
    assert y_jnp.shape == (e, c, n)
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_pal))


def test_td_expert_matmul_precision_band():
    """Per-expert ~6-bit TD-VMM error stays in the paper's ~2% band."""
    e, c, k, n = 3, 16, 128, 24
    x = jax.random.normal(jax.random.PRNGKey(4), (e, c, k))
    w = jax.random.normal(jax.random.PRNGKey(5), (e, k, n)) * 0.1
    exact = jnp.einsum("eck,ekn->ecn", x, w)
    for backend in ("jnp", "pallas"):
        y = td_expert_matmul(x, w, TDVMMLayerConfig(enabled=True,
                                                    backend=backend))
        rel = float(jnp.max(jnp.abs(y - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.05, (backend, rel)


def test_td_expert_matmul_ragged_and_empty():
    """Capacity padding: experts with zero assigned tokens (all-zero rows)
    are exact, and degenerate empty batches don't crash on either backend."""
    e, c, k, n = 4, 8, 64, 16
    x = jax.random.normal(jax.random.PRNGKey(6), (e, c, k))
    # expert 0 fully idle; expert 2 half-filled — the sort-based dispatch
    # zero-pads exactly like this
    x = x.at[0].set(0.0)
    x = x.at[2, 4:].set(0.0)
    w = jax.random.normal(jax.random.PRNGKey(7), (e, k, n)) * 0.2
    cfg = TDVMMLayerConfig(enabled=True, backend="jnp")
    outs = {}
    for backend in ("jnp", "pallas"):
        y = td_expert_matmul(x, w, cfg.replace(backend=backend))
        outs[backend] = np.asarray(y)
        # zero rows in -> exactly zero rows out (zero codes, zero charge)
        assert np.all(outs[backend][0] == 0.0)
        assert np.all(outs[backend][2, 4:] == 0.0)
    np.testing.assert_array_equal(outs["jnp"], outs["pallas"])
    # empty capacity / empty expert stack
    for backend in ("jnp", "pallas"):
        y0 = td_expert_matmul(jnp.zeros((e, 0, k)), w,
                              cfg.replace(backend=backend))
        assert y0.shape == (e, 0, n)
        y1 = td_expert_matmul(jnp.zeros((0, c, k)), jnp.zeros((0, k, n)),
                              cfg.replace(backend=backend))
        assert y1.shape == (0, c, n)


def test_td_expert_matmul_gradients_flow():
    e, c, k, n = 2, 8, 48, 12
    x = jax.random.normal(jax.random.PRNGKey(8), (e, c, k))
    w = jax.random.normal(jax.random.PRNGKey(9), (e, k, n)) * 0.2

    def loss(x, w, backend):
        cfg = TDVMMLayerConfig(enabled=True, backend=backend)
        return jnp.sum(jnp.square(td_expert_matmul(x, w, cfg)))

    gj = jax.grad(loss, argnums=(0, 1))(x, w, "jnp")
    gp = jax.grad(loss, argnums=(0, 1))(x, w, "pallas")
    for g in gj:
        assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.linalg.norm(g)) > 0
    for a, b in zip(gj, gp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# full MoE layer: backend knob honored end to end
# --------------------------------------------------------------------------
def test_moe_apply_backend_parity():
    cfg = _cfg("jnp")
    params = moe.init(jax.random.PRNGKey(10), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 8, cfg.d_model))
    y_jnp, aux_j = moe.apply(params, x, cfg)
    y_pal, aux_p = moe.apply(params, x, _cfg("pallas"))
    assert y_jnp.shape == x.shape
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_pal))
    np.testing.assert_allclose(float(aux_j["lb_loss"]), float(aux_p["lb_loss"]))


def test_moe_apply_quantized_tracks_dense_reference():
    """6-bit expert FFNs should stay within a loose band of the unquantized
    MoE output (quantization error compounds over up/gate/down projections)."""
    cfg = _cfg("jnp")
    params = moe.init(jax.random.PRNGKey(12), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 8, cfg.d_model)) * 0.5
    y_q, _ = moe.apply(params, x, cfg)
    y_ref, _ = moe.apply(params, x, cfg.replace(tdvmm=TDVMMLayerConfig(
        enabled=False)))
    err = float(jnp.linalg.norm(y_q - y_ref) / jnp.maximum(
        jnp.linalg.norm(y_ref), 1e-9))
    assert err < 0.25, err


def test_moe_apply_noise_key_threads_to_experts():
    """Train-time programming noise must reach the expert matmuls: with
    noise=True and a key, outputs differ from the noise-free run (and from a
    different key), without one, noise is off and results are reproducible."""
    cfg = _cfg("jnp", noise=True)
    params = moe.init(jax.random.PRNGKey(16), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(17), (2, 8, cfg.d_model))
    y_clean, _ = moe.apply(params, x, cfg)
    y_clean2, _ = moe.apply(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y_clean), np.asarray(y_clean2))
    y_n1, _ = moe.apply(params, x, cfg, key=jax.random.PRNGKey(0))
    y_n2, _ = moe.apply(params, x, cfg, key=jax.random.PRNGKey(1))
    assert not np.array_equal(np.asarray(y_n1), np.asarray(y_clean))
    assert not np.array_equal(np.asarray(y_n1), np.asarray(y_n2))
    assert bool(jnp.all(jnp.isfinite(y_n1)))


def test_moe_apply_with_shared_experts_and_calibration_cache():
    """Shared experts route through the same batched path; a cached readout
    window (serving config) keeps the layer functional."""
    base = _cfg("jnp")
    cfg = base.replace(moe=MoEConfig(n_experts=4, top_k=2, d_ff=48,
                                     n_shared_experts=1),
                       tdvmm=base.tdvmm.replace(out_scale=0.25))
    params = moe.init(jax.random.PRNGKey(14), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(15), (2, 8, cfg.d_model))
    y, aux = moe.apply(params, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    y_pal, _ = moe.apply(params, x, cfg.replace(
        tdvmm=cfg.tdvmm.replace(backend="pallas")))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_pal))
