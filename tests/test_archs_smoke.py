"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU; assert output shapes and no NaNs.  Full configs are exercised only via
the dry-run (launch/dryrun.py, ShapeDtypeStruct — no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, smoke
from repro.models import model


def _batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    targets = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"inputs": inputs, "targets": targets}


@pytest.fixture(params=sorted(ARCHS))
def arch(request):
    return request.param


def test_forward_shapes_no_nan(arch):
    cfg = smoke(get_config(arch))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch, cfg)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not jnp.any(jnp.isnan(logits)), f"NaNs in {arch} logits"


def test_train_step_loss_finite(arch):
    cfg = smoke(get_config(arch))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), f"{arch} grad norm not finite"
    assert gnorm > 0, f"{arch} gradients are all zero"


def test_prefill_then_decode(arch):
    cfg = smoke(get_config(arch))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    caches = model.init_caches(cfg, b, max_len=32)
    logits, caches = model.prefill_step(params, batch, caches, cfg)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert not jnp.any(jnp.isnan(logits))
    if cfg.input_mode == "tokens":
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
        step_in = {"inputs": tok}
    else:
        step_in = {"inputs": jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model))}
    logits2, caches = model.decode_step(params, step_in, caches, cfg)
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert not jnp.any(jnp.isnan(logits2))


def test_decode_matches_prefill(arch):
    """Token-by-token decode must agree with a full prefill (cache correctness).

    MoE archs run with a no-drop capacity factor here: capacity dropping is
    batch-composition-dependent by construction (tested in test_models_moe),
    and would mask cache bugs with routing noise."""
    import dataclasses
    cfg = smoke(get_config(arch))
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 8
    batch = _batch(cfg, b, s)
    # full forward logits at last position
    full_logits, _ = model.forward(params, batch, cfg)
    # prefill s-1 tokens, then decode token s-1
    if cfg.input_mode == "tokens":
        pre = {"inputs": batch["inputs"][:, : s - 1]}
        last = {"inputs": batch["inputs"][:, s - 1:]}
    else:
        pre = {"inputs": batch["inputs"][:, : s - 1]}
        last = {"inputs": batch["inputs"][:, s - 1:]}
    caches = model.init_caches(cfg, b, max_len=s)
    _, caches = model.prefill_step(params, pre, caches, cfg)
    dec_logits, _ = model.decode_step(params, last, caches, cfg)
    import numpy as np
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1]), np.asarray(dec_logits[:, 0]),
        rtol=2e-2, atol=2e-2)
