"""Request-level tracing & per-site analog attribution.

Hard contracts under test:

  * a traced engine run is **bit-identical** to an untraced one (streams,
    finish reasons, finish steps) with ``compiled_steps == 2`` — tracing is
    pure host-side bookkeeping between the two compiled programs;
  * the exported Chrome trace is schema-valid (balanced stack-disciplined
    ``B``/``E`` spans, monotonic timestamps per (pid, tid), int pid/tid)
    and its span boundaries / finish markers carry exactly the engine step
    ids ``EngineReport`` reports;
  * ``EngineReport.site_attribution`` sums **bit-exactly**: the plain
    left-to-right sum over the per-site table reproduces ``analog_ops`` /
    ``analog_energy_j`` / ``fj_per_op`` with zero float slack, and a
    chained plan's saved inter-site I/O is explicit per site;
  * the tracer's span/clock state rides ``Engine.snapshot()`` (meta v4):
    kill + restore + resume yields ONE continuous schema-valid trace;
  * ``DriftConfig.observe_every`` streams per-site ``clip_rate.<site>``
    series into the sink, so a threshold ``AlertRule`` fires on injected
    drift BEFORE any recalibration runs — and stays quiet on a clean run;
  * ``JsonlEmitter`` durability: ``report()`` and the preemption
    snapshot-and-exit path flush+fsync, so the JSONL is complete on disk
    without ``close()``.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.core import energy as energy_model
from repro.models import model
from repro.runtime import faultinject as fi
from repro.runtime.engine import (DriftConfig, Engine, EngineConfig,
                                  FaultConfig, Request)
from repro.runtime.telemetry import AlertRule, JsonlEmitter, MetricsSink
from repro.runtime.trace import (ENGINE_PID, REQUEST_PID, Tracer,
                                 validate_chrome_trace)


@pytest.fixture(autouse=True, scope="module")
def _f32_mode():
    # test_tdcore flips jax_enable_x64 process-wide at import time, and
    # pytest collection imports every module before any test runs — so in
    # a full-suite run this module would execute under x64.  The drift
    # clip-rate constants below are calibrated against the engine's
    # default-f32 numerics; pin the flag for this module and restore.
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    yield
    jax.config.update("jax_enable_x64", old)


def _cfg():
    return smoke(get_config("qwen1.5-0.5b")).replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("ffn.*", enabled=True, backend="jnp"),)))


ECFG = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    calib = model.calibrate(params, batch, cfg, max_len=48)
    return cfg, params, calib, batch


def _trace(vocab, n=4, seed=0):
    rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for rid in range(n):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(
                0, vocab, rng.integers(3, 11))),
            max_new_tokens=int(rng.integers(2, 6)),
            arrival_step=arrival))
        arrival += int(rng.integers(0, 2))
    return reqs


@pytest.fixture(scope="module")
def traced(served):
    """(requests, untraced report, traced report, the tracer)."""
    cfg, params, calib, _ = served
    reqs = _trace(cfg.vocab_size)
    plain = Engine(cfg, params, ECFG, calib=calib).run(reqs)
    tr = Tracer()
    rep = Engine(cfg, params, ECFG, calib=calib, tracer=tr).run(reqs)
    return reqs, plain, rep, tr


def _same_streams(a, b):
    for ra, rb in zip(a.requests, b.requests):
        assert ra["tokens"] == rb["tokens"], (ra, rb)
        assert ra["finish_reason"] == rb["finish_reason"], (ra, rb)
        assert ra["finished_step"] == rb["finished_step"], (ra, rb)
    assert a.steps == b.steps


# ==========================================================================
# validate_chrome_trace: schema rejection cases (pure unit)
# ==========================================================================
def test_validator_rejects_malformed_documents():
    ok = {"ph": "X", "name": "t", "pid": 0, "tid": 0, "ts": 1.0, "dur": 2.0}
    with pytest.raises(ValueError, match="no traceEvents"):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace([{**ok, "ph": "Z"}])
    with pytest.raises(ValueError, match="pid/tid must be ints"):
        validate_chrome_trace([{**ok, "tid": "r0"}])
    with pytest.raises(ValueError, match="pid/tid must be ints"):
        validate_chrome_trace([{**ok, "pid": True}])
    with pytest.raises(ValueError, match="ts must be numeric"):
        validate_chrome_trace([{**ok, "ts": None}])
    with pytest.raises(ValueError, match="regresses"):
        validate_chrome_trace([ok, {**ok, "ts": 0.5}])
    with pytest.raises(ValueError, match="needs dur"):
        validate_chrome_trace([{**ok, "dur": -1.0}])
    b = {"ph": "B", "name": "s", "pid": 1, "tid": 7, "ts": 0}
    with pytest.raises(ValueError, match="E without open B"):
        validate_chrome_trace([{**b, "ph": "E"}])
    with pytest.raises(ValueError, match="does not match open B"):
        validate_chrome_trace([b, {**b, "ph": "E", "name": "other",
                                   "ts": 1}])
    with pytest.raises(ValueError, match="unbalanced B spans"):
        validate_chrome_trace([b])
    # distinct tids are independent tracks: same names, interleaved, fine
    counts = validate_chrome_trace([
        b, {**b, "tid": 8}, {**b, "tid": 8, "ph": "E", "ts": 1},
        {**b, "ph": "E", "ts": 2}])
    assert counts == {"B": 2, "E": 2}


def test_tracer_soft_cap_drops_only_droppable_events():
    tr = Tracer(max_events=4)          # 3 metadata events pre-fill it
    tr.note_arrival(0, step=0)         # span boundaries always land
    tr.admitted(0, step=0, sid=0, dp_rank=0, pages=1)
    tr.mark_chunk(0, index=0, tokens=4, done=True, step=0)
    tr.tick_done(0, dt=0.01, counters={"queue_depth": 0.0})
    tr.finished(0, step=1, reason="completed")
    tr.tick_done(1, dt=0.01)
    assert tr.dropped >= 2             # the X slice + the counter sample
    counts = validate_chrome_trace(tr.chrome_trace())
    assert counts["B"] == counts["E"] == 3 and "X" not in counts
    with pytest.raises(ValueError, match="max_events"):
        Tracer(max_events=0)


def test_tracer_snapshot_json_round_trip_and_restore_guard():
    a = Tracer()
    a.note_arrival(3, step=0)
    a.admitted(3, step=1, sid=0, dp_rank=0, pages=2)
    a.tick_done(1, dt=0.5)
    b = Tracer()
    b.restore(json.loads(json.dumps(a.snapshot())))   # plain-JSON payload
    assert b.events == a.events and b.clock_us == a.clock_us
    # the restored tracer continues the SAME open span stack
    b.finished(3, step=2, reason="completed")
    assert validate_chrome_trace(b.chrome_trace())["E"] == \
        validate_chrome_trace(a.chrome_trace())["E"]
    with pytest.raises(ValueError, match="not a Tracer snapshot"):
        Tracer().restore({"bogus": 1})


# ==========================================================================
# Traced engine run: purity, schema, span/report cross-checks
# ==========================================================================
def test_traced_run_bit_identical_with_two_compiled_steps(traced):
    _, plain, rep, _ = traced
    _same_streams(plain, rep)
    assert rep.compiled_steps == 2
    assert plain.trace_summary is None and rep.trace_summary is not None


def test_trace_schema_valid_and_spans_match_report(traced):
    _, _, rep, tr = traced
    doc = tr.chrome_trace()
    counts = validate_chrome_trace(doc)
    assert counts["B"] == counts["E"] > 0
    # finish markers carry exactly the report's finish steps
    finish = {e["tid"]: (e["name"], e["args"]["step"])
              for e in doc["traceEvents"]
              if e.get("pid") == REQUEST_PID and e.get("ph") == "i"}
    for r in rep.requests:
        name, step = finish[r["rid"]]
        assert name == f"finish:{r['finish_reason']}", (r, name)
        assert step == r["finished_step"], (r, step)
    # (almost) every engine tick produced an X slice — the final drain
    # tick may legitimately run nothing — and none were dropped
    slices = [e for e in doc["traceEvents"]
              if e.get("pid") == ENGINE_PID and e.get("ph") == "X"]
    assert rep.steps - 1 <= len(slices) <= rep.steps and tr.dropped == 0
    assert all(e["dur"] >= 0 for e in slices)


def test_trace_summary_waterfall_is_consistent(traced):
    _, _, rep, _ = traced
    summ = rep.trace_summary
    # the final drain tick (tick() returns not-alive) is traced but not a
    # counted engine step
    assert rep.steps <= summ["ticks"] <= rep.steps + 1
    assert set(summ["requests"]) == {str(r["rid"]) for r in rep.requests}
    for r in rep.requests:
        row = summ["requests"][str(r["rid"])]
        assert row["finished_step"] == r["finished_step"]
        assert row["reason"] == r["finish_reason"]
        assert row["chunks"] >= 1                  # everyone prefilled
        # waterfall segments are non-negative and sum to the total
        segs = [row["queue_wait_us"], row["prefill_us"], row["decode_us"]]
        assert all(s is not None and s >= 0 for s in segs), row
        assert row["total_us"] == pytest.approx(sum(segs))
    pct = summ["percentiles"]["total_us"]
    assert pct["n"] == len(rep.requests) and pct["p99"] >= pct["p50"]


# ==========================================================================
# Per-site attribution: bit-exact sums, chained I/O savings
# ==========================================================================
def test_site_attribution_sums_bit_exactly(traced):
    _, plain, rep, _ = traced
    for r in (plain, rep):                # attribution never needs a tracer
        attr = r.site_attribution
        assert attr["tokens"] == r.tokens_priced > 0
        ops = e_j = 0.0
        for row in attr["per_site"].values():   # left-to-right, table order
            ops += row["ops"]
            e_j += row["energy_j"]
        assert ops == r.analog_ops              # bit-exact, no approx
        assert e_j == r.analog_energy_j
        assert attr["fj_per_op"] == r.fj_per_op
    # traced and untraced runs price identically
    assert rep.site_attribution == plain.site_attribution


def test_chained_attribution_exposes_saved_io():
    base = _cfg()
    chained = base.replace(tdvmm_plan=TDVMMPlan(rules=(
        tdvmm_rule("ffn.*", enabled=True, backend="jnp"),
        tdvmm_rule("ffn.in", chain=True))))
    a_un = energy_model.site_attribution(
        energy_model.serving_energy_model(base, tile_n=64), tokens=100)
    a_ch = energy_model.site_attribution(
        energy_model.serving_energy_model(chained, tile_n=64), tokens=100)
    assert a_un["io_saved_j"] == 0.0 and a_un["chains"] == []
    assert a_ch["chains"] == [["ffn.in", "ffn.out"]]
    assert a_ch["io_saved_j"] > 0.0
    # both ends of the chained pair show their removed conversion
    for site in ("ffn.in", "ffn.out"):
        assert a_ch["per_site"][site]["io_saved_j"] > 0.0, site
        assert a_ch["per_site"][site]["io_factor"] < 1.0, site
    assert a_ch["energy_j"] < a_un["energy_j"]
    with pytest.raises(ValueError, match=">= 0"):
        energy_model.site_attribution(
            energy_model.serving_energy_model(base, tile_n=64), tokens=-1)


# ==========================================================================
# Tentpole invariant: the trace rides the snapshot (kill + restore = one
# continuous schema-valid span stream)
# ==========================================================================
def test_trace_rides_snapshot_and_resumes_continuously(served, traced):
    cfg, params, calib, _ = served
    reqs, plain, _, base_tr = traced
    e1 = Engine(cfg, params, ECFG, calib=calib, tracer=Tracer())
    r1 = e1.run(reqs, FaultConfig(
        injector=fi.FaultInjector([fi.PreemptAt(plain.steps // 2)])))
    assert r1.preempted
    pre_doc = e1.tracer.chrome_trace()
    validate_chrome_trace(pre_doc)     # auto-closes open spans on the COPY
    snap = e1.snapshot()
    e2 = Engine(cfg, params, ECFG, calib=calib, tracer=Tracer())
    e2.restore(snap)
    r2 = e2.resume()
    _same_streams(plain, r2)
    doc = e2.tracer.chrome_trace()
    counts = validate_chrome_trace(doc)
    assert counts["B"] == counts["E"]
    # continuity: resumed doc contains the pre-kill events plus the rest,
    # and matches the uninterrupted tracer's span population exactly
    assert len(doc["traceEvents"]) > len(pre_doc["traceEvents"]) - len(
        [e for e in pre_doc["traceEvents"]
         if e.get("args", {}).get("auto_closed")])
    base_counts = validate_chrome_trace(base_tr.chrome_trace())
    assert counts["B"] == base_counts["B"]
    assert counts["i"] == base_counts["i"]
    assert e2.tracer.ticks == base_tr.ticks
    # energy bookkeeping survived the kill too
    assert r2.analog_ops == plain.analog_ops
    assert r2.tokens_priced == plain.tokens_priced
    assert r2.site_attribution == plain.site_attribution


def test_restore_trace_without_tracer_raises(served, traced):
    cfg, params, calib, _ = served
    reqs, plain, _, _ = traced
    e1 = Engine(cfg, params, ECFG, calib=calib, tracer=Tracer())
    e1.run(reqs, FaultConfig(
        injector=fi.FaultInjector([fi.PreemptAt(2)])))
    bare = Engine(cfg, params, ECFG, calib=calib)
    with pytest.raises(ValueError, match="tracer"):
        bare.restore(e1.snapshot())


# ==========================================================================
# Live per-site clip-rate series -> AlertRule (satellite: drift observable
# before any recalibration runs)
# ==========================================================================
def _clip_rules(calib, limit=1e-4):
    return [AlertRule(f"clip_rate.{s}", kind="threshold", limit=limit)
            for s in calib.sites()]


def test_clip_rate_alert_fires_before_recalibration(served):
    # Moderate tuning drift moves the live max|z| randomly around the
    # pinned window; for this (seed, sigma) it lands ABOVE it, so |z| mass
    # clips and the per-site series rises.  (A huge sigma instead SHRINKS
    # the latch-normalized z — decorrelation — which the window-ratio
    # check catches; clip rate is the early-warning side of the pair.)
    cfg, params, calib, batch = served
    reqs = _trace(cfg.vocab_size)
    sink = MetricsSink(rules=_clip_rules(calib))
    eng = Engine(cfg, params, ECFG, calib=calib, sink=sink)
    rep = eng.run(reqs, FaultConfig(
        injector=fi.FaultInjector(
            [fi.DriftAt(step=4, sigma=0.05, seed=2, repeats=1)]),
        drift=DriftConfig(probe_batch=batch, observe_every=2,
                          check_every=10**9, max_len=48)))  # observe only
    # the alert fired with ZERO recalibrations: the per-site series sees
    # the drift strictly before any drift_probe recalibration reacts
    assert rep.recalibrations == 0 and rep.drift_events == []
    clip_alerts = [a for a in sink.alerts
                   if a.metric.startswith("clip_rate.")]
    assert len(clip_alerts) >= 1, sink.alerts
    assert min(a.step for a in clip_alerts) >= 4  # post-injection only
    assert rep.compiled_steps == 2                # probe stays eager
    # the series exist per site and carry the post-drift elevation
    for s in calib.sites():
        assert f"clip_rate.{s}" in sink.series
    assert max(a.value for a in clip_alerts) > 1e-4


def test_clip_rate_clean_run_stays_quiet(served):
    cfg, params, calib, batch = served
    reqs = _trace(cfg.vocab_size)
    sink = MetricsSink(rules=_clip_rules(calib))
    rep = Engine(cfg, params, ECFG, calib=calib, sink=sink).run(
        reqs, FaultConfig(drift=DriftConfig(
            probe_batch=batch, observe_every=2, check_every=10**9,
            max_len=48)))
    assert [a for a in sink.alerts if a.metric.startswith("clip_rate.")] \
        == []
    assert any(k.startswith("clip_rate.") for k in sink.series)
    assert rep.compiled_steps == 2


# ==========================================================================
# JsonlEmitter durability: flushed on report() and on preemption exit
# ==========================================================================
def test_jsonl_flushed_on_report_without_close(served, tmp_path):
    cfg, params, calib, _ = served
    reqs = _trace(cfg.vocab_size)
    path = tmp_path / "metrics.jsonl"
    sink = MetricsSink(emitters=[JsonlEmitter(path)])
    Engine(cfg, params, ECFG, calib=calib, sink=sink).run(reqs)
    # no close(): report() flush+fsync already landed every line
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len([ln for ln in lines if ln["t"] == "metric"]) \
        == sink.observations > 0


def test_jsonl_flushed_on_preemption_exit(served, tmp_path):
    cfg, params, calib, _ = served
    reqs = _trace(cfg.vocab_size)
    path = tmp_path / "metrics.jsonl"
    sink = MetricsSink(emitters=[JsonlEmitter(path)])
    rep = Engine(cfg, params, ECFG, calib=calib, sink=sink).run(
        reqs, FaultConfig(injector=fi.FaultInjector([fi.PreemptAt(3)]),
                          snapshot_dir=tmp_path))
    assert rep.preempted
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len([ln for ln in lines if ln["t"] == "metric"]) \
        == sink.observations > 0


# ==========================================================================
# Report plumbing: autotune table + JSON round trip; trace_report.py CLI
# ==========================================================================
def test_report_carries_autotune_and_serializes(traced):
    _, _, rep, _ = traced
    assert set(rep.autotune) >= {"platform", "entries", "misses"}
    doc = json.loads(json.dumps(rep.to_json()))
    assert doc["tokens_priced"] == rep.tokens_priced
    assert doc["site_attribution"]["per_site"] == \
        rep.site_attribution["per_site"]
    assert doc["trace_summary"]["ticks"] >= rep.steps


def test_trace_report_script_renders_markdown(traced, tmp_path):
    _, _, rep, tr = traced
    trace_path = tmp_path / "trace.json"
    trace_path.write_text(json.dumps(tr.chrome_trace()))
    out = tmp_path / "report.md"
    repo = Path(__file__).resolve().parent.parent
    run = subprocess.run(
        [sys.executable, str(repo / "scripts" / "trace_report.py"),
         str(trace_path), "-o", str(out)],
        capture_output=True, text=True, timeout=120)
    assert run.returncode == 0, run.stderr
    md = out.read_text()
    assert "## Per-request latency waterfall" in md
    assert "## Percentiles across requests" in md
    assert "## Engine ticks by phase" in md
    # one waterfall row per request, each showing its finish step
    for r in rep.requests:
        assert f"| {r['rid']} | {r['finish_reason']} " \
               f"| {r['finished_step']} |" in md
