"""Serving driver: prefill+decode loop produces tokens, donates caches,
works with int8 KV; the engine CLI dumps a complete report."""
import dataclasses
import json
import sys

import jax

from repro.configs import get_config, smoke
from repro.launch.serve import serve
from repro.models import attention


def test_serve_dense():
    cfg = smoke(get_config("qwen1.5-0.5b"))
    out = serve(cfg, batch=2, prompt_len=8, gen=4)
    assert out["tokens"].shape == (2, 4)
    assert out["decode_tok_per_s"] > 0


def test_serve_ssm_int8_kv():
    attention.set_kv_cache_int8(True)
    try:
        cfg = smoke(get_config("zamba2-2.7b"))
        out = serve(cfg, batch=2, prompt_len=8, gen=4)
        assert out["tokens"].shape == (2, 4)
    finally:
        attention.set_kv_cache_int8(False)


def test_engine_cli_report_json_is_complete(tmp_path, monkeypatch):
    """``--report-json`` dumps the FULL EngineReport — every dataclass
    field (including the SLA/telemetry ones) and per-request SLA outcomes —
    and ``--metrics-jsonl`` streams the per-tick series alongside."""
    from repro.launch import serve as serve_mod
    from repro.runtime.engine import EngineReport

    report = tmp_path / "report.json"
    jsonl = tmp_path / "metrics.jsonl"
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "qwen1.5-0.5b", "--smoke",
        "--requests", "3", "--slots", "2", "--prompt-len", "8",
        "--gen", "4", "--chunk", "8", "--page-size", "4",
        "--num-pages", "16", "--sla", "--deadline-steps", "500",
        "--metrics-jsonl", str(jsonl), "--report-json", str(report)])
    serve_mod.main()
    doc = json.loads(report.read_text())
    fields = {f.name for f in dataclasses.fields(EngineReport)}
    assert set(doc) == fields                    # nothing dropped, ever
    assert doc["compiled_steps"] == 2
    assert doc["telemetry"]["observations"] > 0
    assert doc["alerts"] == doc["telemetry"]["alerts"]
    # every trace request declared the 500-step deadline and made it
    assert doc["deadline_hits"] == 3 and doc["deadline_misses"] == 0
    for rec in doc["requests"]:
        for key in ("priority", "deadline_steps", "deadline_hit",
                    "joule_budget", "joules_used", "reject_reason"):
            assert key in rec, key
        assert rec["deadline_hit"] is True
    lines = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    assert sum(1 for ln in lines
               if ln["t"] == "metric" and ln["metric"] == "step_latency_s"
               ) == doc["telemetry"]["metrics"]["step_latency_s"]["count"]
