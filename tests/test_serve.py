"""Serving driver: prefill+decode loop produces tokens, donates caches,
works with int8 KV."""
import jax

from repro.configs import get_config, smoke
from repro.launch.serve import serve
from repro.models import attention


def test_serve_dense():
    cfg = smoke(get_config("qwen1.5-0.5b"))
    out = serve(cfg, batch=2, prompt_len=8, gen=4)
    assert out["tokens"].shape == (2, 4)
    assert out["decode_tok_per_s"] > 0


def test_serve_ssm_int8_kv():
    attention.set_kv_cache_int8(True)
    try:
        cfg = smoke(get_config("zamba2-2.7b"))
        out = serve(cfg, batch=2, prompt_len=8, gen=4)
        assert out["tokens"].shape == (2, 4)
    finally:
        attention.set_kv_cache_int8(False)
