"""Streaming telemetry: rolling robust statistics, alert rules, emitters,
snapshot/restore, and the engine integration contracts.

Hard contracts under test:

  * rolling median/MAD match a from-scratch numpy computation over the same
    window at every push, through ring wrap-around;
  * spike rules evaluate against the window *before* the new value (a spike
    never raises the bound that should catch it) and stay silent until
    ``min_samples`` prior samples exist;
  * ``MetricsSink.snapshot()`` is plain JSON and ``restore`` reproduces the
    sink's dynamic state exactly (continued pushes see identical stats);
  * a sink-wired engine run keeps ``compiled_steps == 2`` and its token
    streams bit-identical to a sink-less run;
  * on a WARM engine, an injected ``SlowStep`` straggler fires exactly one
    step-latency spike alert (at the post-tick observation step), and a
    clean warm run fires none.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.models import model
from repro.runtime import faultinject as fi
from repro.runtime.engine import Engine, EngineConfig, FaultConfig, Request
from repro.runtime.telemetry import (Alert, AlertRule, JsonlEmitter,
                                     MemoryEmitter, MetricsSink,
                                     RollingSeries, StdoutEmitter)


# ==========================================================================
# RollingSeries: stats match numpy through ring + window turnover
# ==========================================================================
def test_rolling_series_matches_numpy_reference():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 1.5, size=200)
    s = RollingSeries(capacity=64, window=9)
    for i, x in enumerate(xs):
        s.push(i, x)
        win = xs[max(0, i - 8):i + 1]            # last `window` values
        med = float(np.median(win))
        assert s.median() == pytest.approx(med)
        assert s.mad() == pytest.approx(float(np.median(np.abs(win - med))))
    # ring: only the last `capacity` samples are retained
    assert len(s.values) == 64
    assert list(s.values) == [float(x) for x in xs[-64:]]
    assert list(s.steps) == list(range(136, 200))
    assert s.count == 200                        # lifetime count survives
    assert s.last == pytest.approx(float(xs[-1]))


def test_rolling_series_validates_and_empty_stats():
    with pytest.raises(ValueError, match=">= 1"):
        RollingSeries(capacity=0)
    with pytest.raises(ValueError, match=">= 1"):
        RollingSeries(window=0)
    s = RollingSeries()
    assert s.median() == 0.0 and s.mad() == 0.0 and s.last is None


def test_rolling_series_state_dict_round_trip():
    a = RollingSeries(capacity=16, window=5)
    for i in range(40):
        a.push(i, float(i % 7))
    b = RollingSeries(capacity=16, window=5)
    b.load_state_dict(json.loads(json.dumps(a.state_dict())))
    assert b.median() == a.median() and b.mad() == a.mad()
    assert b.count == a.count and list(b.values) == list(a.values)
    # the restored window continues identically
    a.push(40, 3.25), b.push(40, 3.25)
    assert b.median() == a.median() and b.mad() == a.mad()


# ==========================================================================
# AlertRule semantics
# ==========================================================================
def test_alert_rule_validation():
    with pytest.raises(ValueError, match="unknown alert kind"):
        AlertRule("m", kind="mean")
    with pytest.raises(ValueError, match="needs limit="):
        AlertRule("m", kind="threshold")
    with pytest.raises(ValueError, match="needs baseline="):
        AlertRule("m", kind="regression")


def test_spike_waits_for_min_samples_and_evaluates_pre_push():
    sink = MetricsSink(rules=[AlertRule("m", kind="spike", k=3.0,
                                        min_samples=4)])
    # quiet series: 3 prior samples -> even a huge value stays silent
    for step in range(3):
        assert sink.observe("m", 1.0, step) == []
    assert sink.observe("m", 100.0, 3) == []     # n_prior == 3 < 4
    # the 100.0 outlier is IN the window now, but median/MAD are computed
    # before each new push, so a second spike still trips the rule
    fired = sink.observe("m", 100.0, 4)
    assert [a.kind for a in fired] == ["spike"]
    assert fired[0].step == 4 and fired[0].metric == "m"
    assert fired[0].value == 100.0 and fired[0].limit >= fired[0].median


def test_spike_deadband_floors():
    # dead-flat series: MAD == 0, so without a floor any epsilon would alert
    abs_rule = AlertRule("m", kind="spike", k=6.0, min_samples=2,
                         abs_floor=0.5)
    sink = MetricsSink(rules=[abs_rule])
    for step in range(4):
        sink.observe("m", 1.0, step)
    assert sink.observe("m", 1.4, 4) == []       # inside the 0.5 deadband
    assert len(sink.observe("m", 1.6, 5)) == 1   # beyond it
    rel = MetricsSink(rules=[AlertRule("m", kind="spike", k=6.0,
                                       min_samples=2, rel_floor=0.5)])
    for step in range(4):
        rel.observe("m", 10.0, step)
    assert rel.observe("m", 14.0, 4) == []       # < median * (1 + 0.5)
    assert len(rel.observe("m", 16.0, 5)) == 1


def test_threshold_and_regression_rules():
    sink = MetricsSink(rules=[
        AlertRule("depth", kind="threshold", limit=8.0),
        AlertRule("fj", kind="regression", baseline=50.0, tol=0.1)])
    assert sink.observe("depth", 8.0, 0) == []   # at the limit: fine
    a = sink.observe("depth", 9.0, 1)
    assert len(a) == 1 and a[0].limit == 8.0
    assert sink.observe("fj", 54.9, 2) == []     # inside baseline*(1+tol)
    b = sink.observe("fj", 55.1, 3)
    assert len(b) == 1 and b[0].limit == pytest.approx(55.0)
    # rules only fire on their own metric
    assert sink.observe("other", 1e9, 4) == []
    assert sink.alerts_for("depth") == a
    assert sink.alerts_for("depth", kind="spike") == []


# ==========================================================================
# Emitters
# ==========================================================================
def test_memory_emitter_sees_metrics_and_alerts():
    em = MemoryEmitter()
    sink = MetricsSink(rules=[AlertRule("m", kind="threshold", limit=1.0)],
                       emitters=[em])
    sink.observe("m", 0.5, 0)
    sink.observe("m", 2.0, 1)
    assert em.metrics == [("m", 0, 0.5), ("m", 1, 2.0)]
    assert [a.step for a in em.alerts] == [1]
    assert em.alerts == sink.alerts


def test_jsonl_emitter_streams_and_closes(tmp_path):
    path = tmp_path / "metrics.jsonl"
    em = JsonlEmitter(path)
    sink = MetricsSink(rules=[AlertRule("m", kind="threshold", limit=1.0)],
                       emitters=[em])
    sink.observe("m", 0.5, 0)
    sink.observe("m", 2.0, 1)
    em.close()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["t"] for ln in lines] == ["metric", "metric", "alert"]
    assert lines[1] == {"t": "metric", "metric": "m", "step": 1,
                        "value": 2.0}
    assert lines[2]["kind"] == "threshold" and lines[2]["value"] == 2.0
    em.close()                                   # idempotent
    # reopening appends (serve.py resume keeps one growing file)
    JsonlEmitter(path).on_metric("m", 2, 3.0)
    assert len(path.read_text().splitlines()) == 4


def test_stdout_emitter_prints_alerts_only(capsys):
    em = StdoutEmitter()
    em.on_metric("m", 0, 1.0)
    em.on_alert(Alert(step=3, metric="m", kind="spike", value=2.0,
                      limit=1.5, median=1.0, mad=0.05))
    out = capsys.readouterr().out
    assert out.count("\n") == 1 and "ALERT spike m step=3" in out


# ==========================================================================
# MetricsSink snapshot/restore
# ==========================================================================
def _fed_sink():
    sink = MetricsSink(rules=[AlertRule("m", kind="spike", k=3.0,
                                        min_samples=4, abs_floor=0.01)],
                       window=8, capacity=32)
    rng = np.random.default_rng(7)
    for step in range(50):
        sink.observe("m", float(rng.lognormal(0, 1)), step)
        sink.observe("aux", float(step), step)
    return sink


def test_sink_snapshot_restore_round_trip():
    a = _fed_sink()
    snap = json.loads(json.dumps(a.snapshot()))  # plain JSON survives a dump
    b = MetricsSink(rules=a.rules, window=8, capacity=32)
    b.restore(snap)
    assert b.snapshot() == a.snapshot()
    assert b.observations == a.observations
    assert [x.to_json() for x in b.alerts] == [x.to_json() for x in a.alerts]
    assert b.summary() == a.summary()
    # the restored sink continues identically: same stats, same verdicts
    for step in range(50, 60):
        va = a.observe("m", float(step % 3) * 0.7, step)
        vb = b.observe("m", float(step % 3) * 0.7, step)
        assert [x.to_json() for x in vb] == [x.to_json() for x in va]
    assert b.snapshot() == a.snapshot()


def test_sink_restore_rejects_garbage():
    with pytest.raises(ValueError, match="not a MetricsSink snapshot"):
        MetricsSink().restore({"nope": 1})


# ==========================================================================
# Engine integration (tiny model)
# ==========================================================================
def _cfg():
    return smoke(get_config("qwen1.5-0.5b")).replace(tdvmm_plan=TDVMMPlan(
        rules=(tdvmm_rule("ffn.*", enabled=True, backend="jnp"),)))


ECFG = EngineConfig(slots=3, page_size=4, num_pages=32, chunk=4)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    calib = model.calibrate(params, batch, cfg, max_len=48)
    return cfg, params, calib


def _trace(vocab, n=4, seed=0):
    rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for rid in range(n):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(
                0, vocab, rng.integers(3, 11))),
            max_new_tokens=int(rng.integers(2, 6)),
            arrival_step=arrival))
        arrival += int(rng.integers(0, 2))
    return reqs


def test_sink_wired_run_streams_unchanged_two_compiled_steps(served):
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size)
    base = Engine(cfg, params, ECFG, calib=calib).run(reqs)
    sink = MetricsSink()
    rep = Engine(cfg, params, ECFG, calib=calib, sink=sink).run(reqs)
    assert rep.compiled_steps == 2               # telemetry is host-side only
    for ra, rb in zip(base.requests, rep.requests):
        assert ra["tokens"] == rb["tokens"]
        assert ra["finish_reason"] == rb["finish_reason"]
    # every engine tick fed the core series
    for metric in ("step_latency_s", "queue_depth", "active_slots",
                   "page_in_use", "page_high_water", "generated_tokens",
                   "step_retries", "fj_per_op"):
        assert sink.series[metric].count >= rep.steps, metric
    assert rep.telemetry == sink.summary()
    assert rep.alerts == len(sink.alerts)
    # fJ/Op telemetry converges on the energy table's figure
    assert sink.series["fj_per_op"].last == pytest.approx(rep.fj_per_op)


def test_warm_engine_slowstep_fires_exactly_one_spike(served):
    cfg, params, calib = served
    reqs = _trace(cfg.vocab_size)
    rule = AlertRule("step_latency_s", kind="spike", k=6.0, min_samples=6,
                     abs_floor=0.05)
    sink = MetricsSink(rules=[rule])
    eng = Engine(cfg, params, ECFG, calib=calib, sink=sink)
    ref = eng.run(reqs)                          # warmup: absorbs jit compiles
    warm_alerts = len(sink.alerts)
    # clean warm run: zero false positives
    eng.run(reqs)
    assert len(sink.alerts) == warm_alerts
    # injected straggler: exactly one spike, observed at slow_step + 1 (the
    # sleep happens inside the compiled-step wrapper; the sink observes the
    # tick's dt after the step counter advanced past it)
    slow = max(1, ref.steps // 2)
    rep = eng.run(reqs, FaultConfig(
        injector=fi.FaultInjector([fi.SlowStep(slow, sleep_s=0.3)])))
    injected = sink.alerts[warm_alerts:]
    assert len(injected) == 1, injected
    assert injected[0].metric == "step_latency_s"
    assert injected[0].step == slow + 1
    assert injected[0].value >= 0.3
    # the straggler only inflated wall time — streams are untouched
    for ra, rb in zip(ref.requests, rep.requests):
        assert ra["tokens"] == rb["tokens"]
    assert rep.compiled_steps == 2
