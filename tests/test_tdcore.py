"""Property tests for the paper's core claims (Eq. 1-7, sections 2-3).

The event-driven crossing simulator must reproduce the closed form EXACTLY
(the paper's central identity) for every quadrant variant, every weight/input
draw, and chained layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import currents as cur
from repro.core import encoding as enc
from repro.core import tdcore
from repro.core.constants import TDVMMSpec

jax.config.update("jax_enable_x64", True)

SPEC = TDVMMSpec(bits=8)


def _rand(key, shape, lo, hi):
    return jax.random.uniform(key, shape, jnp.float64, lo, hi)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 48), st.integers(1, 16))
def test_single_quadrant_matches_closed_form(seed, n_in, n_out):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (n_in,), 0.0, 1.0)
    w = _rand(k2, (n_in, n_out), 0.0, 1.0)
    y_sim = tdcore.td_vmm_single_quadrant(x, w, SPEC)
    y_ref = tdcore.ideal_single_quadrant(x, w, SPEC.w_max)
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_ref),
                               rtol=1e-9, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 32), st.integers(1, 8))
def test_four_quadrant_matches_closed_form(seed, n_in, n_out):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (n_in,), -1.0, 1.0)
    w = _rand(k2, (n_in, n_out), -1.0, 1.0)
    y_sim = tdcore.td_vmm_four_quadrant(x, w, SPEC)
    y_ref = tdcore.ideal_four_quadrant(x, w, SPEC.w_max)
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_ref),
                               rtol=1e-9, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_mlp_chain_in_time_domain(seed):
    """Fig. 2: two VMMs + ReLU (AND gate) chained purely via crossing times."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k1, (10,), -1.0, 1.0)
    w1 = _rand(k2, (10, 10), -1.0, 1.0)
    w2 = _rand(k3, (10, 10), -1.0, 1.0)
    y_sim = tdcore.td_mlp_forward(x, w1, w2, SPEC)
    y_ref = tdcore.ideal_mlp(x, w1, w2, SPEC.w_max)
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_ref),
                               rtol=1e-9, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 64))
def test_current_programming_invariants(seed, n):
    """Eq. 6-7: 0 <= I_i <= I_max and I_0 >= 0 for any weights in range."""
    w = _rand(jax.random.PRNGKey(seed), (n, 4), 0.0, 1.0)
    i_mat, bias = cur.program_matrix(w, SPEC.i_max, SPEC.w_max)
    assert float(jnp.min(i_mat)) >= 0.0
    assert float(jnp.max(i_mat)) <= SPEC.i_max * (1 + 1e-9)
    assert float(jnp.min(bias)) >= -1e-18


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 32))
def test_output_window_bounds(seed, n):
    """Section 2.2: outputs always land inside [T, 2T] regardless of weights."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (n,), -1.0, 1.0)
    w = _rand(k2, (n, 3), -1.0, 1.0)
    _, (tp, tm) = tdcore.td_vmm_four_quadrant(x, w, SPEC, return_times=True)
    t = SPEC.t_window_s
    for tt in (tp, tm):
        assert float(jnp.min(tt)) >= t - 1e-12
        assert float(jnp.max(tt)) <= 2 * t + 1e-12


def test_relu_and_gate_semantics():
    """AND-gate pulse duration == relu of the differential output."""
    t = 1.0
    tp = jnp.array([1.2, 1.7, 1.5])
    tm = jnp.array([1.5, 1.4, 1.5])
    d = tdcore.relu_duration(tp, tm)
    np.testing.assert_allclose(np.asarray(d), [0.3, 0.0, 0.0], atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(4, 10))
def test_quantization_roundtrip(seed, bits):
    x = _rand(jax.random.PRNGKey(seed), (64,), 0.0, 1.0)
    q = enc.fake_quant(x, bits)
    assert float(jnp.max(jnp.abs(q - x))) <= 0.5 / ((1 << bits) - 1) + 1e-9
    codes = enc.quantize_code(x, bits)
    assert int(jnp.min(codes)) >= 0 and int(jnp.max(codes)) <= (1 << bits) - 1


def test_pulse_duration_equivalence():
    """Section 3.1: duration encoding injects the same charge as rising-edge."""
    t = SPEC.t_window_s
    x = jnp.array([0.3, 0.8, 0.0, 1.0])
    onset_charge_time = t - enc.value_to_onset(x, t)   # time the source is ON in [0,T]
    dur = enc.value_to_duration(x, t)
    np.testing.assert_allclose(np.asarray(onset_charge_time), np.asarray(dur))


def test_pipeline_schedule():
    s = tdcore.pipeline_schedule(n_stages=2, n_samples=100, spec=TDVMMSpec(bits=6))
    assert s["period_s"] == pytest.approx(2 * SPEC.t0_s * 64 + 2e-9)
    assert s["total_s"] > 99 * s["period_s"]
    assert s["throughput_samples_per_s"] == pytest.approx(1.0 / s["period_s"])
