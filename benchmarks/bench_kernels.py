"""Kernel micro-benchmarks: Pallas (interpret mode on CPU — correctness-path
timing only; Mosaic compilation happens on real TPUs) vs the jnp reference
path, plus the arithmetic-intensity accounting that motivates each kernel.

Emits ``BENCH_kernels.json`` (bytes moved, GB/s, us per shape, op counts,
jnp-vs-pallas speedups) so CI tracks the perf trajectory run over run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, reset_rows, save_json, time_call
from repro.core.layers import TDVMMLayerConfig, td_grouped_matmul, td_matmul
from repro.kernels.crossing.ref import crossing_ref
from repro.kernels.ssd.ref import ssd_naive
from repro.kernels.tdvmm.ops import tdvmm_matmul
from repro.kernels.tdvmm.ref import tdvmm_matmul_ref
from repro.models.ssm import ssd_chunked


def _codes(key, shape, dtype):
    c = jnp.round(jax.random.uniform(key, shape, minval=-63, maxval=63))
    return c.astype(dtype)


def bench_tdvmm_backends():
    """jnp vs Pallas parity + throughput at model shapes.

    On CPU the Pallas path runs in interpret mode (Python-level grid walk):
    the numbers quantify interpret overhead, not TPU performance — the point
    of the row pair is the parity column (max |jnp - pallas|, must be 0) and
    the jnp-path GFLOP/s at shapes a model actually emits.
    """
    from repro.kernels.tdvmm import ops as tdops
    for (m, k, n) in [(512, 1024, 4096), (256, 896, 896), (33, 300, 130)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(m + n))
        xc = _codes(kx, (m, k), jnp.float32)
        wc = _codes(kw, (k, n), jnp.float32)
        xs = jnp.ones((m,))
        ws = jnp.ones((n,))
        flops = 2 * m * k * n
        outs = {}
        for backend in ("jnp", "pallas"):
            # Plan through plan_kernel so each row records the chosen blocks
            # and whether the autotune table answered (miss = heuristic
            # fallback, visible here instead of quietly slow).
            kp = tdops.plan_kernel(backend, m, k, n, "f32")
            fn = jax.jit(functools.partial(
                tdvmm_matmul, gain=1e-4, out_bits=6, backend=backend,
                block_sizes=kp.blocks))
            outs[backend] = fn(xc, wc, xs, ws)
            us = time_call(fn, xc, wc, xs, ws, iters=3)
            emit(f"tdvmm_{backend}_{m}x{k}x{n}", us,
                 f"GFLOP/s={flops/us*1e-3:.1f}|blocks={kp.blocks}"
                 f"|hit={kp.autotune_hit}",
                 data={"m": m, "k": k, "n": n,
                       "gflops_per_s": round(flops / us * 1e-3, 1),
                       "plan_blocks": list(kp.blocks),
                       "autotune_hit": kp.autotune_hit,
                       "autotune_platform": kp.platform})
        parity = float(jnp.max(jnp.abs(outs["jnp"] - outs["pallas"])))
        emit(f"tdvmm_parity_{m}x{k}x{n}", 0.0, f"max_abs_diff={parity}",
             data={"max_abs_diff": parity})

    # full layer path (encode -> integrate -> readout -> rescale)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
    w = jax.random.normal(jax.random.PRNGKey(2), (1024, 4096)) * 0.05
    for backend in ("jnp", "pallas"):
        cfg = TDVMMLayerConfig(enabled=True, backend=backend)
        fn = jax.jit(lambda x, w, cfg=cfg: td_matmul(x, w, cfg))
        us = time_call(fn, x, w, iters=3)
        emit(f"td_matmul_layer_{backend}_256x1024x4096", us,
             f"GFLOP/s={2*256*1024*4096/us*1e-3:.1f}")


def _iter_eqns(fn, args):
    """Every equation in the traced program of fn(*args), recursing into
    nested (pjit/scan/pallas) sub-jaxprs — one traversal shared by all the
    jaxpr-derived bench metrics."""
    eqns = []

    def walk(jx):
        for eqn in jx.eqns:
            eqns.append(eqn)
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (list, tuple)) else [val]):
                    if hasattr(sub, "eqns"):
                        walk(sub)
                    elif hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return eqns


def _matmul_operand_dtype(fn, args):
    """The dtype actually reaching the codes matmul: the first contraction
    (dot_general) in the traced program, by its LHS input dtype.  This keeps
    the bytes-moved claim honest — if the int8 dispatch ever regressed to
    f32, this (and the CI invariant built on it) would catch it, not just
    the analytic itemsize arithmetic."""
    for eqn in _iter_eqns(fn, args):
        if eqn.primitive.name == "dot_general":
            return str(eqn.invars[0].aval.dtype)
    return "none"


def bench_int8_vs_f32_codes():
    """The headline bytes-moved win: int8 code storage streams the codes
    matmul at a quarter of the f32 HBM bytes (and accumulates exactly in
    int32, so there is no 2^24 envelope to respect).

    ``bytes_hbm`` is the analytic HBM traffic of the codes matmul — code
    reads + one f32 output write — cross-checked against the dtype the
    traced dot_general actually consumes (``matmul_operand_dtype``); CPU
    wall time is reported for trajectory tracking but XLA-CPU's int8 matmul
    codegen is not the serving target.
    """
    byte_rows, op_dtypes = {}, {}
    for (m, k, n) in [(512, 2048, 512), (512, 1024, 4096)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(k))
        for name, dt in (("int8", jnp.int8), ("f32", jnp.float32)):
            xc = _codes(kx, (m, k), dt)
            wc = _codes(kw, (k, n), dt)
            xs = jnp.ones((m,))
            ws = jnp.ones((n,))
            itemsize = jnp.dtype(dt).itemsize
            bytes_hbm = (m * k + k * n) * itemsize + m * n * 4
            fn = jax.jit(functools.partial(
                tdvmm_matmul, gain=1e-4, out_bits=6, out_scale=0.5,
                backend="jnp"))
            us = time_call(fn, xc, wc, xs, ws, iters=3)
            byte_rows[(m, k, n, name)] = bytes_hbm
            op_dtypes[(m, k, n, name)] = _matmul_operand_dtype(
                fn, (xc, wc, xs, ws))
            emit(f"tdvmm_codes_{name}_{m}x{k}x{n}", us,
                 f"HBM_MB={bytes_hbm/2**20:.2f}|GB/s={bytes_hbm/us*1e-3:.2f}",
                 data={"m": m, "k": k, "n": n, "code_dtype": name,
                       "matmul_operand_dtype": op_dtypes[(m, k, n, name)],
                       "bytes_hbm": bytes_hbm,
                       "gb_per_s": round(bytes_hbm / us * 1e-3, 2)})
        ratio = byte_rows[(m, k, n, "f32")] / byte_rows[(m, k, n, "int8")]
        int8_verified = op_dtypes[(m, k, n, "int8")] == "int8"
        emit(f"tdvmm_codes_bytes_ratio_{m}x{k}x{n}", 0.0,
             f"f32_bytes/int8_bytes={ratio:.2f}x|int8_dot={int8_verified}",
             data={"bytes_reduction": round(ratio, 2),
                   "int8_reduces_hbm_bytes": ratio > 1.0 and int8_verified})


def _pallas_input_bytes(fn, args):
    """Total bytes of the first pallas_call's operands in the traced program
    — the actual HBM->VMEM stream footprint of the kernel launch, which is
    how the int4 packing claim is verified (the packed launch must stream
    about half the int8 code bytes, not just claim to)."""
    for eqn in _iter_eqns(fn, args):
        if eqn.primitive.name == "pallas_call":
            total = 0
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "shape", None) is not None:
                    size = 1
                    for d in aval.shape:
                        size *= d
                    total += size * jnp.dtype(aval.dtype).itemsize
            return total
    return 0


def bench_int4_packing():
    """int4 code packing (p <= 3): two codes per byte in the HBM stream.

    The Pallas launch consumes nibble-packed int8 arrays (K in packed units)
    and unpacks in-VMEM right before the dot — the analytic code-byte ratio
    vs int8 is 0.5, cross-checked against the traced pallas_call's actual
    operand bytes, and the outputs must be bit-for-bit identical to int8
    (same int32 accumulation, order-independent).
    """
    for (m, k, n) in [(512, 2048, 512), (512, 1024, 4096)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(k + 1))
        xc = jnp.round(jax.random.uniform(
            kx, (m, k), minval=-7, maxval=7)).astype(jnp.int8)
        wc = jnp.round(jax.random.uniform(
            kw, (k, n), minval=-7, maxval=7)).astype(jnp.int8)
        xs = jnp.ones((m,))
        ws = jnp.ones((n,))
        outs, code_bytes, stream_bytes = {}, {}, {}
        for name in ("int8", "int4"):
            fn = jax.jit(functools.partial(
                tdvmm_matmul, gain=1e-4, out_bits=6, out_scale=0.5,
                backend="pallas", code_dtype=name))
            outs[name] = fn(xc, wc, xs, ws)
            kb = (k + 1) // 2 if name == "int4" else k
            code_bytes[name] = m * kb + kb * n
            stream_bytes[name] = _pallas_input_bytes(fn, (xc, wc, xs, ws))
            us = time_call(fn, xc, wc, xs, ws, iters=3)
            emit(f"tdvmm_codes_{name}_pallas_{m}x{k}x{n}", us,
                 f"code_MB={code_bytes[name]/2**20:.2f}",
                 data={"m": m, "k": k, "n": n, "code_dtype": name,
                       "code_bytes": code_bytes[name],
                       "pallas_stream_bytes": stream_bytes[name]})
        parity = float(jnp.max(jnp.abs(outs["int8"] - outs["int4"])))
        ratio = code_bytes["int4"] / code_bytes["int8"]
        # Scale vectors ride along in both launches; <= 0.6 still requires
        # the code operands themselves to have halved.
        streamed = stream_bytes["int4"] <= 0.6 * stream_bytes["int8"]
        emit(f"tdvmm_int4_codes_ratio_{m}x{k}x{n}", 0.0,
             f"int4_bytes/int8_bytes={ratio:.2f}|max_abs_diff={parity}",
             data={"code_bytes_ratio": round(ratio, 3),
                   "max_abs_diff_vs_int8": parity,
                   "packed_stream_verified": streamed,
                   "int4_halves_code_bytes": (
                       ratio <= 0.5 and parity == 0.0 and streamed)})


def _count_launches(fn, args):
    """Codes-matmul dispatches in the traced program: each td_matmul is one
    contraction (a dot_general — inside the pallas_call body on the Pallas
    backend, at the top level on jnp), so the grouped path's 3-to-1 / 5-to-1
    launch collapse shows up directly as the dot_general count."""
    return sum(1 for eqn in _iter_eqns(fn, args)
               if eqn.primitive.name == "dot_general")


def _count_encodes(fn, args, m, k):
    """Input-encode materializations: conversions *producing* an int8 (M, K)
    code matrix in the traced program (view ops like squeeze/reshape over
    already-encoded codes don't count).  The sequential path re-encodes the
    same activation once per projection; the grouped launch encodes once."""
    return sum(
        eqn.primitive.name == "convert_element_type"
        and any(getattr(v.aval, "shape", ()) == (m, k)
                and getattr(v.aval, "dtype", None) == jnp.int8
                for v in eqn.outvars)
        for eqn in _iter_eqns(fn, args))


def bench_grouped_projection():
    """Grouped-projection TD-VMM: attn.qkv (G=3) and ssm.in_proj (G=5) as ONE
    shared-input ragged concat launch vs G sequential td_matmul dispatches.

    The paper's NxN tile amortizes one DAC encode across every output column;
    the grouped launch is the model-level analog — the metrics are the launch
    count (G -> 1), the encode-bytes reduction (the input code matrix is
    materialized once instead of G times), and the grouped-vs-sequential
    parity (bit-for-bit 0.0 under matching per-member windows, both
    backends).  Padded-N overhead reports the zero-code columns the ragged
    concat adds: each member rounds only to the 128 lane (the old batched
    stacking padded every member to the widest — 2.33x on attn.qkv under
    heavy GQA; the ragged grid is ~1.0x).
    """
    from repro.kernels.tdvmm import tdvmm
    cases = {
        "attn_qkv": (64, 896, (896, 128, 128)),          # wq / wk / wv
        "ssm_in_proj": (64, 512, (1024, 1024, 128, 128, 16)),  # z/x/B/C/dt
    }
    for name, (m, k, ns) in cases.items():
        g = len(ns)
        x = jax.random.normal(jax.random.PRNGKey(g), (m, k))
        ws = tuple(jax.random.normal(jax.random.PRNGKey(17 + i), (k, n)) * 0.1
                   for i, n in enumerate(ns))
        outs = {}
        for backend in ("jnp", "pallas"):
            cfg = TDVMMLayerConfig(enabled=True, backend=backend)
            grouped_fn = jax.jit(
                lambda x_, ws_, c=cfg: td_grouped_matmul(x_, ws_, c))
            seq_fn = jax.jit(
                lambda x_, ws_, c=cfg: tuple(td_matmul(x_, w, c) for w in ws_))
            outs[backend] = (grouped_fn(x, ws), seq_fn(x, ws))
            if backend == "jnp":
                launches = {"grouped": _count_launches(grouped_fn, (x, ws)),
                            "sequential": _count_launches(seq_fn, (x, ws))}
                encodes = {"grouped": _count_encodes(grouped_fn, (x, ws), m, k),
                           "sequential": _count_encodes(seq_fn, (x, ws), m, k)}
                us_g = time_call(grouped_fn, x, ws, iters=3)
                us_s = time_call(seq_fn, x, ws, iters=3)
        parity = max(
            float(jnp.max(jnp.abs(a - b)))
            for grouped, seq in outs.values()
            for a, b in zip(grouped, seq))
        cross = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(outs["jnp"][0], outs["pallas"][0]))
        widths = tuple(
            tdvmm.padded_size(nn, tdvmm.LANE, tdvmm.LANE) for nn in ns)
        n_total = sum(widths)
        emit(f"tdvmm_grouped_{name}_jnp", us_g,
             f"sequential_us={us_s:.1f}|launches={launches['grouped']}v"
             f"{launches['sequential']}",
             data={"m": m, "k": k, "ns": list(ns), "cpu_us_grouped": us_g,
                   "cpu_us_sequential": us_s})
        emit(f"tdvmm_grouped_launch_count_{name}", 0.0,
             f"launches {launches['sequential']}->{launches['grouped']}|"
             f"encodes {encodes['sequential']}->{encodes['grouped']}|"
             f"max_abs_diff={parity}",
             data={"group": g,
                   "grouped_launches": launches["grouped"],
                   "sequential_launches": launches["sequential"],
                   "one_launch": (launches["grouped"] == 1
                                  and launches["sequential"] == g),
                   "grouped_encodes": encodes["grouped"],
                   "sequential_encodes": encodes["sequential"],
                   "encode_bytes_reduction": round(
                       encodes["sequential"] / max(encodes["grouped"], 1), 2),
                   "encode_bytes_grouped": encodes["grouped"] * m * k,
                   "encode_bytes_sequential": encodes["sequential"] * m * k,
                   "member_widths": list(widths),
                   "n_total": n_total,
                   "padded_n_overhead": round(n_total / sum(ns), 3),
                   "max_abs_diff_vs_sequential": parity,
                   "max_abs_diff_jnp_vs_pallas": cross})


# Pure view/layout primitives: no HBM materialization of their own.
_VIEW_PRIMS = {"squeeze", "reshape", "broadcast_in_dim", "transpose"}


def _count_mn_hbm_materializations(fn, args, m, n):
    """Count *top-level* jaxpr equations that materialize an (M, N)-shaped
    array — each one is an HBM round-trip of the full output tile before XLA
    fusion (the fused kernel's guarantee is exactly one such write).

    Does NOT recurse into pallas_call bodies: with autotuned interpret
    blocks a kernel-body block can equal the whole (M, N) tile, but block
    values live in VMEM — only the pallas_call's own output is an HBM
    write.  View primitives (squeeze/reshape/...) are excluded for the same
    reason."""
    count = 0

    def walk(jx):
        nonlocal count
        for eqn in jx.eqns:
            if eqn.primitive.name in _VIEW_PRIMS:
                continue
            mn_out = any(getattr(v.aval, "shape", ())[-2:] == (m, n)
                         for v in eqn.outvars)
            if eqn.primitive.name == "pallas_call":
                # The kernel's own output IS the one HBM write; block values
                # inside the body live in VMEM, so don't recurse.
                count += mn_out
                continue
            subs = [sub for val in eqn.params.values()
                    for sub in (val if isinstance(val, (list, tuple))
                                else [val])
                    if hasattr(sub, "eqns") or hasattr(sub, "jaxpr")]
            if subs:
                # Call-like wrapper (pjit / custom_vjp / scan): not a
                # materialization itself — count what happens inside.
                for sub in subs:
                    walk(sub if hasattr(sub, "eqns") else sub.jaxpr)
                continue
            count += mn_out

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return count


def bench_fused_epilogue():
    """Fused in-kernel epilogue (gain + p-bit readout over a fixed window +
    per-row x per-channel rescale) vs the unfused jnp chain.

    The interpret-measured metric is the count of (M, N) materializations in
    the traced program: the unfused path builds the accumulator and then a
    chain of full-size elementwise intermediates, while the fused kernel
    finishes each tile in VMEM and writes HBM once.  On TPU that is the
    wall-clock difference; on CPU wall time only tracks interpret overhead.
    """
    m, k, n = 256, 1024, 512
    kx, kw = jax.random.split(jax.random.PRNGKey(3))
    xc = _codes(kx, (m, k), jnp.int8)
    wc = _codes(kw, (k, n), jnp.int8)
    xs = jax.random.uniform(jax.random.PRNGKey(4), (m,), minval=0.5, maxval=2.0)
    ws = jax.random.uniform(jax.random.PRNGKey(5), (n,), minval=0.5, maxval=2.0)
    counts, times = {}, {}
    for backend in ("jnp", "pallas"):
        fn = jax.jit(functools.partial(
            tdvmm_matmul, gain=1e-4, out_bits=6, out_scale=0.5,
            backend=backend))
        counts[backend] = _count_mn_hbm_materializations(
            fn, (xc, wc, xs, ws), m, n)
        y = fn(xc, wc, xs, ws)
        jax.block_until_ready(y)
        times[backend] = time_call(fn, xc, wc, xs, ws, iters=3)
        emit(f"tdvmm_epilogue_{backend}_{m}x{k}x{n}", times[backend],
             f"MN_materializations={counts[backend]}",
             data={"m": m, "k": k, "n": n,
                   "mn_materializations": counts[backend],
                   "fused": backend == "pallas"})
    emit(f"tdvmm_fused_epilogue_opcount_{m}x{k}x{n}", 0.0,
         f"unfused_jnp={counts['jnp']}|fused_pallas={counts['pallas']}",
         data={"unfused_mn_ops": counts["jnp"],
               "fused_mn_ops": counts["pallas"],
               "fused_beats_unfused_opcount":
                   counts["pallas"] < counts["jnp"],
               "cpu_us_jnp": round(times["jnp"], 1),
               "cpu_us_pallas_interpret": round(times["pallas"], 1)})

    # Data-calibrated readout (out_scale=None, the output_calibration=True
    # serving path): the two-phase calibrated kernel folds the per-slot
    # max|z| into the accumulator walk — one launch, ONE (M, N) HBM write —
    # vs the legacy two-pass path (integrate kernel + unfused jnp epilogue).
    cal_counts, cal_outs = {}, {}
    for mode, fused in (("fused", True), ("unfused", False)):
        fn = jax.jit(functools.partial(
            tdvmm_matmul, gain=1e-4, out_bits=6, backend="pallas",
            fused_calibration=fused))
        cal_outs[mode] = fn(xc, wc, xs, ws)
        cal_counts[mode] = _count_mn_hbm_materializations(
            fn, (xc, wc, xs, ws), m, n)
        cal_counts[f"us_{mode}"] = time_call(fn, xc, wc, xs, ws, iters=3)
    jnp_fn = jax.jit(functools.partial(
        tdvmm_matmul, gain=1e-4, out_bits=6, backend="jnp"))
    cal_outs["jnp"] = jnp_fn(xc, wc, xs, ws)
    parity = float(jnp.max(jnp.abs(cal_outs["fused"] - cal_outs["unfused"])))
    parity_jnp = float(jnp.max(jnp.abs(cal_outs["fused"] - cal_outs["jnp"])))
    emit(f"tdvmm_calibrated_epilogue_{m}x{k}x{n}", cal_counts["us_fused"],
         f"MN_writes fused={cal_counts['fused']} "
         f"unfused={cal_counts['unfused']}|max_abs_diff={parity}",
         data={"m": m, "k": k, "n": n,
               "fused_mn_materializations": cal_counts["fused"],
               "unfused_mn_materializations": cal_counts["unfused"],
               "single_mn_write": cal_counts["fused"] == 1,
               "max_abs_diff_fused_vs_unfused": parity,
               "max_abs_diff_vs_jnp": parity_jnp,
               "cpu_us_unfused": round(cal_counts["us_unfused"], 1)})


def check_invariants(doc: dict, baseline: dict | None = None) -> None:
    """Assert the report's perf/parity invariants (shared by the CI
    bench-smoke job and ``benchmarks/run.py``, which re-asserts them in the
    same run as the serving bench so the suite stays one command).

    When ``baseline`` (a previously checked-in BENCH_kernels.json doc) is
    given, wall-clock invariants are also checked *relative* to it: the
    pallas/jnp time ratio at the model shapes must not regress by more than
    25% vs the baseline's ratio.  Ratios (not absolute us) so a slower or
    faster CI machine doesn't flap the gate.
    """
    rows = {r["name"]: r for r in doc["rows"]}
    # jnp and pallas backends must agree bit for bit on integer codes
    parity = [r for n, r in rows.items() if n.startswith("tdvmm_parity")]
    assert parity and all(r["max_abs_diff"] == 0.0 for r in parity), parity
    # int8 code storage must reduce HBM bytes on the codes matmul
    ratios = [r for n, r in rows.items()
              if n.startswith("tdvmm_codes_bytes_ratio")]
    assert ratios and all(r["int8_reduces_hbm_bytes"] for r in ratios)
    # int4 packing must halve the code bytes bit-for-bit vs int8, and the
    # traced pallas launch must actually stream the packed operands
    int4 = [r for n, r in rows.items()
            if n.startswith("tdvmm_int4_codes_ratio")]
    assert int4, "no int4 packing rows"
    for r in int4:
        assert r["max_abs_diff_vs_int8"] == 0.0, r
        assert r["code_bytes_ratio"] <= 0.5, r
        assert r["packed_stream_verified"], r
        assert r["int4_halves_code_bytes"], r
    # the fused epilogue must materialize fewer (M, N) arrays
    fused = next(r for n, r in rows.items()
                 if n.startswith("tdvmm_fused_epilogue_opcount"))
    assert fused["fused_beats_unfused_opcount"], fused
    # the data-calibrated readout must be single-pass (ONE (M, N) HBM write)
    # and bit-for-bit with the legacy two-pass path
    cal = next(r for n, r in rows.items()
               if n.startswith("tdvmm_calibrated_epilogue"))
    assert cal["single_mn_write"], cal
    assert cal["max_abs_diff_fused_vs_unfused"] == 0.0, cal
    assert cal["max_abs_diff_vs_jnp"] == 0.0, cal
    # grouped projections (attn.qkv G=3, ssm.in_proj G=5) must run as ONE
    # launch with ONE input encode, bit-for-bit vs sequential — and the
    # ragged concat must not pad members beyond lane rounding
    grouped = [r for n, r in rows.items()
               if n.startswith("tdvmm_grouped_launch_count")]
    assert len(grouped) == 2, grouped
    for r in grouped:
        assert r["one_launch"] and r["grouped_launches"] == 1, r
        assert r["sequential_launches"] == r["group"], r
        assert r["encode_bytes_reduction"] == r["group"], r
        assert r["max_abs_diff_vs_sequential"] == 0.0, r
        assert r["max_abs_diff_jnp_vs_pallas"] == 0.0, r
        assert r["padded_n_overhead"] <= 1.05, r
    # autotuned pallas wall-clock: the model-shape rows must be table hits
    # with their chosen blocks recorded, and the headline shape must clear
    # the 3x-over-pre-autotune floor (9.1 GFLOP/s before the table existed)
    for shape in ("512x1024x4096", "256x896x896"):
        r = rows[f"tdvmm_pallas_{shape}"]
        assert r["autotune_hit"], r
        assert len(r["plan_blocks"]) == 3, r
    assert rows["tdvmm_pallas_512x1024x4096"]["gflops_per_s"] >= 27.3, \
        rows["tdvmm_pallas_512x1024x4096"]
    if baseline is not None:
        base_rows = {r["name"]: r for r in baseline.get("rows", [])}
        for shape in ("512x1024x4096", "256x896x896"):
            pk, jk = f"tdvmm_pallas_{shape}", f"tdvmm_jnp_{shape}"
            if pk not in base_rows or jk not in base_rows:
                continue
            base_ratio = (base_rows[pk]["us_per_call"]
                          / base_rows[jk]["us_per_call"])
            ratio = rows[pk]["us_per_call"] / rows[jk]["us_per_call"]
            assert ratio <= base_ratio * 1.25, (
                f"pallas/jnp ratio regressed at {shape}: "
                f"{ratio:.2f} vs baseline {base_ratio:.2f}")


def run():
    from repro.kernels.tdvmm import ops as tdops

    reset_rows()
    tdops.reset_autotune_report()
    k = jax.random.PRNGKey(0)

    bench_tdvmm_backends()
    bench_int8_vs_f32_codes()
    bench_int4_packing()
    bench_fused_epilogue()
    bench_grouped_projection()

    # tdvmm: jnp reference path (the kernel's oracle); AI accounting
    m, kk, n = 512, 2048, 512
    xq = jnp.round(jax.random.uniform(k, (m, kk), minval=-63, maxval=63))
    wq = jnp.round(jax.random.uniform(k, (kk, n), minval=-63, maxval=63))
    xs, ws = jnp.ones((m,)), jnp.ones((n,))
    fn = jax.jit(lambda a, b: tdvmm_matmul_ref(a, b, xs, ws, 1.0))
    us = time_call(fn, xq, wq)
    flops = 2 * m * kk * n
    emit("tdvmm_ref_512x2048x512", us,
         f"GFLOP/s={flops/us*1e-3:.1f}|AI_flops_per_byte="
         f"{flops/((m*kk+kk*n+m*n)*4):.0f}")

    # crossing: exact sort-based solve; the kernel replaces 30 HBM sweeps
    b, kk2, n2 = 8, 256, 512
    t_on = jax.random.uniform(k, (b, kk2))
    cur = jax.random.uniform(k, (kk2, n2), minval=0.01)
    fn2 = jax.jit(lambda t, c: crossing_ref(t, c, 0.3 * kk2))
    us2 = time_call(fn2, t_on, cur)
    emit("crossing_ref_8x256x512", us2,
         f"vmem_reuse_factor=iters(24)x|tile_KB={kk2*128*4//1024}")

    # ssd: chunked vs naive recurrence (the chunking win the kernel blocks)
    bb, L, H, P, G, S = 2, 512, 8, 64, 1, 64
    x = jax.random.normal(k, (bb, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(k, (bb, L, H))) * 0.1
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    bmat = jax.random.normal(k, (bb, L, G, S)) * 0.3
    cmat = jax.random.normal(k, (bb, L, G, S)) * 0.3
    f_naive = jax.jit(lambda *a: ssd_naive(*a)[0])
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    us_n = time_call(f_naive, x, dt, a_log, bmat, cmat, iters=3)
    us_c = time_call(f_chunk, x, dt, a_log, bmat, cmat, iters=3)
    emit("ssd_naive_L512", us_n, "token-recurrence")
    emit("ssd_chunked_L512", us_c, f"speedup_vs_naive={us_n/us_c:.1f}x")

    save_json("BENCH_kernels.json",
              meta={"suite": "kernels",
                    "autotune": tdops.autotune_report()})


if __name__ == "__main__":
    run()
