"""Kernel micro-benchmarks: Pallas (interpret mode on CPU — correctness-path
timing only; Mosaic compilation happens on real TPUs) vs the jnp reference
path, plus the arithmetic-intensity accounting that motivates each kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core.layers import TDVMMLayerConfig, td_matmul
from repro.kernels.crossing.ref import crossing_ref
from repro.kernels.ssd.ref import ssd_naive
from repro.kernels.tdvmm.ops import tdvmm_matmul
from repro.kernels.tdvmm.ref import tdvmm_matmul_ref
from repro.models.ssm import ssd_chunked


def bench_tdvmm_backends():
    """jnp vs Pallas parity + throughput at model shapes.

    On CPU the Pallas path runs in interpret mode (Python-level grid walk):
    the numbers quantify interpret overhead, not TPU performance — the point
    of the row pair is the parity column (max |jnp - pallas|, must be 0) and
    the jnp-path GFLOP/s at shapes a model actually emits.
    """
    for (m, k, n) in [(512, 1024, 4096), (256, 896, 896), (33, 300, 130)]:
        kx, kw = jax.random.split(jax.random.PRNGKey(m + n))
        xc = jnp.round(jax.random.uniform(kx, (m, k), minval=-63, maxval=63))
        wc = jnp.round(jax.random.uniform(kw, (k, n), minval=-63, maxval=63))
        xs = jnp.ones((m,))
        ws = jnp.ones((n,))
        flops = 2 * m * k * n
        outs = {}
        for backend in ("jnp", "pallas"):
            fn = jax.jit(functools.partial(
                tdvmm_matmul, gain=1e-4, out_bits=6, backend=backend))
            outs[backend] = fn(xc, wc, xs, ws)
            us = time_call(fn, xc, wc, xs, ws, iters=3)
            emit(f"tdvmm_{backend}_{m}x{k}x{n}", us,
                 f"GFLOP/s={flops/us*1e-3:.1f}")
        parity = float(jnp.max(jnp.abs(outs["jnp"] - outs["pallas"])))
        emit(f"tdvmm_parity_{m}x{k}x{n}", 0.0, f"max_abs_diff={parity}")

    # full layer path (encode -> integrate -> readout -> rescale)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 1024))
    w = jax.random.normal(jax.random.PRNGKey(2), (1024, 4096)) * 0.05
    for backend in ("jnp", "pallas"):
        cfg = TDVMMLayerConfig(enabled=True, backend=backend)
        fn = jax.jit(lambda x, w, cfg=cfg: td_matmul(x, w, cfg))
        us = time_call(fn, x, w, iters=3)
        emit(f"td_matmul_layer_{backend}_256x1024x4096", us,
             f"GFLOP/s={2*256*1024*4096/us*1e-3:.1f}")


def run():
    k = jax.random.PRNGKey(0)

    bench_tdvmm_backends()

    # tdvmm: jnp reference path (the kernel's oracle); AI accounting
    m, kk, n = 512, 2048, 512
    xq = jnp.round(jax.random.uniform(k, (m, kk), minval=-63, maxval=63))
    wq = jnp.round(jax.random.uniform(k, (kk, n), minval=-63, maxval=63))
    xs, ws = jnp.ones((m,)), jnp.ones((n,))
    fn = jax.jit(lambda a, b: tdvmm_matmul_ref(a, b, xs, ws, 1.0))
    us = time_call(fn, xq, wq)
    flops = 2 * m * kk * n
    emit("tdvmm_ref_512x2048x512", us,
         f"GFLOP/s={flops/us*1e-3:.1f}|AI_flops_per_byte="
         f"{flops/((m*kk+kk*n+m*n)*4):.0f}")

    # crossing: exact sort-based solve; the kernel replaces 30 HBM sweeps
    b, kk2, n2 = 8, 256, 512
    t_on = jax.random.uniform(k, (b, kk2))
    cur = jax.random.uniform(k, (kk2, n2), minval=0.01)
    fn2 = jax.jit(lambda t, c: crossing_ref(t, c, 0.3 * kk2))
    us2 = time_call(fn2, t_on, cur)
    emit("crossing_ref_8x256x512", us2,
         f"vmem_reuse_factor=iters(24)x|tile_KB={kk2*128*4//1024}")

    # ssd: chunked vs naive recurrence (the chunking win the kernel blocks)
    bb, L, H, P, G, S = 2, 512, 8, 64, 1, 64
    x = jax.random.normal(k, (bb, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(k, (bb, L, H))) * 0.1
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    bmat = jax.random.normal(k, (bb, L, G, S)) * 0.3
    cmat = jax.random.normal(k, (bb, L, G, S)) * 0.3
    f_naive = jax.jit(lambda *a: ssd_naive(*a)[0])
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    us_n = time_call(f_naive, x, dt, a_log, bmat, cmat, iters=3)
    us_c = time_call(f_chunk, x, dt, a_log, bmat, cmat, iters=3)
    emit("ssd_naive_L512", us_n, "token-recurrence")
    emit("ssd_chunked_L512", us_c, f"speedup_vs_naive={us_n/us_c:.1f}x")


if __name__ == "__main__":
    run()
