"""Paper section 4.2 comparison table — the proposed TD-VMM vs previously
reported mixed-signal VMMs (numbers quoted from the paper's references)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import energy

PRIOR = [
    ("FG/CMOS current-mode 180nm [14]", 5.67e3, "measured"),
    ("CMOS current-mode 3-bit 180nm [12]", 6.39e3, "estimated"),
    ("switch-cap 3-bit 40nm [16]", 7.70e3, "measured"),
    ("memristive 4-bit 22nm [7]", 60.0e3, "estimated"),
    ("ReRAM 8-bit 14nm [13]", 181.8e3, "estimated"),
]


def run():
    ours_n1000 = energy.cost(1000).tops_per_j * 1e3   # GOps/J
    ours_n100 = energy.cost(100).tops_per_j * 1e3
    for name, gops, kind in PRIOR:
        emit(f"cmp_{name.split(' ')[0]}", 0.0,
             f"GOps/J={gops:.0f}|{kind}|ours_N1000={ours_n1000:.0f}|"
             f"speedup={ours_n1000/gops:.1f}x")
    emit("cmp_ours_summary", 0.0,
         f"N100_GOps/J={ours_n100:.0f}|N1000_GOps/J={ours_n1000:.0f}|"
         f"paper>150TOps/J_at_N1000={'Y' if ours_n1000 > 145e3 else 'N'}")


if __name__ == "__main__":
    run()
