"""Paper section 3 case study — the 10x10x10 two-layer perceptron built from
two four-quadrant TD-VMMs + AND-gate ReLU, computed fully in the time domain
(event-driven crossing simulation), vs its ideal digital twin; plus the
pipelined timing and per-inference energy of the implemented network."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import energy, tdcore
from repro.core.constants import TDVMMSpec


def run():
    spec = TDVMMSpec(bits=6)
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.uniform(k1, (10, 10), minval=-1, maxval=1)
    w2 = jax.random.uniform(k2, (10, 10), minval=-1, maxval=1)
    xb = jax.random.uniform(k3, (64, 10), minval=-1, maxval=1)

    sim = jax.jit(lambda xb: tdcore.td_mlp_forward_batched(xb, w1, w2, spec))
    ideal = jax.jit(lambda xb: jax.vmap(
        lambda x: tdcore.ideal_mlp(x, w1, w2, 1.0))(xb))
    us = time_call(sim, xb)
    err = float(jnp.max(jnp.abs(sim(xb) - ideal(xb))))
    emit("perceptron_10x10x10_sim_vs_ideal", us, f"max_err={err:.2e}")

    sched = tdcore.pipeline_schedule(2, 64, spec)
    emit("perceptron_pipelined_64_samples", 0.0,
         f"period_ns={sched['period_s']*1e9:.0f}|total_us={sched['total_s']*1e6:.2f}")

    # energy of the implemented circuit: two 10x10 four-quadrant VMMs
    c = energy.cost(10, bits=6)
    emit("perceptron_energy_per_inference", 0.0,
         f"pJ={2*c.e_total_j*1e12:.2f}|paper_single_vmm_pJ=5.44")


if __name__ == "__main__":
    run()
