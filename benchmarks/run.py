"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_precision    — Fig. 4  (DIBL error surface, effective bits)
  * bench_energy_area  — Fig. 5  (energy + area vs N, section 4.2 anchors)
  * bench_latency      — section 4.2 latency / Fig. 2d pipelining
  * bench_comparison   — section 4.2 prior-work comparison table
  * bench_perceptron   — section 3 case study (10x10x10 time-domain MLP)
  * bench_kernels      — Pallas kernel reference-path micro-benches
  * bench_llm_mapping  — beyond-paper: assigned archs costed on TD-VMM tiles
  * bench_serving      — continuous-batching engine on a ragged trace
  * roofline_report    — dry-run roofline terms per (arch x shape x mesh)

After the sweep the JSON reports' invariants are re-asserted in the same
run (``bench_kernels.check_invariants`` + ``bench_serving.check_invariants``
— the one-command version of the CI bench-smoke gates), so a stale
``BENCH_kernels.json`` can't silently drift from the code that claims it.
"""
from __future__ import annotations

import json
import traceback


def main() -> None:
    from benchmarks import (bench_comparison, bench_energy_area,
                            bench_kernels, bench_latency, bench_llm_mapping,
                            bench_perceptron, bench_precision, bench_serving,
                            roofline_report)
    print("name,us_per_call,derived")
    failed = False
    for mod in (bench_precision, bench_energy_area, bench_latency,
                bench_comparison, bench_perceptron, bench_kernels,
                bench_llm_mapping, bench_serving, roofline_report):
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — benches are independent
            failed = True
            print(f"{mod.__name__},ERROR,see_stderr")
            traceback.print_exc()
    for path, checker in (("BENCH_kernels.json", bench_kernels.check_invariants),
                          ("BENCH_serving.json", bench_serving.check_invariants)):
        try:
            with open(path) as f:
                checker(json.load(f))
            print(f"{path}: invariants OK")
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{path}: INVARIANT FAILURE")
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
