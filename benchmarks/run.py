"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_precision    — Fig. 4  (DIBL error surface, effective bits)
  * bench_energy_area  — Fig. 5  (energy + area vs N, section 4.2 anchors)
  * bench_latency      — section 4.2 latency / Fig. 2d pipelining
  * bench_comparison   — section 4.2 prior-work comparison table
  * bench_perceptron   — section 3 case study (10x10x10 time-domain MLP)
  * bench_kernels      — Pallas kernel reference-path micro-benches
  * bench_llm_mapping  — beyond-paper: assigned archs costed on TD-VMM tiles
  * roofline_report    — dry-run roofline terms per (arch x shape x mesh)
"""
from __future__ import annotations

import traceback


def main() -> None:
    from benchmarks import (bench_comparison, bench_energy_area,
                            bench_kernels, bench_latency, bench_llm_mapping,
                            bench_perceptron, bench_precision,
                            roofline_report)
    print("name,us_per_call,derived")
    for mod in (bench_precision, bench_energy_area, bench_latency,
                bench_comparison, bench_perceptron, bench_kernels,
                bench_llm_mapping, roofline_report):
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — benches are independent
            print(f"{mod.__name__},ERROR,see_stderr")
            traceback.print_exc()


if __name__ == "__main__":
    main()
