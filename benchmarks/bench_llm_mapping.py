"""Beyond-paper: cost out deploying every assigned architecture's linear
layers onto TD-VMM tiles (section 4.2's time-division-multiplexed reuse),
reporting energy/token and effective TOps/J per arch at the 6-bit operating
point."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import ARCHS, get_config
from repro.core import energy


def _linear_shapes(cfg) -> list[tuple[int, int]]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    shapes = []
    per_layer = [
        (d, cfg.n_heads * hd), (d, cfg.n_kv_heads * hd),
        (d, cfg.n_kv_heads * hd), (cfg.n_heads * hd, d)]
    gated = cfg.act == "silu_glu"

    def ffn(dff):
        return ([(d, dff)] * (2 if gated else 1)) + [(dff, d)]

    if cfg.family in ("dense", "vlm", "audio"):
        for _ in range(cfg.n_layers):
            shapes += per_layer + ffn(cfg.d_ff)
    elif cfg.family == "moe":
        m = cfg.moe
        for _ in range(cfg.n_layers):
            shapes += per_layer
            # only activated experts consume energy per token (weight-
            # stationary tiles idle when unselected)
            for _ in range(m.top_k + m.n_shared_experts):
                shapes += ffn(m.d_ff)
    elif cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        d_inner = s.expand * d
        n_h = d_inner // s.head_dim
        for _ in range(cfg.n_layers):
            shapes += [(d, d_inner), (d, d_inner),
                       (d, s.n_groups * s.d_state), (d, s.n_groups * s.d_state),
                       (d, n_h), (d_inner, d)]
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            n_shared = cfg.n_layers // cfg.hybrid_attn_every
            for _ in range(n_shared):
                shapes += per_layer + ffn(cfg.d_ff)
    return shapes


def run():
    for name in sorted(ARCHS):
        cfg = get_config(name)
        out = energy.llm_mapping_cost(_linear_shapes(cfg), tile_n=1024, bits=6)
        emit(f"llm_map_{name}", 0.0,
             f"tiles={out['tiles']:.0f}|energy/token_uJ={out['energy_per_token_j']*1e6:.2f}|"
             f"TOps/J={out['tops_per_j']:.0f}|"
             f"token_latency_ns={out['latency_per_token_s']*1e9:.0f}")


if __name__ == "__main__":
    run()
