"""Paper Fig. 4 — relative output error vs (I_max, V_SG) and vs V_D.

Reproduces the measured trends: V_SG optimum at ~0.8 V, error < 2% at
I_max ~ 1 uA => >= 5-6 bit computing precision; plus the end-to-end layer
error at the chosen operating point."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import nonideal
from repro.core.constants import DELTA_VD, V_SG_OPT
from repro.core.layers import TDVMMLayerConfig, td_matmul


def run():
    # Fig 4a: error surface over (V_SG, I_max)
    for vsg in (0.6, 0.7, 0.8, 0.9, 1.0):
        for imax in (1e-8, 1e-7, 1e-6, 2e-6):
            us = time_call(nonideal.relative_error, imax, vsg, DELTA_VD)
            e = float(nonideal.relative_error(imax, vsg, DELTA_VD))
            emit(f"fig4a_err_vsg{vsg}_imax{imax:.0e}", us,
                 f"error={e*100:.2f}%")
    # Fig 4b: error vs drain swing at the optimum
    for dv in (0.1, 0.2, 0.3, 0.4):
        e = float(nonideal.relative_error(1e-6, V_SG_OPT, dv))
        emit(f"fig4b_err_dvd{dv}", 0.0, f"error={e*100:.2f}%")
    # headline: effective precision at the paper's operating point
    e_opt = float(nonideal.relative_error(1e-6, V_SG_OPT, DELTA_VD))
    bits = int(nonideal.effective_bits(e_opt))
    emit("fig4_effective_bits_at_opt", 0.0,
         f"err={e_opt*100:.2f}%|bits={bits}|paper>=5")

    # end-to-end layer error vs precision (the ~6-bit ceiling in practice)
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (16, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128)) * 0.1
    ref = x @ w
    for bits in (4, 5, 6, 8):
        cfg = TDVMMLayerConfig(enabled=True, bits=bits, weight_bits=bits)
        fn = jax.jit(lambda x, w: td_matmul(x, w, cfg))
        us = time_call(fn, x, w)
        rel = float(jnp.max(jnp.abs(fn(x, w) - ref)) / jnp.max(jnp.abs(ref)))
        emit(f"tdvmm_layer_{bits}bit_256x128", us, f"rel_err={rel*100:.2f}%")


if __name__ == "__main__":
    run()
