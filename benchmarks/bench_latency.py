"""Paper section 4.2 — latency/throughput: 2T = 2*T0*2^p per precision bit,
pipelined period 2T + tau_reset, and the two-layer pipelined timeline of
Fig. 2d."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import tdcore
from repro.core.constants import TDVMMSpec


def run():
    for p in (4, 5, 6, 8):
        spec = TDVMMSpec(bits=p)
        c = spec.latency_s
        emit(f"latency_p{p}", 0.0,
             f"2T_ns={2*spec.t_window_s*1e9:.1f}|period_ns={c*1e9:.1f}|"
             f"paper_6bit~100ns={'Y' if p==6 else '-'}")
    # Fig. 2d pipelined operation
    for stages, samples in ((2, 1000), (4, 1000)):
        s = tdcore.pipeline_schedule(stages, samples, TDVMMSpec(bits=6))
        emit(f"fig2d_pipeline_{stages}stage_{samples}samples", 0.0,
             f"period_ns={s['period_s']*1e9:.1f}|total_us={s['total_s']*1e6:.1f}|"
             f"Msamples/s={s['throughput_samples_per_s']/1e6:.2f}")
    # throughput per tile at N=1000
    spec = TDVMMSpec(bits=6)
    n = 1000
    ops = 2.0 * n * n
    emit("tile_throughput_N1000_6bit", 0.0,
         f"GOps/s={ops/spec.latency_s/1e9:.1f}")


if __name__ == "__main__":
    run()
