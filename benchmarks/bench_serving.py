"""Serving benchmark: a ragged synthetic trace through the
continuous-batching TD-VMM engine (``runtime/engine.py``).

Replays a fixed-seed trace (mixed prompt lengths, Poisson-ish arrival gaps,
per-request decode budgets) through the paged engine for two plan configs —
``ffn`` TD-VMM **unchained** vs **time-domain chained** (``ffn.in`` ->
``ffn.out``, Fig. 2: the intermediate p-bit readout disappears) — and emits
``BENCH_serving.json``: throughput, p50/p99 latency proxies
(steps-in-system), slot utilization, paged-KV memory high-water, and the
paper's currency measured at request level: fJ/Op, J/token,
tokens-per-joule.

Invariants (asserted by ``check_invariants`` in CI and ``benchmarks/run.py``):

  * the engine drains the ragged trace in fewer wall-steps than the legacy
    static uniform-batch ``serve()`` schedule, at higher decode utilization;
  * paged KV memory high-water < the dense ``batch * max_len`` allocation;
  * zero NaN logit rows (evict-before-poison), exactly TWO compiled steps;
  * per-request streams bit-identical to running the request alone at the
    same calibrated windows;
  * the chained plan spends fewer joules per token than the unchained one;
  * an engine killed mid-trace and restored from its snapshot resumes the
    remaining trace bit-identically to the uninterrupted baseline;
  * injected device-current drift triggers >= 1 online recalibration with
    ``compiled_steps`` still exactly 2 (hot-swapped runtime windows);
  * SLA scheduling (``serving_sla``): every admitted feasible deadline is
    hit, an infeasible request is rejected at admission with zero compute,
    an over-budget request degrades gracefully with neighbors bit-equal to
    their solo runs;
  * telemetry (``serving_telemetry_spike``): an injected straggler step
    raises exactly one rolling-median spike alert at the injected step,
    with zero false positives on the clean warm trace (metrics stream to
    ``BENCH_serving_metrics.jsonl``);
  * tracing (``serving_trace``): a traced replay streams bit-identically
    to the untraced reference, its Chrome trace validates (balanced B/E
    spans, monotonic timestamps per thread) with span boundaries matching
    the report's finish steps, and the per-site attribution table sums
    **bit-exactly** to the aggregate analog-ops / energy / fJ/Op counters
    (the chained plan's saved inter-site I/O is explicit per site);
  * tracing overhead (``serving_trace_overhead``): median tick latency
    with tracing on <= 1.05x tracing off — span bookkeeping is host-side
    and never touches the two compiled step programs.

Wall timings route through ``benchmarks.common`` (warmup + median of
repeats, spread recorded per row) so serving numbers carry the same
trust annotations as the kernel suite's.
"""
from __future__ import annotations

from pathlib import Path

import jax
import numpy as np

from benchmarks.common import Timing, emit, reset_rows, save_json, time_host
from repro.configs import TDVMMPlan, get_config, smoke, tdvmm_rule
from repro.models import model
from repro.runtime.engine import Engine, EngineConfig, Request, static_baseline

METRICS_JSONL = "BENCH_serving_metrics.jsonl"

ARCH = "qwen1.5-0.5b"

PLANS = {
    "ffn_unchained": TDVMMPlan(rules=(
        tdvmm_rule("ffn.*", enabled=True, backend="auto"),)),
    "ffn_chained": TDVMMPlan(rules=(
        tdvmm_rule("ffn.*", enabled=True, backend="auto"),
        tdvmm_rule("ffn.in", chain=True))),
}


def make_trace(vocab: int, n_requests: int = 10, seed: int = 0,
               prompt_lo: int = 4, prompt_hi: int = 14,
               gen_lo: int = 2, gen_hi: int = 25,
               max_gap: int = 1) -> list[Request]:
    """Fixed-seed ragged trace: uniform prompt/budget mix, arrival gaps
    drawn from [0, max_gap] (the Poisson-ish schedule — deterministic, so
    the scheduler-determinism and bit-identity invariants are replayable)."""
    rng = np.random.default_rng(seed)
    reqs, arrival = [], 0
    for rid in range(n_requests):
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(
                0, vocab, rng.integers(prompt_lo, prompt_hi))),
            max_new_tokens=int(rng.integers(gen_lo, gen_hi)),
            arrival_step=arrival))
        arrival += int(rng.integers(0, max_gap + 1))
    return reqs


def _dense_cache_bytes(cfg, batch: int, max_len: int) -> int:
    shapes = jax.eval_shape(lambda: model.init_caches(cfg, batch, max_len))
    return int(sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(shapes)))


def _percentile(xs: list[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run(n_requests: int = 10):
    reset_rows()
    base = smoke(get_config(ARCH))
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, base)
    trace = make_trace(base.vocab_size, n_requests=n_requests)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in trace)
    # tile_n=64 matches the smoke model's d_model (a 256-tile would be >75%
    # padding waste on 64-wide matrices and swamp the fJ/Op signal); the
    # block-table width is sized to the longest request, not the pool, so
    # per-step attention doesn't span mostly-trash pages.
    from repro.runtime.paged_cache import pages_for
    ecfg = EngineConfig(slots=4, page_size=4, num_pages=64, chunk=8, tile_n=64,
                        max_pages_per_slot=pages_for(max_len, 4))

    static = static_baseline(trace, ecfg.slots, ecfg.chunk)
    dense_bytes = _dense_cache_bytes(base, ecfg.slots, max_len)

    reports, plan_ctx = {}, {}
    for name, plan in PLANS.items():
        cfg = base.replace(tdvmm_plan=plan)
        calib_batch = {"inputs": jax.random.randint(
            jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
        calib = model.calibrate(params, calib_batch, cfg, max_len=32)
        plan_ctx[name] = (cfg, calib, calib_batch)
        # One engine reused across warmup + repeats: run() re-initializes
        # all serving state, the instance keeps its jit caches, so the
        # median is post-compile wall time (PR 6 timing hygiene).
        engine = Engine(cfg, params, ecfg, calib=calib)
        rep, wall = time_host(lambda: engine.run(trace))
        reports[name] = rep

        # bit-identity: the first two requests replayed alone (B=1, same
        # chunking + calibrated windows) must stream identical tokens.
        solo_ok = True
        solo_ecfg = EngineConfig(slots=1, page_size=ecfg.page_size,
                                 num_pages=ecfg.num_pages, chunk=ecfg.chunk,
                                 max_pages_per_slot=ecfg.max_pages_per_slot)
        for req in trace[:2]:
            solo = Engine(cfg, params, solo_ecfg, calib=calib).run(
                [Request(req.rid, req.prompt, req.max_new_tokens, 0)])
            got = next(r for r in rep.requests if r["rid"] == req.rid)
            solo_ok &= solo.requests[0]["tokens"] == got["tokens"]

        sis = [r["steps_in_system"] for r in rep.requests
               if r["finished_step"] >= 0]
        tokens_proc = rep.prompt_tokens + rep.generated_tokens
        # us_per_call = median post-warmup wall time PER ENGINE STEP, with
        # the repeat count and (per-step) spread riding on the Timing.
        steps = max(rep.steps, 1)
        emit(f"serving_engine_{name}",
             Timing(float(wall) / steps, wall.repeats,
                    wall.spread_us / steps),
             f"steps={rep.steps}|util={rep.utilization:.2f}"
             f"|fJ_per_op={rep.fj_per_op:.2f}",
             data={
                 "requests": len(trace),
                 "wall_steps": rep.steps,
                 "prefill_steps": rep.prefill_steps,
                 "decode_steps": rep.decode_steps,
                 "idle_steps": rep.idle_steps,
                 "generated_tokens": rep.generated_tokens,
                 "prompt_tokens": rep.prompt_tokens,
                 "tok_per_s_wall":
                     rep.generated_tokens / max(float(wall) / 1e6, 1e-9),
                 "utilization": rep.utilization,
                 "evictions": rep.evictions,
                 "nan_logit_steps": rep.nan_logit_steps,
                 "p50_steps_in_system": _percentile(sis, 50),
                 "p99_steps_in_system": _percentile(sis, 99),
                 "page_high_water": rep.page_high_water,
                 "kv_high_water_bytes": rep.kv_high_water_bytes,
                 "analog_ops": rep.analog_ops,
                 "analog_energy_j": rep.analog_energy_j,
                 "fj_per_op": rep.fj_per_op,
                 "j_per_token": (rep.analog_energy_j / tokens_proc
                                 if tokens_proc else 0.0),
                 "tokens_per_joule": rep.tokens_per_joule,
                 "compiled_steps": rep.compiled_steps,
                 "bit_identical_solo": solo_ok,
             })

    ref = reports["ffn_unchained"]
    emit("serving_vs_static", 0.0,
         f"engine={ref.steps}steps vs static={static['wall_steps']}",
         data={
             "engine_wall_steps": ref.steps,
             "static_wall_steps": static["wall_steps"],
             "engine_beats_static_steps": ref.steps < static["wall_steps"],
             "engine_utilization": ref.utilization,
             "static_utilization": static["utilization"],
             "engine_beats_static_utilization":
                 ref.utilization > static["utilization"],
             "kv_high_water_bytes": ref.kv_high_water_bytes,
             "dense_cache_bytes": dense_bytes,
             "paged_beats_dense_memory":
                 ref.kv_high_water_bytes < dense_bytes,
         })

    un, ch = reports["ffn_unchained"], reports["ffn_chained"]
    emit("serving_energy_chained_vs_unchained", 0.0,
         f"J/tok {ch.analog_energy_j:.3g} vs {un.analog_energy_j:.3g}",
         data={
             "unchained_energy_j": un.analog_energy_j,
             "chained_energy_j": ch.analog_energy_j,
             "unchained_tokens_per_joule": un.tokens_per_joule,
             "chained_tokens_per_joule": ch.tokens_per_joule,
             "chained_saves_energy":
                 ch.analog_energy_j < un.analog_energy_j,
         })

    # --- fault tolerance: kill mid-trace, snapshot, restore, resume -------
    # The hard contract: the resumed run's per-request streams are
    # bit-identical to the uninterrupted baseline (ref above).
    import tempfile

    from repro.checkpoint import checkpoint
    from repro.runtime import faultinject as fi
    from repro.runtime.engine import DriftConfig, FaultConfig

    cfg_u, calib_u, calib_batch_u = plan_ctx["ffn_unchained"]
    preempt_step = max(1, ref.steps // 2)
    with tempfile.TemporaryDirectory() as td:
        e1 = Engine(cfg_u, params, ecfg, calib=calib_u)
        r1 = e1.run(trace, FaultConfig(
            injector=fi.FaultInjector([fi.PreemptAt(preempt_step)]),
            snapshot_dir=td))
        flat, snap_step = checkpoint.load_engine_snapshot(td)
        e2 = Engine(cfg_u, params, ecfg, calib=calib_u)
        e2.restore(flat)
        r2 = e2.resume()
    streams_match = all(
        a["tokens"] == b["tokens"]
        for a, b in zip(ref.requests, r2.requests))
    reasons_match = all(
        a["finish_reason"] == b["finish_reason"]
        and a["finished_step"] == b["finished_step"]
        for a, b in zip(ref.requests, r2.requests))
    emit("serving_crash_resume", 0.0,
         f"killed@{preempt_step}/{ref.steps} steps, resumed bit-identical="
         f"{streams_match}",
         data={
             "preempt_step": preempt_step,
             "baseline_steps": ref.steps,
             "preempted": r1.preempted,
             "snapshot_step": snap_step,
             "resumed_steps": r2.steps,
             "streams_match": streams_match,
             "finish_reasons_match": reasons_match,
             "compiled_steps_resumed": e2.compiled_steps(),
         })

    # --- drift + online recalibration: perturb device currents mid-trace;
    # the probe must flag it and hot-swap windows WITHOUT a third compiled
    # program (compiled_steps stays 2).
    drift_step = max(1, ref.steps // 3)
    e3 = Engine(cfg_u, params, ecfg, calib=calib_u)
    r3 = e3.run(trace, FaultConfig(
        injector=fi.FaultInjector(
            [fi.DriftAt(drift_step, sigma=0.5, repeats=3)]),
        drift=DriftConfig(probe_batch=calib_batch_u,
                          check_every=max(1, ref.steps // 4),
                          clip_threshold=0.01, window_tol=0.1)))
    emit("serving_drift_recalibration", 0.0,
         f"{len(r3.drift_events)} drift events, {r3.recalibrations} "
         f"recalibrations, compiled={r3.compiled_steps}",
         data={
             "drift_step": drift_step,
             "drift_events": len(r3.drift_events),
             "recalibrations": r3.recalibrations,
             "max_log_ratio": (r3.drift_events[0]["max_log_ratio"]
                               if r3.drift_events else 0.0),
             "max_clip_rate": (r3.drift_events[0]["max_clip_rate"]
                               if r3.drift_events else 0.0),
             "compiled_steps": r3.compiled_steps,
             "nan_logit_steps": r3.nan_logit_steps,
         })

    # --- SLA scheduling: priorities, deadline admission control, joule
    # budgets (runtime/sla.py priced by core.energy.serving_energy_model).
    from repro.runtime.sla import SlaConfig, min_steps_to_finish

    sla_cfg = SlaConfig(aging_steps=8)
    # Every base request: cycled priorities + a generously feasible
    # deadline (the engine drains the whole trace well inside 2x the
    # static-batch schedule) -> hit-rate must be exactly 1.0.
    feasible_deadline = 2 * static["wall_steps"] + 32
    sla_trace = [Request(r.rid, r.prompt, r.max_new_tokens, r.arrival_step,
                         priority=r.rid % 3,
                         deadline_steps=feasible_deadline)
                 for r in trace]
    # Deadline-infeasible: even immediate exclusive service needs
    # min_steps_to_finish steps; deadline 1 can never be met -> rejected
    # at admission, zero tokens, zero joules.
    infeasible = Request(900, prompt=trace[0].prompt, max_new_tokens=20,
                         deadline_steps=1)
    assert min_steps_to_finish(infeasible, ecfg.chunk) > 2
    # Joule-budgeted: enough for the prompt + ~2.5 tokens of its 6-token
    # budget -> admitted (min work fits) but finished over_budget
    # mid-stream.
    eng_sla = Engine(cfg_u, params, ecfg, calib=calib_u, sla=sla_cfg)
    e_tok = eng_sla.energy["energy_per_token_j"]
    budgeted = Request(901, prompt=trace[1].prompt, max_new_tokens=6,
                       joule_budget=(len(trace[1].prompt) + 2.5) * e_tok)
    rep_sla = eng_sla.run(sla_trace + [infeasible, budgeted])
    by_sla = {r["rid"]: r for r in rep_sla.requests}
    ref_by = {r["rid"]: r for r in ref.requests}
    # Request isolation survives SLA reordering: every base request's
    # stream is bit-equal to the plain-FIFO run's (itself proven
    # bit-identical to solo replays above).
    neighbors_ok = all(by_sla[r.rid]["tokens"] == ref_by[r.rid]["tokens"]
                       for r in trace)
    rej = by_sla[900]
    ob = by_sla[901]
    hit_denom = rep_sla.deadline_hits + rep_sla.deadline_misses
    hit_rate = rep_sla.deadline_hits / hit_denom if hit_denom else 0.0
    emit("serving_sla", 0.0,
         f"deadline_hit_rate={hit_rate:.2f}|rejected={rep_sla.rejected}"
         f"|over_budget={rep_sla.over_budget}",
         data={
             "aging_steps": sla_cfg.aging_steps,
             "feasible_deadline_steps": feasible_deadline,
             "deadline_hits": rep_sla.deadline_hits,
             "deadline_misses": rep_sla.deadline_misses,
             "deadline_hit_rate": hit_rate,
             "rejected": rep_sla.rejected,
             "rejected_zero_compute":
                 rej["finish_reason"] == "rejected"
                 and rej["tokens"] == [] and rej["joules_used"] == 0.0,
             "reject_reason": rej["reject_reason"],
             "over_budget": rep_sla.over_budget,
             "over_budget_partial_stream":
                 ob["finish_reason"] == "over_budget"
                 and 0 < len(ob["tokens"]) < budgeted.max_new_tokens,
             "over_budget_joules_used": ob["joules_used"],
             "over_budget_joule_budget": ob["joule_budget"],
             "neighbors_bit_equal_solo": neighbors_ok,
             "compiled_steps": rep_sla.compiled_steps,
         })

    # --- telemetry: rolling-median/MAD spike detection on step latency.
    # Warm the engine (jit-compile steps legitimately alert), then prove
    # the detector is quiet on a clean warm trace and fires EXACTLY once
    # on an injected straggler step.  All samples stream to the JSONL
    # artifact.
    from repro.runtime.telemetry import AlertRule, JsonlEmitter, MetricsSink

    Path(METRICS_JSONL).unlink(missing_ok=True)
    sink = MetricsSink(
        rules=[AlertRule("step_latency_s", kind="spike", k=6.0,
                         min_samples=6, abs_floor=0.05)],
        emitters=[JsonlEmitter(METRICS_JSONL)])
    e5 = Engine(cfg_u, params, ecfg, calib=calib_u, sink=sink)
    e5.run(trace)                         # warm (compile spikes expected)
    warm_alerts = len(sink.alerts)
    e5.run(trace)                         # clean warm run
    clean_fp = len(sink.alerts) - warm_alerts
    slow_step = max(1, ref.steps // 2)
    rep5 = e5.run(trace, FaultConfig(
        injector=fi.FaultInjector([fi.SlowStep(slow_step, sleep_s=0.3)])))
    injected = sink.alerts[warm_alerts + clean_fp:]
    for em in sink.emitters:
        em.close()
    emit("serving_telemetry_spike", 0.0,
         f"injected@{slow_step}: {len(injected)} alert(s), "
         f"clean_false_positives={clean_fp}",
         data={
             "slow_step": slow_step,
             "slow_sleep_s": 0.3,
             "clean_false_positives": clean_fp,
             "injected_alerts": len(injected),
             # the sink observes AFTER the tick lands, so the alert is
             # stamped at slow_step + 1
             "alert_at_injected_step":
                 len(injected) == 1 and injected[0].step == slow_step + 1,
             "alert_value_s": injected[0].value if injected else 0.0,
             "alert_limit_s": injected[0].limit if injected else 0.0,
             "sink_observations": sink.observations,
             "metrics_jsonl": METRICS_JSONL,
             "compiled_steps": rep5.compiled_steps,
         })

    # --- tracing & per-site attribution: a traced replay must be
    # bit-identical to the untraced reference, produce a schema-valid
    # Chrome trace whose request span boundaries match the report's finish
    # steps, and carry a per-site attribution table that sums bit-exactly
    # (left-to-right in table order) to the aggregate energy counters.
    from repro.runtime.trace import Tracer, validate_chrome_trace

    e6 = Engine(cfg_u, params, ecfg, calib=calib_u, tracer=Tracer())
    r6 = e6.run(trace)
    traced_streams_match = all(
        a["tokens"] == b["tokens"]
        and a["finish_reason"] == b["finish_reason"]
        and a["finished_step"] == b["finished_step"]
        for a, b in zip(ref.requests, r6.requests))
    counts = validate_chrome_trace(e6.tracer.chrome_trace())  # raises if bad
    summ = r6.trace_summary
    spans_match_report = all(
        summ["requests"][str(r["rid"])]["finished_step"]
        == r["finished_step"] for r in r6.requests)
    attr = r6.site_attribution
    ops_sum = e_sum = 0.0
    for srow in attr["per_site"].values():       # left-to-right, table order
        ops_sum += srow["ops"]
        e_sum += srow["energy_j"]
    site_sums_bit_exact = (
        ops_sum == r6.analog_ops and e_sum == r6.analog_energy_j
        and attr["fj_per_op"] == r6.fj_per_op
        and attr["tokens"] == r6.tokens_priced)
    attr_c = ch.site_attribution        # chained run: saved I/O per site
    emit("serving_trace", 0.0,
         f"{counts.get('B', 0)}B/{counts.get('E', 0)}E spans"
         f"|site_sums_exact={site_sums_bit_exact}",
         data={
             "traced_streams_match": traced_streams_match,
             "trace_event_counts": counts,
             "trace_ticks": summ["ticks"],
             "spans_match_report": spans_match_report,
             "site_sums_bit_exact": site_sums_bit_exact,
             "tokens_priced": r6.tokens_priced,
             "fj_per_op_by_site": {s: v["fj_per_op"]
                                   for s, v in attr["per_site"].items()},
             "chained_io_saved_j": attr_c["io_saved_j"],
             "chained_chains": attr_c["chains"],
             "compiled_steps": r6.compiled_steps,
         })

    # --- trace overhead: the span bookkeeping is pure host-side work, so
    # the traced engine's median tick must stay within 5% of untraced.
    # The engine is deterministic, so tick i of every replay does identical
    # work; each replay records its per-tick latencies through the engine's
    # own MetricsSink series (both engines carry a sink, so the comparison
    # isolates the tracer).  Runs alternate ABBA to cancel machine drift,
    # and the per-tick-index MIN across replays filters scheduler/GC spikes
    # before the medians are compared — a sequential A-then-B wall-clock
    # timing would book both noise sources as tracing cost.
    eng_off = Engine(cfg_u, params, ecfg, calib=calib_u, sink=MetricsSink())
    eng_on = Engine(cfg_u, params, ecfg, calib=calib_u, sink=MetricsSink(),
                    tracer=Tracer())
    eng_off.run(trace)
    eng_on.run(trace)                  # warm both jit caches

    def _tick_latencies(eng) -> np.ndarray:
        eng.sink = MetricsSink()       # fresh series per replay
        eng.run(trace)
        return np.asarray(list(eng.sink.series["step_latency_s"].values))

    pairs = 5
    offs, ons = [], []
    for i in range(pairs):             # ABBA: off/on order flips each pair
        order = (eng_off, eng_on) if i % 2 == 0 else (eng_on, eng_off)
        for eng in order:
            (offs if eng is eng_off else ons).append(_tick_latencies(eng))
    n_ticks = min(min(map(len, offs)), min(map(len, ons)))
    off_best = np.min([t[:n_ticks] for t in offs], axis=0)
    on_best = np.min([t[:n_ticks] for t in ons], axis=0)
    tick_off = float(np.median(off_best)) * 1e6
    tick_on = float(np.median(on_best)) * 1e6
    overhead_ratio = tick_on / max(tick_off, 1e-9)
    spread_on = float(np.ptp(on_best)) * 1e6
    emit("serving_trace_overhead",
         Timing(tick_on, pairs, spread_on),
         f"tick {tick_on:.1f}us traced vs {tick_off:.1f}us untraced "
         f"(x{overhead_ratio:.3f} over {n_ticks} paired ticks)",
         data={
             "tick_us_tracing_off": tick_off,
             "tick_us_tracing_on": tick_on,
             "pairs": pairs,
             "paired_ticks": n_ticks,
             "overhead_ratio": overhead_ratio,
             "overhead_bound": 1.05,
         })

    # --- mesh scaling: DP slot-pool linearity + per-request bit-identity.
    # Runs in a subprocess with 4 forced host devices so this process keeps
    # its single-device jax runtime (same pattern as the multidev tests).
    import json as _json
    import os
    import subprocess
    import sys

    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), str(repo)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    child = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.bench_serving import _mesh_scaling_child; "
         f"_mesh_scaling_child({int(n_requests)})"],
        env=env, capture_output=True, text=True, timeout=1800)
    assert child.returncode == 0, child.stderr[-3000:]
    line = [ln for ln in child.stdout.splitlines()
            if ln.startswith("MESH_RESULTS::")][0]
    mres = _json.loads(line.split("::", 1)[1])
    solo_m = mres.pop("solo")
    ref_streams = [{"rid": r["rid"], "tokens": r["tokens"],
                    "finish_reason": r["finish_reason"]}
                   for r in ref.requests]
    trivial = mres["1x1"]
    mesh_1x1_bit_identical = (
        trivial["streams"] == solo_m["streams"]
        and trivial["finished_steps"] == solo_m["finished_steps"]
        and trivial["steps"] == solo_m["steps"])
    per_request_ok = all(m["streams"] == ref_streams
                         for m in mres.values())
    slots_linear = all(m["total_slots"] == m["devices"] * ecfg.slots
                       for m in mres.values())
    emit("serving_mesh_scaling", 0.0,
         "tok/step " + "|".join(
             f"{k}={m['generated'] / max(m['steps'], 1):.2f}"
             for k, m in mres.items()),
         data={
             "slots_per_rank": ecfg.slots,
             "meshes": {k: {"devices": m["devices"],
                            "total_slots": m["total_slots"],
                            "wall_steps": m["steps"],
                            "generated_tokens": m["generated"],
                            "tokens_per_step":
                                m["generated"] / max(m["steps"], 1)}
                        for k, m in mres.items()},
             "solo_wall_steps": solo_m["steps"],
             "solo_matches_parent": solo_m["streams"] == ref_streams,
             "mesh_1x1_bit_identical": mesh_1x1_bit_identical,
             "per_request_bit_identity": per_request_ok,
             "slots_scale_linearly": slots_linear,
             "compiled_steps_by_mesh":
                 {k: m["compiled_steps"] for k, m in mres.items()},
         })

    from repro.kernels.tdvmm import ops as tdvmm_ops
    save_json("BENCH_serving.json",
              meta={"suite": "serving",
                    "autotune": tdvmm_ops.autotune_report()})


def _mesh_scaling_child(n_requests: int = 10) -> None:
    """Subprocess entry for the mesh-scaling row: replays the bench trace
    through the engine meshless and on (1,1)/(2,1)/(4,1) meshes.  Must run
    under ``--xla_force_host_platform_device_count=4`` (the parent sets it
    in the env before this interpreter starts, so it lands before the first
    jax import)."""
    import json

    from repro.launch.mesh import make_test_mesh
    from repro.runtime.paged_cache import pages_for

    base = smoke(get_config(ARCH))
    cfg = base.replace(tdvmm_plan=PLANS["ffn_unchained"])
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    calib_batch = {"inputs": jax.random.randint(
        jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    calib = model.calibrate(params, calib_batch, cfg, max_len=32)
    trace = make_trace(cfg.vocab_size, n_requests=n_requests)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in trace)
    ecfg = EngineConfig(slots=4, page_size=4, num_pages=64, chunk=8,
                        tile_n=64, max_pages_per_slot=pages_for(max_len, 4))

    def pack(rep):
        return {
            "steps": rep.steps, "devices": rep.devices,
            "total_slots": rep.total_slots,
            "generated": rep.generated_tokens,
            "compiled_steps": rep.compiled_steps,
            "streams": [{"rid": r["rid"], "tokens": r["tokens"],
                         "finish_reason": r["finish_reason"]}
                        for r in rep.requests],
            "finished_steps": [r["finished_step"] for r in rep.requests],
        }

    out = {"solo": pack(Engine(cfg, params, ecfg, calib=calib).run(trace))}
    for d, t in ((1, 1), (2, 1), (4, 1)):
        rep = Engine(cfg, params, ecfg, calib=calib,
                     mesh=make_test_mesh(d, t)).run(trace)
        out[f"{d}x{t}"] = pack(rep)
    print("MESH_RESULTS::" + json.dumps(out))


def check_invariants(doc: dict) -> None:
    """Assert the serving report's invariants (CI bench-smoke + run.py)."""
    rows = {r["name"]: r for r in doc["rows"]}
    engines = [r for n, r in rows.items() if n.startswith("serving_engine_")]
    assert len(engines) == 2, engines
    for r in engines:
        assert r["nan_logit_steps"] == 0, r          # evict-before-poison
        assert r["compiled_steps"] == 2, r           # two-compiled-step rule
        assert r["bit_identical_solo"], r            # request isolation
        assert r.get("timing_repeats", 0) >= 3, r    # median-of-repeats
        assert "timing_spread_us" in r, r            # spread recorded
    vs = rows["serving_vs_static"]
    assert vs["engine_beats_static_steps"], vs
    assert vs["engine_beats_static_utilization"], vs
    assert vs["paged_beats_dense_memory"], vs
    en = rows["serving_energy_chained_vs_unchained"]
    assert en["chained_saves_energy"], en
    cr = rows["serving_crash_resume"]
    assert cr["preempted"], cr                       # injection fired
    assert cr["streams_match"], cr                   # bit-identical resume
    assert cr["finish_reasons_match"], cr
    assert cr["compiled_steps_resumed"] <= 2, cr
    dr = rows["serving_drift_recalibration"]
    assert dr["recalibrations"] >= 1, dr             # drift caught + fixed
    assert dr["compiled_steps"] == 2, dr             # no third program
    sla = rows["serving_sla"]
    assert sla["deadline_hit_rate"] == 1.0, sla      # feasible trace: 100%
    assert sla["rejected"] >= 1, sla                 # infeasible rejected
    assert sla["rejected_zero_compute"], sla         # ...before any compute
    assert sla["over_budget"] >= 1, sla              # budget enforced
    assert sla["over_budget_partial_stream"], sla    # graceful degradation
    assert sla["neighbors_bit_equal_solo"], sla      # isolation under SLA
    assert sla["compiled_steps"] == 2, sla
    ts = rows["serving_telemetry_spike"]
    assert ts["clean_false_positives"] == 0, ts      # quiet when warm
    assert ts["injected_alerts"] == 1, ts            # exactly one spike
    assert ts["alert_at_injected_step"], ts          # at the right step
    assert ts["compiled_steps"] == 2, ts
    tr = rows["serving_trace"]
    assert tr["traced_streams_match"], tr            # tracing is pure
    assert tr["spans_match_report"], tr              # spans == finish steps
    assert tr["site_sums_bit_exact"], tr             # table sums == aggregate
    assert tr["chained_io_saved_j"] > 0.0, tr        # chain savings explicit
    assert tr["compiled_steps"] == 2, tr
    ov = rows["serving_trace_overhead"]
    assert ov["overhead_ratio"] <= ov["overhead_bound"], ov
    assert ov.get("pairs", 0) >= 5, ov               # ABBA replay pairs
    assert ov.get("paired_ticks", 0) >= 20, ov       # per-tick sample depth
    assert doc.get("autotune", {}).get("platform"), doc.get("autotune")
    ms = rows["serving_mesh_scaling"]
    assert set(ms["meshes"]) == {"1x1", "2x1", "4x1"}, ms
    assert ms["mesh_1x1_bit_identical"], ms          # (1,1) == no mesh exactly
    assert ms["per_request_bit_identity"], ms        # streams equal solo
    assert ms["solo_matches_parent"], ms             # runtime-independent
    assert ms["slots_scale_linearly"], ms            # DP pool: slots = dp * S
    for k, c in ms["compiled_steps_by_mesh"].items():
        assert c == 2, (k, c)                        # two programs per mesh


if __name__ == "__main__":
    run()
