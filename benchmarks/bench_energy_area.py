"""Paper Fig. 5 — energy and area per operation vs VMM size N (6-bit
digital-I/O conservative design), with component breakdowns, plus every
section-4.2 anchor number."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import energy


def run():
    ns = [10] + list(range(50, 1001, 50))
    for n in ns:
        c = energy.cost(n)
        emit(f"fig5a_energy_N{n}", 0.0,
             f"fJ/Op={c.e_per_op_j*1e15:.2f}|TOps/J={c.tops_per_j:.1f}|"
             f"static%={100*c.e_static_j/c.e_total_j:.0f}|"
             f"io%={100*c.e_io_j/c.e_total_j:.1f}")
        emit(f"fig5b_area_N{n}", 0.0,
             f"um2/op={c.area_um2/(2*n*n):.3f}|cap%={100*c.area_cap_um2/c.area_um2:.0f}|"
             f"mem%={100*c.area_mem_um2/c.area_um2:.0f}|"
             f"neuron%={100*c.area_neuron_um2/c.area_um2:.1f}")
    for key, (model, paper) in energy.validate_against_paper().items():
        emit(f"sec42_anchor_{key}", 0.0,
             f"model={model:.4g}|paper={paper:.4g}|"
             f"ok={'Y' if abs(model-paper)/max(abs(paper),1e-12)<0.12 else 'N'}")


if __name__ == "__main__":
    run()
