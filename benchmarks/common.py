"""Shared benchmark utilities: timing + CSV row emission + JSON reports."""
from __future__ import annotations

import json
import time
from typing import Callable, Optional

import jax

ROWS: list[dict] = []


def reset_rows() -> None:
    """Drop any rows emitted by earlier suites in the same process.

    Suites that write a JSON report call this first: ``save_json`` dumps
    every row since the last save, so without the reset a full
    ``benchmarks.run`` sweep would sweep print-only suites' rows (e.g.
    bench_llm_mapping) into the next report and the artifact would differ
    from the standalone ``python -m benchmarks.<suite>`` run."""
    ROWS[:] = []


class Timing(float):
    """A median-microseconds wall time that also carries how it was measured.

    Subclasses float (the median), so every existing consumer that divides
    or compares a ``time_call`` result is unchanged; ``emit`` additionally
    records the repeat count and min-to-max spread so a noisy median can't
    silently masquerade as a stable one in the JSON report.
    """
    repeats: int
    spread_us: float

    def __new__(cls, median_us: float, repeats: int, spread_us: float):
        self = super().__new__(cls, median_us)
        self.repeats = repeats
        self.spread_us = spread_us
        return self


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> Timing:
    """Median-of-``iters`` wall time per call in microseconds (post-jit).

    Every sample is ``block_until_ready``-fenced (async dispatch would
    otherwise time the enqueue, not the compute), warmup runs absorb
    compilation and first-touch allocation, and the min-to-max spread across
    the repeats rides along on the returned ``Timing``."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return Timing(times[len(times) // 2] * 1e6, iters,
                  (times[-1] - times[0]) * 1e6)


def time_host(fn: Callable, warmup: int = 1, iters: int = 3):
    """Median-of-``iters`` wall time for a *host-driven* callable (e.g. a
    full serving-engine run) -> ``(last_result, Timing)`` in microseconds.

    Same hygiene as ``time_call`` — warmup absorbs jit compilation, the
    median resists scheduler noise, and the min-to-max spread rides on the
    ``Timing`` — but without the ``block_until_ready`` fence: the callable
    is expected to synchronize internally (the engine's drive loop pulls
    every step's logits to the host).  The callable must be idempotent
    (each invocation re-initializes its own state) so the returned result
    is the same object every repeat would produce."""
    out = None
    for _ in range(warmup):
        out = fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return out, Timing(times[len(times) // 2] * 1e6, iters,
                       (times[-1] - times[0]) * 1e6)


def emit(name: str, us_per_call: float, derived: str,
         data: Optional[dict] = None):
    """Record (and print) one benchmark row.  ``data`` carries structured
    metrics (bytes moved, GB/s, speedups) into the JSON report."""
    row = {"name": name, "us_per_call": round(us_per_call, 1),
           "derived": derived}
    if isinstance(us_per_call, Timing):
        row["timing_repeats"] = us_per_call.repeats
        row["timing_spread_us"] = round(us_per_call.spread_us, 1)
    if data:
        row.update(data)
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(path: str, meta: Optional[dict] = None) -> str:
    """Dump every row emitted since the last save (plus run metadata) as a
    JSON report — the CI-tracked perf trajectory artifact
    (e.g. BENCH_kernels.json).  Snapshots and clears the row buffer so each
    suite's report contains only its own rows."""
    rows, ROWS[:] = list(ROWS), []
    doc = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        **(meta or {}),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {path} ({len(rows)} rows)")
    return path
