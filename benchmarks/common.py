"""Shared benchmark utilities: timing + CSV row emission + JSON reports."""
from __future__ import annotations

import json
import time
from typing import Callable, Optional

import jax

ROWS: list[dict] = []


def reset_rows() -> None:
    """Drop any rows emitted by earlier suites in the same process.

    Suites that write a JSON report call this first: ``save_json`` dumps
    every row since the last save, so without the reset a full
    ``benchmarks.run`` sweep would sweep print-only suites' rows (e.g.
    bench_llm_mapping) into the next report and the artifact would differ
    from the standalone ``python -m benchmarks.<suite>`` run."""
    ROWS[:] = []


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (post-jit)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str,
         data: Optional[dict] = None):
    """Record (and print) one benchmark row.  ``data`` carries structured
    metrics (bytes moved, GB/s, speedups) into the JSON report."""
    row = {"name": name, "us_per_call": round(us_per_call, 1),
           "derived": derived}
    if data:
        row.update(data)
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(path: str, meta: Optional[dict] = None) -> str:
    """Dump every row emitted since the last save (plus run metadata) as a
    JSON report — the CI-tracked perf trajectory artifact
    (e.g. BENCH_kernels.json).  Snapshots and clears the row buffer so each
    suite's report contains only its own rows."""
    rows, ROWS[:] = list(ROWS), []
    doc = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        **(meta or {}),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {path} ({len(rows)} rows)")
    return path
