"""Roofline table generator: reads artifacts/dryrun/*.json into the
EXPERIMENTS.md table and emits one CSV row per (arch x shape x mesh)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def rows(pod: str = "pod1"):
    out = []
    for f in sorted(ART.glob(f"*__{pod}.json")):
        d = json.loads(f.read_text())
        tag = f.stem
        if d["status"] != "ok":
            out.append((tag, d))
            continue
        out.append((tag, d))
    return out


def run():
    if not ART.exists():
        emit("roofline_missing", 0.0, "run launch/dryrun.py first")
        return
    for pod in ("pod1", "pod2"):
        for tag, d in rows(pod):
            if d["status"] == "skipped":
                emit(f"roofline_{tag}", 0.0, "SKIP|" + d["reason"][:60])
                continue
            if d["status"] != "ok":
                emit(f"roofline_{tag}", 0.0, "ERROR")
                continue
            r = d["roofline"]
            emit(f"roofline_{tag}", 0.0,
                 f"dom={r['dominant']}|tc={r['t_compute_s']:.3e}|"
                 f"tm={r['t_memory_s']:.3e}|tx={r['t_collective_s']:.3e}|"
                 f"mfu={r['mfu_at_bound']:.4f}|useful={r['model_to_hlo_flops']:.3f}")


if __name__ == "__main__":
    run()
