"""Render an engine Chrome-trace JSON as a markdown latency report.

Standalone summarizer over the ``launch/serve.py --trace-out`` (or
``runtime.trace.Tracer.chrome_trace``) artifact — it parses the Chrome
Trace Event Format document directly (no engine state needed), so it works
on any archived CI trace:

    PYTHONPATH=src python scripts/trace_report.py /tmp/trace.json
    PYTHONPATH=src python scripts/trace_report.py trace.json -o report.md

Output: a per-request latency waterfall table (queue-wait vs prefill vs
decode, reconstructed from the ``queued``/``prefill``/``decode`` span
stack on each request thread) plus p50/p95/p99 percentiles across
requests, and a per-tick phase breakdown from the engine-tick slices.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.runtime.trace import (ENGINE_PID, REQUEST_PID,  # noqa: E402
                                 validate_chrome_trace)

_SPANS = ("queued", "prefill", "decode")


def load_events(path) -> list[dict]:
    doc = json.loads(Path(path).read_text())
    validate_chrome_trace(doc)
    return doc["traceEvents"] if isinstance(doc, dict) else doc


def request_waterfalls(events: list[dict]) -> dict[int, dict]:
    """rid -> span durations (us) + finish info, via B/E stack matching."""
    out: dict[int, dict] = {}
    open_at: dict[tuple, list] = {}
    for ev in events:
        if ev.get("pid") != REQUEST_PID:
            continue
        rid = ev["tid"]
        row = out.setdefault(rid, {"reason": None, "steps": {}})
        ph, name = ev.get("ph"), ev.get("name")
        if ph == "B":
            open_at.setdefault((rid, name), []).append(ev["ts"])
        elif ph == "E":
            starts = open_at.get((rid, name))
            if starts:
                row[f"{name}_us"] = ev["ts"] - starts.pop()
                row["steps"][name] = ev.get("args", {}).get("step")
        elif ph == "i" and isinstance(name, str) \
                and name.startswith("finish:"):
            row["reason"] = name.split(":", 1)[1]
            row["finished_step"] = ev.get("args", {}).get("step")
    return out


def tick_breakdown(events: list[dict]) -> dict[str, dict]:
    """Engine-tick slice stats grouped by phase kind (prefill/decode/idle)."""
    buckets: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("pid") != ENGINE_PID or ev.get("ph") != "X":
            continue
        name = ev.get("name", "")
        kind = "prefill" if name.startswith("prefill_chunk") else name
        buckets.setdefault(kind, []).append(float(ev.get("dur", 0.0)))
    return {
        kind: {"ticks": len(durs), "total_us": float(np.sum(durs)),
               "mean_us": float(np.mean(durs)),
               "p95_us": float(np.percentile(durs, 95))}
        for kind, durs in sorted(buckets.items())}


def _fmt_us(v) -> str:
    return f"{v:,.0f}" if v is not None else "-"


def render_markdown(path) -> str:
    events = load_events(path)
    reqs = request_waterfalls(events)
    ticks = tick_breakdown(events)
    lines = [f"# Trace report: `{path}`", ""]

    lines += ["## Per-request latency waterfall (engine-clock µs)", "",
              "| rid | reason | finish step | queue wait | prefill "
              "| decode | total |",
              "|---:|---|---:|---:|---:|---:|---:|"]
    cols = {k: [] for k in ("queued_us", "prefill_us", "decode_us",
                            "total_us")}
    for rid in sorted(reqs):
        row = reqs[rid]
        parts = [row.get(f"{s}_us") for s in _SPANS]
        total = sum(p for p in parts if p is not None) \
            if any(p is not None for p in parts) else None
        for key, val in zip(("queued_us", "prefill_us", "decode_us"), parts):
            if val is not None:
                cols[key].append(val)
        if total is not None:
            cols["total_us"].append(total)
        lines.append(
            f"| {rid} | {row.get('reason') or '?'} "
            f"| {row.get('finished_step', '-')} "
            f"| {_fmt_us(parts[0])} | {_fmt_us(parts[1])} "
            f"| {_fmt_us(parts[2])} | {_fmt_us(total)} |")

    lines += ["", "## Percentiles across requests (µs)", "",
              "| phase | p50 | p95 | p99 | mean | n |",
              "|---|---:|---:|---:|---:|---:|"]
    labels = {"queued_us": "queue wait", "prefill_us": "prefill",
              "decode_us": "decode", "total_us": "total"}
    for key, label in labels.items():
        vs = cols[key]
        if vs:
            lines.append(
                f"| {label} | {_fmt_us(np.percentile(vs, 50))} "
                f"| {_fmt_us(np.percentile(vs, 95))} "
                f"| {_fmt_us(np.percentile(vs, 99))} "
                f"| {_fmt_us(np.mean(vs))} | {len(vs)} |")
        else:
            lines.append(f"| {label} | - | - | - | - | 0 |")

    lines += ["", "## Engine ticks by phase", "",
              "| phase | ticks | total µs | mean µs | p95 µs |",
              "|---|---:|---:|---:|---:|"]
    for kind, s in ticks.items():
        lines.append(f"| {kind} | {s['ticks']} | {_fmt_us(s['total_us'])} "
                     f"| {_fmt_us(s['mean_us'])} | {_fmt_us(s['p95_us'])} |")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON from --trace-out")
    ap.add_argument("-o", "--out", default=None,
                    help="write markdown here (default: stdout)")
    args = ap.parse_args(argv)
    md = render_markdown(args.trace)
    if args.out:
        Path(args.out).write_text(md)
        print(f"[trace_report] wrote {args.out}")
    else:
        print(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
