"""Generate the EXPERIMENTS.md roofline table from artifacts/dryrun/*.json."""
import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

ORDER = ["yi-34b", "qwen2.5-14b", "qwen1.5-0.5b", "nemotron-4-15b",
         "llava-next-mistral-7b", "musicgen-large", "mamba2-1.3b",
         "mixtral-8x7b", "kimi-k2-1t-a32b", "zamba2-2.7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, digits=3):
    if x == 0:
        return "0"
    return f"{x:.{digits}g}"


def bottleneck_fix(d):
    r = d["roofline"]
    dom = r["dominant"]
    arch, shape = d["arch"], d["shape"]
    if dom == "collective":
        return "cut TP degree / batch-shard more (model too small for 16-way TP)"
    if dom == "memory":
        if "moe" in arch or "kimi" in arch or "mixtral" in arch:
            return "shrink MoE dispatch buffers (bf16 buffers, local capacity)"
        if shape.startswith("decode"):
            return "KV-cache layout: avoid cache rewrite, quantize KV to int8"
        return "fuse elementwise chains / drop remat saves (bf16 residuals)"
    return "increase per-chip batch or reduce remat recompute"


def main(pod="pod1"):
    rows = []
    for arch in ORDER:
        for shape in SHAPES:
            f = ART / f"{arch}__{shape}__{pod}.json"
            if not f.exists():
                rows.append(f"| {arch} | {shape} | — | missing |  |  |  |  |  |  |")
                continue
            d = json.loads(f.read_text())
            if d["status"] == "skipped":
                rows.append(
                    f"| {arch} | {shape} | skip | full-attention: N/A per DESIGN §5 |  |  |  |  |  |  |")
                continue
            r = d["roofline"]
            mem = d.get("memory_analysis", {})
            tmp_gb = (mem.get("temp_size_in_bytes") or 0) / 2**30
            arg_gb = (mem.get("argument_size_in_bytes") or 0) / 2**30
            rows.append(
                f"| {arch} | {shape} | {r['dominant'][:4]} "
                f"| {fmt(r['t_compute_s'])} | {fmt(r['t_memory_s'])} "
                f"| {fmt(r['t_collective_s'])} | {fmt(r['mfu_at_bound'], 2)} "
                f"| {fmt(r['model_to_hlo_flops'], 2)} "
                f"| {arg_gb:.1f}+{tmp_gb:.1f} | {bottleneck_fix(d)} |")
    hdr = ("| arch | shape | dom | t_comp (s) | t_mem (s) | t_coll (s) | MFU@bound "
           "| useful-FLOP ratio | GB/dev (args+temp) | what moves the dominant term |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    print(hdr)
    print("\n".join(rows))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "pod1")
