"""Autotune TD-VMM kernel block sizes and regenerate autotune_table.py.

Sweeps (block_m, block_k, block_n) candidates per (M, K, N, dtype) launch
shape, times the fused Pallas path through ``ops.tdvmm_matmul`` (median of
repeats, ``block_until_ready``-fenced, early-abandoning candidates whose
first sample is already far off the best), and rewrites
``src/repro/kernels/tdvmm/autotune_table.py`` for the platform it ran on —
the other platform's table is preserved verbatim.

Shapes come from two sources:

  * the fixed shapes ``benchmarks/bench_kernels.py`` times (always included,
    so the checked-in BENCH_kernels.json rows are table hits), and
  * every launch shape the resolved plans emit
    (``configs.plan.plan_launch_shapes``) across the selected ``--archs``
    at ``--m`` tokens — the model-emitted work list.

Shapes whose FLOP count exceeds ``--measure-limit`` are not timed: on the
interpret platform the wall-clock model is known (time scales with the grid
*step count*, each step being a Python-level block dispatch), so the
largest-single-block candidate is written directly.  Pass a larger limit to
time them anyway.

Usage:
    python scripts/autotune_tdvmm.py                  # all archs, m=512
    python scripts/autotune_tdvmm.py --archs mamba2-1.3b qwen1.5-0.5b
    python scripts/autotune_tdvmm.py --dry-run        # print, don't write
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
for p in (str(ROOT / "src"), str(ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

TABLE_PATH = ROOT / "src" / "repro" / "kernels" / "tdvmm" / "autotune_table.py"

# The shapes benchmarks/bench_kernels.py times (plus the perceptron case
# study): these must be table hits so the checked-in BENCH_kernels.json rows
# carry autotune_hit=True.
BENCH_SHAPES: list[tuple[int, int, int, str]] = [
    # bench_tdvmm_backends (f32 codes) + the int8/int4 byte-count shapes
    (512, 1024, 4096, "float32"),
    (512, 1024, 4096, "int8"),
    (512, 1024, 4096, "int4"),
    (256, 896, 896, "float32"),
    (33, 300, 130, "float32"),
    (512, 2048, 512, "float32"),
    (512, 2048, 512, "int8"),
    (512, 2048, 512, "int4"),
    # td_matmul_layer + bench_fused_epilogue
    (256, 1024, 4096, "int8"),
    (256, 1024, 512, "int8"),
    # bench_grouped_projection ragged concat launches
    (64, 896, 1152, "int8"),
    (64, 512, 2432, "int8"),
    # the perceptron case-study shape
    (8, 128, 64, "float32"),
    (8, 128, 64, "int8"),
]

# Giant blocks: min(block, padded dim) clamps these to a single grid step in
# every dimension — the interpret-mode optimum whenever it fits in memory.
SINGLE_BLOCK = (1 << 14, 1 << 15, 1 << 15)


def _interpret_candidates(m, k, n, name):
    from repro.kernels.tdvmm import tdvmm
    cands = [
        SINGLE_BLOCK,                       # one grid step
        (SINGLE_BLOCK[0], SINGLE_BLOCK[1], 2048),  # walk N in big strides
        (512, SINGLE_BLOCK[1], 2048),
        tdvmm._heuristic_blocks(name, "interpret"),
    ]
    seen, out = set(), []
    for c in cands:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def _mosaic_candidates(m, k, n, name, vmem_bytes=14 * 2**20):
    """VMEM-budgeted MXU tiles: x block + w block (double-buffered streams)
    plus the f32 accumulator and output tile must fit the per-core budget."""
    from repro.kernels.tdvmm import tdvmm
    itemsize = 4 if name == "float32" else 1
    kdiv = 2 if name == "int4" else 1  # packed-unit K stream
    out = []
    for bm in (128, 256, 512):
        for bk in (512, 1024, 2048, 4096):
            for bn in (128, 256, 512):
                use = (2 * (bm * bk + bk * bn) * itemsize // kdiv
                       + 2 * bm * bn * 4)
                if use <= vmem_bytes:
                    out.append((bm, bk, bn))
    out.append(tdvmm._heuristic_blocks(name, "mosaic"))
    return sorted(set(out))


def _operands(m, k, n, name, rng):
    lim = 7 if name == "int4" else 63
    x = rng.integers(-lim, lim + 1, size=(m, k)).astype(np.int8)
    w = rng.integers(-lim, lim + 1, size=(k, n)).astype(np.int8)
    if name == "float32":
        x, w = x.astype(np.float32), w.astype(np.float32)
    xs = jnp.ones((m,), jnp.float32)
    ws = jnp.ones((n,), jnp.float32)
    return jnp.asarray(x), jnp.asarray(w), xs, ws


def _time_candidate(args_, blocks, code_dtype, interpret, best_us):
    """Median-of-repeats for one block candidate, early-abandoning when the
    first post-compile sample is already >= 2x the incumbent."""
    import functools

    from benchmarks.common import time_call
    from repro.kernels.tdvmm import ops

    x, w, xs, ws = args_
    fn = jax.jit(functools.partial(
        ops.tdvmm_matmul, gain=1e-4, out_bits=6, out_scale=0.5,
        backend="pallas", interpret=interpret, code_dtype=code_dtype,
        block_sizes=blocks))
    probe = time_call(fn, x, w, xs, ws, warmup=1, iters=1)
    if best_us is not None and probe >= 2.0 * best_us:
        return float(probe)
    return float(time_call(fn, x, w, xs, ws, warmup=0, iters=3))


def collect_shapes(arch_names, m):
    from repro.configs import archs, plan as planmod
    shapes = dict.fromkeys(BENCH_SHAPES)
    for a in arch_names:
        cfg = archs.get_config(a)
        for shp in planmod.plan_launch_shapes(cfg, m):
            shapes[shp] = None
    return list(shapes)


def sweep(shapes, measure_limit):
    from repro.kernels.tdvmm import tdvmm
    platform = tdvmm.autotune_platform()
    interpret = platform == "interpret"
    rng = np.random.default_rng(0)
    table, report = {}, []
    for m, k, n, name in shapes:
        key = (m, k, n, name)
        cands = (_interpret_candidates(m, k, n, name) if interpret
                 else _mosaic_candidates(m, k, n, name))
        if 2 * m * k * n > measure_limit:
            # Too big to time here: the interpret wall-clock model says
            # fewest grid steps wins, so take the single-block candidate.
            table[key] = cands[0]
            report.append((key, cands[0], None, "arithmetic"))
            continue
        best, best_us = None, None
        for cand in cands:
            code_dtype = {"float32": "f32"}.get(name, name)
            us = _time_candidate(
                (_operands(m, k, n, name, rng)), cand, code_dtype,
                interpret, best_us)
            if best_us is None or us < best_us:
                best, best_us = cand, us
        table[key] = best
        report.append((key, best, best_us, "measured"))
        print(f"  {m}x{k}x{n}:{name} -> {best}  ({best_us:.0f} us)")
    return platform, table, report


def render(platform, table):
    """Regenerate autotune_table.py: the swept platform's table is replaced,
    the other platform's entries are carried over verbatim."""
    from repro.kernels.tdvmm import autotune_table as current
    tables = {"mosaic": dict(current.MOSAIC_TABLE),
              "interpret": dict(current.INTERPRET_TABLE)}
    tables[platform] = table

    def fmt(tbl):
        lines = []
        for (m, k, n, name), blocks in sorted(tbl.items()):
            lines.append(f'    ({m}, {k}, {n}, "{name}"): {blocks!r},')
        return "\n".join(lines)

    doc = current.__doc__.rstrip("\n")
    return f'''"""{doc}
"""

# fmt: off
MOSAIC_TABLE: dict[tuple[int, int, int, str], tuple[int, int, int]] = {{
{fmt(tables["mosaic"])}
}}

INTERPRET_TABLE: dict[tuple[int, int, int, str], tuple[int, int, int]] = {{
{fmt(tables["interpret"])}
}}
# fmt: on
'''


def main(argv=None):
    from repro.configs import archs
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--archs", nargs="*", default=sorted(archs.ARCHS),
                    help="arch ids whose plan-emitted shapes to tune "
                         "(default: all)")
    ap.add_argument("--m", type=int, default=512,
                    help="token count M for plan-emitted shapes")
    ap.add_argument("--measure-limit", type=float, default=2e10,
                    help="max 2*M*K*N FLOPs to actually time; larger shapes "
                         "get the arithmetic single-block choice")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the table instead of writing it")
    args = ap.parse_args(argv)

    shapes = collect_shapes(args.archs, args.m)
    print(f"tuning {len(shapes)} shapes "
          f"({sum(1 for s in shapes if 2*s[0]*s[1]*s[2] <= args.measure_limit)}"
          f" measured)")
    platform, table, report = sweep(shapes, args.measure_limit)
    text = render(platform, table)
    if args.dry_run:
        print(text)
        return
    TABLE_PATH.write_text(text)
    measured = sum(1 for *_, how in report if how == "measured")
    print(f"wrote {TABLE_PATH} ({platform}: {len(table)} entries, "
          f"{measured} measured, {len(report) - measured} arithmetic)")


if __name__ == "__main__":
    main()
